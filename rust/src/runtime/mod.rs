//! PJRT runtime: loads the HLO-text artifacts exported by
//! `python/compile/aot.py` and executes them on the XLA CPU client via
//! the `xla` crate. This is the *reference* (multiplier-full) execution
//! path the LUT engine is compared against; it is also proof that the
//! JAX model and the Rust weights agree.
//!
//! Interchange is HLO **text**, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{ensure, Context, Result};
use std::path::Path;

/// A compiled XLA executable with a fixed input signature
/// `f32[batch, features] -> (f32[batch, classes],)`.
pub struct PjrtModel {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub features: usize,
    pub classes: usize,
    platform: String,
}

impl PjrtModel {
    /// Load and compile an HLO text file. `batch`/`features`/`classes`
    /// must match the shapes the artifact was lowered with.
    pub fn load(path: &Path, batch: usize, features: usize, classes: usize) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(PjrtModel { exe, batch, features, classes, platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Run a full batch. `images` must be exactly `batch * features`
    /// long; returns `batch * classes` logits.
    pub fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            images.len() == self.batch * self.features,
            "expected {} values, got {}",
            self.batch * self.features,
            images.len()
        );
        let x = xla::Literal::vec1(images)
            .reshape(&[self.batch as i64, self.features as i64])
            .context("reshaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[x])?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let logits = out.to_vec::<f32>().context("reading logits")?;
        ensure!(
            logits.len() == self.batch * self.classes,
            "expected {} logits, got {}",
            self.batch * self.classes,
            logits.len()
        );
        Ok(logits)
    }

    /// Run up to `batch` images, padding the tail with zeros; returns
    /// one logits row per input image.
    pub fn infer_padded(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        ensure!(images.len() <= self.batch, "batch overflow");
        let mut flat = vec![0f32; self.batch * self.features];
        for (i, img) in images.iter().enumerate() {
            ensure!(img.len() == self.features, "image {i} has wrong size");
            flat[i * self.features..(i + 1) * self.features].copy_from_slice(img);
        }
        let logits = self.infer_batch(&flat)?;
        Ok(images
            .iter()
            .enumerate()
            .map(|(i, _)| logits[i * self.classes..(i + 1) * self.classes].to_vec())
            .collect())
    }

    /// Classify a batch (argmax per row).
    pub fn classify(&self, images: &[Vec<f32>]) -> Result<Vec<usize>> {
        Ok(self
            .infer_padded(images)?
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect())
    }
}

/// Standard artifact path for a reference model.
pub fn ref_hlo_path(artifacts: &Path, arch: crate::nn::Arch, batch: usize) -> std::path::PathBuf {
    artifacts.join(format!("{}_ref_b{batch}.hlo.txt", arch.name()))
}

// NOTE: runtime tests live in rust/tests/runtime_integration.rs — they
// need `make artifacts` to have produced HLO files and are integration-
// scoped, not unit-scoped.
