//! Scalar-nonlinearity LUTs (paper §Computing a nonlinear function f
//! with LUT): a binary16 -> binary16 table is 2^16 · 16 bits = 128 KiB
//! ("reducing the input and output to a 16-bit half-precision float
//! reduces the LUT table size to 128 Kibibytes") and replaces sigmoids /
//! tanh / any scalar activation with a single memory read.
//!
//! ReLU deliberately has no table — the paper implements it as a
//! compare-and-branch, and so does the engine.

use crate::engine::counters::Counters;
use crate::lut::cost::scalar_fn_size_bits;
use crate::lut::wire;
use crate::quant::f16::F16;

/// A full binary16 -> binary16 scalar function table.
pub struct ScalarLut {
    /// Human-readable function name (metrics/debug).
    pub name: &'static str,
    /// table[bits] = f16 output bits for f16 input pattern `bits`.
    table: Vec<u16>,
}

impl ScalarLut {
    /// Tabulate an arbitrary scalar function over every f16 input
    /// pattern (full precision inside — "the computations needed to
    /// produce the elements in O ... can all be done in full
    /// precision"). Non-finite inputs map through the function of their
    /// decoded value; NaN-in propagates NaN-out.
    pub fn tabulate(name: &'static str, f: impl Fn(f32) -> f32) -> ScalarLut {
        let mut table = Vec::with_capacity(1 << 16);
        for bits in 0..=u16::MAX {
            let x = F16(bits).to_f32();
            table.push(F16::from_f32(f(x)).0);
        }
        ScalarLut { name, table }
    }

    /// The logistic sigmoid 1/(1+e^-x).
    pub fn sigmoid() -> ScalarLut {
        ScalarLut::tabulate("sigmoid", |x| 1.0 / (1.0 + (-x).exp()))
    }

    /// tanh.
    pub fn tanh() -> ScalarLut {
        ScalarLut::tabulate("tanh", f32::tanh)
    }

    /// One lookup per element — no arithmetic at all.
    #[inline]
    pub fn eval(&self, x: F16, ctr: &mut Counters) -> F16 {
        ctr.lut_evals += 1;
        F16(self.table[x.0 as usize])
    }

    /// Map a whole vector in place.
    pub fn eval_vec(&self, xs: &mut [F16], ctr: &mut Counters) {
        for x in xs.iter_mut() {
            *x = F16(self.table[x.0 as usize]);
        }
        ctr.lut_evals += xs.len() as u64;
    }

    /// Size in bits: 2^16 · 16 — the paper's 128 KiB.
    pub fn size_bits(&self) -> u64 {
        scalar_fn_size_bits(16, 16)
    }

    /// Serialize for the `.ltm` artifact: name + the full 128 KiB table
    /// (the table is the ground truth — arbitrary tabulated functions
    /// round-trip bit-exactly, not just the named ones).
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        let name = self.name.as_bytes();
        wire::put_u32(out, name.len() as u32);
        out.extend_from_slice(name);
        for &e in &self.table {
            wire::put_u16(out, e);
        }
    }

    /// Deserialize a table written by [`ScalarLut::write_wire`]. The
    /// name is mapped back to a known static label ("custom" when the
    /// function is not one of the built-ins).
    pub fn read_wire(r: &mut wire::Reader) -> wire::Result<ScalarLut> {
        let name_len = r.u32()? as usize;
        if name_len > 64 {
            return wire::err(format!("scalar LUT name too long ({name_len})"));
        }
        let name_bytes = r.take(name_len)?;
        let name = match std::str::from_utf8(name_bytes) {
            Ok("sigmoid") => "sigmoid",
            Ok("tanh") => "tanh",
            Ok(_) => "custom",
            Err(_) => return wire::err("scalar LUT name not utf-8"),
        };
        let mut table = Vec::with_capacity(1 << 16);
        for _ in 0..(1usize << 16) {
            table.push(r.u16()?);
        }
        Ok(ScalarLut { name, table })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_is_the_papers_128_kib() {
        let s = ScalarLut::sigmoid();
        assert_eq!(s.size_bits() / 8 / 1024, 128);
    }

    #[test]
    fn sigmoid_matches_function_to_f16_precision() {
        let s = ScalarLut::sigmoid();
        let mut ctr = Counters::default();
        for x in [-8.0f32, -2.0, -0.5, 0.0, 0.5, 2.0, 8.0] {
            let got = s.eval(F16::from_f32(x), &mut ctr).to_f32();
            let want = 1.0 / (1.0 + (-F16::fake_quant(x)).exp());
            assert!(
                (got - want).abs() <= 2.0 * (want * 2.0f32.powi(-11)).abs() + 1e-4,
                "x={x}: {got} vs {want}"
            );
        }
        assert_eq!(ctr.mults, 0);
        assert_eq!(ctr.lut_evals, 7);
    }

    #[test]
    fn tanh_is_odd_through_the_table() {
        let t = ScalarLut::tanh();
        let mut ctr = Counters::default();
        for x in [0.25f32, 1.0, 3.0] {
            let pos = t.eval(F16::from_f32(x), &mut ctr).to_f32();
            let neg = t.eval(F16::from_f32(-x), &mut ctr).to_f32();
            assert!((pos + neg).abs() < 1e-3, "tanh not odd at {x}");
        }
    }

    #[test]
    fn eval_vec_counts_and_transforms() {
        let s = ScalarLut::sigmoid();
        let mut v: Vec<F16> = vec![F16::from_f32(0.0); 10];
        let mut ctr = Counters::default();
        s.eval_vec(&mut v, &mut ctr);
        assert_eq!(ctr.lut_evals, 10);
        for h in v {
            assert!((h.to_f32() - 0.5).abs() < 1e-3);
        }
    }

    #[test]
    fn sigmoid_saturates_cleanly() {
        let s = ScalarLut::sigmoid();
        let mut ctr = Counters::default();
        assert_eq!(s.eval(F16::from_f32(30.0), &mut ctr).to_f32(), 1.0);
        assert_eq!(s.eval(F16::from_f32(-30.0), &mut ctr).to_f32(), 0.0);
    }

    #[test]
    fn wire_roundtrip_preserves_table() {
        let s = ScalarLut::sigmoid();
        let mut buf = Vec::new();
        s.write_wire(&mut buf);
        let back = ScalarLut::read_wire(&mut wire::Reader::new(&buf)).unwrap();
        assert_eq!(back.name, "sigmoid");
        assert_eq!(back.table, s.table);
        let custom = ScalarLut::tabulate("square", |x| x * x);
        let mut buf2 = Vec::new();
        custom.write_wire(&mut buf2);
        let back2 = ScalarLut::read_wire(&mut wire::Reader::new(&buf2)).unwrap();
        assert_eq!(back2.name, "custom");
        assert_eq!(back2.table, custom.table);
    }

    #[test]
    fn nan_propagates() {
        let s = ScalarLut::tabulate("id", |x| x);
        let mut ctr = Counters::default();
        let nan = F16(0x7C01);
        assert!(s.eval(nan, &mut ctr).to_f32().is_nan());
    }
}
