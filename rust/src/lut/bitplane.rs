//! Fixed-point bitplane LUT bank (paper §Fixed point formats).
//!
//! Writing each input element as `x_i = Σ_j a_ij 2^j` and swapping the
//! summation order gives `Wx = Σ_j 2^j · W a_·j` — the *same* table
//! serves every bitplane `j`, evaluated `n` times with its output
//! shifted left by `j` and added. A chunk of `m` elements needs only a
//! `2^m`-row table (vs `2^(m·n)` for whole-code indexing), at the price
//! of `n·k` lookups instead of `k`.

use super::{to_acc, LutError, Partition, MAX_TABLE_BYTES};
use crate::engine::counters::Counters;
use crate::quant::FixedFormat;

/// One `2^m x p` table per chunk, shared across all n bitplanes.
#[derive(Debug)]
pub struct DenseBitplaneLut {
    pub partition: Partition,
    pub fmt: FixedFormat,
    pub p: usize,
    /// tables[c][idx * p + o] = Σ_{s in chunk, bit_s(idx)=1} W[o, s],
    /// in accumulator scale *at the LSB plane* (plane j adds `<< j`).
    tables: Vec<Vec<i64>>,
    /// Bias in accumulator scale, added once per evaluation.
    bias_acc: Vec<i64>,
}

impl DenseBitplaneLut {
    pub fn build(
        w: &[f32],
        b: &[f32],
        p: usize,
        q: usize,
        partition: Partition,
        fmt: FixedFormat,
    ) -> Result<Self, LutError> {
        assert_eq!(w.len(), p * q);
        assert_eq!(b.len(), p);
        partition.validate()?;
        assert_eq!(partition.q, q);
        let mut tables = Vec::with_capacity(partition.k());
        for chunk in &partition.chunks {
            let m = chunk.len();
            if m >= 28 {
                return Err(LutError::TooLarge { rows: 1u128 << m, cols: p });
            }
            let rows = 1usize << m;
            if rows * p * 8 > MAX_TABLE_BYTES {
                return Err(LutError::TooLarge { rows: rows as u128, cols: p });
            }
            let mut table = vec![0i64; rows * p];
            for idx in 0..rows {
                let row = &mut table[idx * p..(idx + 1) * p];
                for (e, &col) in chunk.iter().enumerate() {
                    if (idx >> e) & 1 == 1 {
                        // LSB-plane weight: w * 2^-n (code LSB value)
                        let scale = (-(fmt.bits as f64)).exp2();
                        for (o, r) in row.iter_mut().enumerate() {
                            *r += to_acc(w[o * q + col] as f64 * scale);
                        }
                    }
                }
            }
            tables.push(table);
        }
        let bias_acc = b.iter().map(|&v| to_acc(v as f64)).collect();
        Ok(DenseBitplaneLut { partition, fmt, p, tables, bias_acc })
    }

    /// Evaluate `Wx + b` from quantized codes: for each chunk and each
    /// bitplane, gather the plane's bits into an index, look up, shift
    /// by the plane, add. `n·k` lookups, zero multiplies.
    ///
    /// Hot-path notes (§Perf): the plane indices of a chunk are built in
    /// a *single pass* over its codes (one load per element, bits
    /// deposited into all n indices) instead of n passes, and the row
    /// accumulation uses unchecked slices — the index is `< 2^m` by
    /// construction and the table has exactly `2^m · p` entries.
    pub fn eval_codes(&self, codes: &[u32], ctr: &mut Counters) -> Vec<i64> {
        assert_eq!(codes.len(), self.partition.q);
        let n = self.fmt.bits as usize;
        let mut acc = self.bias_acc.clone();
        ctr.adds += self.p as u64; // bias add
        let mut idx = [0usize; 16]; // n <= 16 by FixedFormat invariant
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = &self.tables[c];
            // fast path for singleton chunks (the paper's k = q, m_i = 1
            // memory-parity configuration): the table has two rows and
            // the code's set bits directly select shifted adds of row 1.
            if let [col] = chunk.as_slice() {
                let mut code = unsafe { *codes.get_unchecked(*col) } as usize;
                ctr.lut_evals += n as u64;
                let row = unsafe { table.get_unchecked(self.p..2 * self.p) };
                while code != 0 {
                    let j = code.trailing_zeros();
                    for (a, &r) in acc.iter_mut().zip(row) {
                        *a += r << j;
                    }
                    ctr.shift_adds += self.p as u64;
                    code &= code - 1; // clear lowest set bit
                }
                continue;
            }
            idx[..n].fill(0);
            for (e, &col) in chunk.iter().enumerate() {
                let code = unsafe { *codes.get_unchecked(col) } as usize;
                for (j, slot) in idx[..n].iter_mut().enumerate() {
                    *slot |= ((code >> j) & 1) << e;
                }
            }
            ctr.lut_evals += n as u64;
            for (j, &row_idx) in idx[..n].iter().enumerate() {
                if row_idx == 0 {
                    // all-zero row is identically zero; hardware would
                    // still read it — the lookup is charged above.
                    continue;
                }
                let row = unsafe {
                    table.get_unchecked(row_idx * self.p..(row_idx + 1) * self.p)
                };
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += r << j;
                }
                ctr.shift_adds += self.p as u64;
            }
        }
        acc
    }

    /// Quantize then evaluate.
    pub fn eval_f32(&self, x: &[f32], ctr: &mut Counters) -> Vec<i64> {
        let codes: Vec<u32> = x.iter().map(|&v| self.fmt.quantize(v)).collect();
        self.eval_codes(&codes, ctr)
    }

    /// Total size in bits at `r_o`-bit entries: Σ_i 2^{m_i}·p·r_o.
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.tables
            .iter()
            .map(|t| t.len() as u64 * r_o as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::from_acc;
    use crate::util::Rng;

    fn ref_affine(w: &[f32], b: &[f32], p: usize, q: usize, x: &[f32]) -> Vec<f32> {
        (0..p)
            .map(|o| b[o] + (0..q).map(|i| w[o * q + i] * x[i]).sum::<f32>())
            .collect()
    }

    fn random_case(p: usize, q: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..p * q).map(|_| rng.normal() * 0.5).collect(),
            (0..p).map(|_| rng.normal() * 0.1).collect(),
            (0..q).map(|_| rng.f32()).collect(),
        )
    }

    #[test]
    fn matches_reference_on_quantized_input() {
        let (p, q) = (6, 16);
        let (w, b, x) = random_case(p, q, 3);
        let fmt = FixedFormat::new(5);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 4), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            assert!(
                (from_acc(a, 0) - want[o]).abs() < 1e-4,
                "{} vs {}",
                from_acc(a, 0),
                want[o]
            );
        }
    }

    #[test]
    fn agrees_with_whole_code_lut() {
        use crate::lut::dense::DenseWholeLut;
        let (p, q) = (4, 8);
        let (w, b, x) = random_case(p, q, 9);
        let fmt = FixedFormat::new(3);
        let whole =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        let plane =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt)
                .unwrap();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        let a1 = whole.eval_f32(&x, &mut c1);
        let a2 = plane.eval_f32(&x, &mut c2);
        for (x1, x2) in a1.iter().zip(&a2) {
            assert!((from_acc(*x1, 0) - from_acc(*x2, 0)).abs() < 1e-5);
        }
        // bitplane does n× the lookups of whole-code
        assert_eq!(c2.lut_evals, c1.lut_evals * fmt.bits as u64);
    }

    #[test]
    fn lookup_count_is_n_times_k() {
        let (p, q) = (3, 12);
        let (w, b, x) = random_case(p, q, 1);
        let fmt = FixedFormat::new(4);
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 3), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let _ = lut.eval_f32(&x, &mut ctr);
        assert_eq!(ctr.lut_evals, (4 * 4) as u64); // n=4 planes, k=4 chunks
        assert_eq!(ctr.mults, 0);
    }

    #[test]
    fn size_is_exponential_in_m_not_in_n() {
        let (p, q) = (10, 8);
        let w = vec![0.0f32; p * q];
        let b = vec![0.0f32; p];
        let s3 = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(3),
        )
        .unwrap()
        .size_bits(16);
        let s8 = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(8),
        )
        .unwrap()
        .size_bits(16);
        // bitplane table size is independent of input precision n
        assert_eq!(s3, s8);
        assert_eq!(s3, 4 * 4 * 10 * 16); // k=4, 2^2 rows, p=10, 16-bit
    }

    #[test]
    fn zero_input_gives_bias() {
        let (p, q) = (3, 6);
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal()).collect();
        let b = vec![1.0f32, -2.0, 0.5];
        let lut = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(4),
        )
        .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&vec![0.0; q], &mut ctr);
        for (o, &a) in acc.iter().enumerate() {
            assert!((from_acc(a, 0) - b[o]).abs() < 1e-6);
        }
    }
}
