//! Fixed-point bitplane LUT bank (paper §Fixed point formats).
//!
//! Writing each input element as `x_i = Σ_j a_ij 2^j` and swapping the
//! summation order gives `Wx = Σ_j 2^j · W a_·j` — the *same* table
//! serves every bitplane `j`, evaluated `n` times with its output
//! shifted left by `j` and added. A chunk of `m` elements needs only a
//! `2^m`-row table (vs `2^(m·n)` for whole-code indexing), at the price
//! of `n·k` lookups instead of `k`.
//!
//! Hot-path structure (§Perf):
//!
//! * tables live in one contiguous [`TableArena`], i32-narrowed when
//!   every entry fits (half the bytes per gathered row);
//! * [`DenseBitplaneLut::eval_batch`] is chunk-outer / sample-inner, so
//!   a chunk's table is streamed once per *batch*;
//! * when `n · max_chunk ≤ 64` and `n ≤ 8`, all n plane indices of a
//!   chunk are built in a **single packed u64** per sample via a
//!   2^n-entry spread table (`spread[code]` pre-scatters code bit j to
//!   bit `j·M`), replacing the n-pass bit-deposit inner loop with one
//!   load + shift + or per element. The paper's linear config (r=3,
//!   m=14 → 42 packed bits) takes this path.

use super::arena::{with_arena, ArenaEntry, TableArena};
use super::{to_acc, wire, LutError, Partition, MAX_TABLE_BYTES};
use crate::engine::counters::Counters;
use crate::quant::FixedFormat;

/// Packed-plane spread table for `(n, stride)`: `spread[code] =
/// Σ_j bit_j(code) << (j·stride)`; `None` when packing does not fit in
/// a u64. Shared by [`DenseBitplaneLut::build`] and the artifact
/// decoder so both construct byte-identical fast paths.
fn spread_table(n: u32, stride: u32) -> Option<Vec<u64>> {
    if n <= 8 && n * stride <= 64 && stride >= 1 {
        Some(
            (0..(1u32 << n))
                .map(|code| {
                    (0..n)
                        .map(|j| (((code >> j) & 1) as u64) << (j * stride))
                        .sum()
                })
                .collect(),
        )
    } else {
        None
    }
}

/// One `2^m x p` table per chunk, shared across all n bitplanes.
#[derive(Debug)]
pub struct DenseBitplaneLut {
    pub partition: Partition,
    pub fmt: FixedFormat,
    pub p: usize,
    /// arena chunk c, row idx, col o = Σ_{s in chunk, bit_s(idx)=1}
    /// W[o, s], in accumulator scale *at the LSB plane* (plane j adds
    /// `<< j`).
    arena: TableArena,
    /// Bias in accumulator scale, added once per evaluation.
    bias_acc: Vec<i64>,
    /// Packed-plane spread table: `spread[code] = Σ_j bit_j(code) <<
    /// (j·stride)`; `None` when `n·stride > 64` or `n > 8`.
    spread: Option<Vec<u64>>,
    /// Packed-plane field stride (= partition.max_chunk()).
    stride: u32,
}

impl DenseBitplaneLut {
    pub fn build(
        w: &[f32],
        b: &[f32],
        p: usize,
        q: usize,
        partition: Partition,
        fmt: FixedFormat,
    ) -> Result<Self, LutError> {
        assert_eq!(w.len(), p * q);
        assert_eq!(b.len(), p);
        partition.validate()?;
        assert_eq!(partition.q, q);
        let mut tables = Vec::with_capacity(partition.k());
        for chunk in &partition.chunks {
            let m = chunk.len();
            if m >= 28 {
                return Err(LutError::TooLarge { rows: 1u128 << m, cols: p });
            }
            let rows = 1usize << m;
            // checked: rows * p * 8 can wrap usize on huge configs
            match rows.checked_mul(p).and_then(|e| e.checked_mul(8)) {
                Some(bytes) if bytes <= MAX_TABLE_BYTES => {}
                _ => return Err(LutError::TooLarge { rows: rows as u128, cols: p }),
            }
            let mut table = vec![0i64; rows * p];
            for idx in 0..rows {
                let row = &mut table[idx * p..(idx + 1) * p];
                for (e, &col) in chunk.iter().enumerate() {
                    if (idx >> e) & 1 == 1 {
                        // LSB-plane weight: w * 2^-n (code LSB value)
                        let scale = (-(fmt.bits as f64)).exp2();
                        for (o, r) in row.iter_mut().enumerate() {
                            *r += to_acc(w[o * q + col] as f64 * scale);
                        }
                    }
                }
            }
            tables.push(table);
        }
        let bias_acc = b.iter().map(|&v| to_acc(v as f64)).collect();
        let arena = TableArena::from_tables(&tables, p);
        let stride = partition.max_chunk() as u32;
        let spread = spread_table(fmt.bits, stride);
        Ok(DenseBitplaneLut { partition, fmt, p, arena, bias_acc, spread, stride })
    }

    /// The arena (diagnostics: width, residency).
    pub fn arena(&self) -> &TableArena {
        &self.arena
    }

    /// Evaluate `Wx + b` from quantized codes: for each chunk and each
    /// bitplane, gather the plane's bits into an index, look up, shift
    /// by the plane, add. `n·k` lookups, zero multiplies.
    pub fn eval_codes(&self, codes: &[u32], ctr: &mut Counters) -> Vec<i64> {
        let mut acc = vec![0i64; self.p];
        self.eval_batch(codes, 1, &mut acc, std::slice::from_mut(ctr));
        acc
    }

    /// Batched evaluation: `codes` row-major `batch x q`, `out`
    /// `batch x p` (overwritten with bias-initialised accumulators),
    /// `ctrs` one counter row per sample (shift-adds are data-dependent
    /// and attributed to the exact sample that incurred them).
    /// Chunk-outer / sample-inner. Bit-exact with per-sample
    /// evaluation — identical adds in identical per-sample order.
    pub fn eval_batch(&self, codes: &[u32], batch: usize, out: &mut [i64], ctrs: &mut [Counters]) {
        let q = self.partition.q;
        let p = self.p;
        assert_eq!(codes.len(), batch * q);
        assert_eq!(out.len(), batch * p);
        assert_eq!(ctrs.len(), batch);
        for s in 0..batch {
            out[s * p..(s + 1) * p].copy_from_slice(&self.bias_acc);
        }
        with_arena!(self.arena, E => self.eval_batch_impl::<E>(codes, batch, out, ctrs));
        let n = self.fmt.bits as u64;
        let k = self.partition.k() as u64;
        for ctr in ctrs.iter_mut() {
            ctr.adds += p as u64; // bias adds
            // every plane of every chunk is charged a lookup (hardware
            // reads the row even when the index is all-zero and skipped)
            ctr.lut_evals += n * k;
        }
    }

    /// Records the data-dependent shift-adds (rows actually gathered
    /// × p) on the owning sample's counter row. Dispatches between the
    /// scalar reference loops and the AVX2 lane kernel (see
    /// [`crate::lut::kernel`]): both perform the identical per-sample
    /// multiset of shifted row adds, so outputs and counters are
    /// bit-identical.
    fn eval_batch_impl<E: super::kernel::LaneRow>(
        &self,
        codes: &[u32],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if crate::lut::kernel::active() == crate::lut::kernel::Kernel::Avx2 {
                // SAFETY: active() returns Avx2 only on CPUs with AVX2.
                unsafe { self.eval_batch_avx2::<E>(codes, batch, out, ctrs) };
                return;
            }
        }
        self.eval_batch_scalar::<E>(codes, batch, out, ctrs);
    }

    fn eval_batch_scalar<E: ArenaEntry>(
        &self,
        codes: &[u32],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let q = self.partition.q;
        let p = self.p;
        let n = self.fmt.bits as usize;
        let stride = self.stride;
        let mask = if stride >= 64 { u64::MAX } else { (1u64 << stride) - 1 };
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = self.arena.chunk_slice::<E>(c);
            // fast path for singleton chunks (the paper's k = q, m_i = 1
            // memory-parity configuration): the table has two rows and
            // the code's set bits directly select shifted adds of row 1.
            if let [col] = chunk.as_slice() {
                let row = &table[p..2 * p];
                for s in 0..batch {
                    let mut code = codes[s * q + col] as usize;
                    let acc = &mut out[s * p..(s + 1) * p];
                    while code != 0 {
                        let j = code.trailing_zeros();
                        for (a, r) in acc.iter_mut().zip(row) {
                            *a += r.widen() << j;
                        }
                        ctrs[s].shift_adds += p as u64;
                        code &= code - 1; // clear lowest set bit
                    }
                }
                continue;
            }
            if let Some(spread) = &self.spread {
                // packed-plane path: all n indices in one u64 per sample.
                // the mask drops code bits >= n, matching the general
                // path's deposit loop (which only reads planes j < n)
                let code_mask = spread.len() - 1;
                for s in 0..batch {
                    let srow = &codes[s * q..(s + 1) * q];
                    let mut packed = 0u64;
                    for (e, &col) in chunk.iter().enumerate() {
                        packed |= spread[srow[col] as usize & code_mask] << e;
                    }
                    let acc = &mut out[s * p..(s + 1) * p];
                    for j in 0..n {
                        let row_idx = ((packed >> (j as u32 * stride)) & mask) as usize;
                        if row_idx == 0 {
                            // all-zero row is identically zero; hardware
                            // would still read it — charged in eval_batch.
                            continue;
                        }
                        let row = &table[row_idx * p..(row_idx + 1) * p];
                        for (a, r) in acc.iter_mut().zip(row) {
                            *a += r.widen() << j;
                        }
                        ctrs[s].shift_adds += p as u64;
                    }
                }
                continue;
            }
            // general path: n plane indices built in a single pass over
            // the chunk's codes (one load per element, bits deposited
            // into all n indices)
            for s in 0..batch {
                let srow = &codes[s * q..(s + 1) * q];
                let mut idx = [0usize; 16]; // n <= 16 by FixedFormat invariant
                for (e, &col) in chunk.iter().enumerate() {
                    let code = srow[col] as usize;
                    for (j, slot) in idx[..n].iter_mut().enumerate() {
                        *slot |= ((code >> j) & 1) << e;
                    }
                }
                let acc = &mut out[s * p..(s + 1) * p];
                for (j, &row_idx) in idx[..n].iter().enumerate() {
                    if row_idx == 0 {
                        continue;
                    }
                    let row = &table[row_idx * p..(row_idx + 1) * p];
                    for (a, r) in acc.iter_mut().zip(row) {
                        *a += r.widen() << j;
                    }
                    ctrs[s].shift_adds += p as u64;
                }
            }
        }
    }

    /// AVX2 twin of [`Self::eval_batch_scalar`]: the packed-plane path
    /// builds four samples' packed indices per step — one `vpgatherdd`
    /// per chunk element pulls the four samples' codes, one
    /// `vpgatherqq` pulls their spread words — and every row
    /// accumulation runs 4×i64 lanes per step. The per-sample multiset
    /// of `(row, shift)` adds is identical to the scalar path, so
    /// outputs and counters match bit-for-bit.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_batch_avx2<E: super::kernel::LaneRow>(
        &self,
        codes: &[u32],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        use std::arch::x86_64::*;
        let q = self.partition.q;
        let p = self.p;
        let n = self.fmt.bits as usize;
        let stride = self.stride;
        let mask = if stride >= 64 { u64::MAX } else { (1u64 << stride) - 1 };
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = self.arena.chunk_table::<E>(c);
            if let [col] = chunk.as_slice() {
                let row = table.row(1);
                for s in 0..batch {
                    let mut code = codes[s * q + col] as usize;
                    let acc = &mut out[s * p..(s + 1) * p];
                    while code != 0 {
                        let j = code.trailing_zeros();
                        E::shift_add_row_avx2(acc, row, j);
                        ctrs[s].shift_adds += p as u64;
                        code &= code - 1;
                    }
                }
                continue;
            }
            if let Some(spread) = &self.spread {
                let code_mask = spread.len() - 1;
                debug_assert!(3 * q <= i32::MAX as usize);
                let lane_off = _mm_setr_epi32(0, q as i32, (2 * q) as i32, (3 * q) as i32);
                let mask_v = _mm_set1_epi32(code_mask as i32);
                let mut s0 = 0usize;
                while s0 + 4 <= batch {
                    let mut packed4 = _mm256_setzero_si256();
                    for (e, &col) in chunk.iter().enumerate() {
                        // SAFETY: gathered element offsets are
                        // (s0 + l)·q + col with l < 4 and s0 + 3 < batch,
                        // all below codes.len() = batch·q.
                        let base = codes.as_ptr().add(s0 * q + col) as *const i32;
                        let cv =
                            _mm_and_si128(_mm_i32gather_epi32::<4>(base, lane_off), mask_v);
                        // SAFETY: indices are masked below spread.len()
                        // (a power of two, ≤ 256).
                        let sv = _mm256_i32gather_epi64::<8>(spread.as_ptr() as *const i64, cv);
                        packed4 = _mm256_or_si256(
                            packed4,
                            _mm256_sll_epi64(sv, _mm_cvtsi32_si128(e as i32)),
                        );
                    }
                    let mut packed = [0u64; 4];
                    _mm256_storeu_si256(packed.as_mut_ptr() as *mut __m256i, packed4);
                    for (l, &pk) in packed.iter().enumerate() {
                        let s = s0 + l;
                        let acc = &mut out[s * p..(s + 1) * p];
                        for j in 0..n {
                            let row_idx = ((pk >> (j as u32 * stride)) & mask) as usize;
                            if row_idx == 0 {
                                continue;
                            }
                            E::shift_add_row_avx2(acc, table.row(row_idx), j as u32);
                            ctrs[s].shift_adds += p as u64;
                        }
                    }
                    s0 += 4;
                }
                // ragged tail: scalar packed-index build, lane-wide adds
                for s in s0..batch {
                    let srow = &codes[s * q..(s + 1) * q];
                    let mut packed = 0u64;
                    for (e, &col) in chunk.iter().enumerate() {
                        packed |= spread[srow[col] as usize & code_mask] << e;
                    }
                    let acc = &mut out[s * p..(s + 1) * p];
                    for j in 0..n {
                        let row_idx = ((packed >> (j as u32 * stride)) & mask) as usize;
                        if row_idx == 0 {
                            continue;
                        }
                        E::shift_add_row_avx2(acc, table.row(row_idx), j as u32);
                        ctrs[s].shift_adds += p as u64;
                    }
                }
                continue;
            }
            // general path: scalar index build, lane-wide row adds
            for s in 0..batch {
                let srow = &codes[s * q..(s + 1) * q];
                let mut idx = [0usize; 16]; // n <= 16 by FixedFormat invariant
                for (e, &col) in chunk.iter().enumerate() {
                    let code = srow[col] as usize;
                    for (j, slot) in idx[..n].iter_mut().enumerate() {
                        *slot |= ((code >> j) & 1) << e;
                    }
                }
                let acc = &mut out[s * p..(s + 1) * p];
                for (j, &row_idx) in idx[..n].iter().enumerate() {
                    if row_idx == 0 {
                        continue;
                    }
                    E::shift_add_row_avx2(acc, table.row(row_idx), j as u32);
                    ctrs[s].shift_adds += p as u64;
                }
            }
        }
    }

    /// Quantize then evaluate.
    pub fn eval_f32(&self, x: &[f32], ctr: &mut Counters) -> Vec<i64> {
        let codes: Vec<u32> = x.iter().map(|&v| self.fmt.quantize(v)).collect();
        self.eval_codes(&codes, ctr)
    }

    /// Total size in bits at `r_o`-bit entries: Σ_i 2^{m_i}·p·r_o.
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.arena.total_entries() as u64 * r_o as u64
    }

    /// Serialize for the `.ltm` artifact. The packed-plane spread table
    /// is derived state and is rebuilt on load. `aligned` selects the
    /// v2 layout (64-byte-aligned entry block).
    pub fn write_wire(&self, out: &mut Vec<u8>, aligned: bool) {
        self.partition.write_wire(out);
        wire::put_u32(out, self.fmt.bits);
        wire::put_u64(out, self.p as u64);
        self.arena.write_wire(out, aligned);
        wire::put_i64_seq(out, &self.bias_acc);
    }

    /// Deserialize a bank written by [`DenseBitplaneLut::write_wire`].
    pub fn read_wire(
        r: &mut wire::Reader,
        ctx: &wire::WireCtx,
    ) -> wire::Result<DenseBitplaneLut> {
        let partition = Partition::read_wire(r)?;
        let bits = r.u32()?;
        if !(1..=16).contains(&bits) {
            return wire::err(format!("bitplane: bad input bits {bits}"));
        }
        let fmt = FixedFormat::new(bits);
        let p = r.len_capped(1 << 24, "bitplane p")?;
        let arena = TableArena::read_wire(r, ctx)?;
        let bias_acc = r.i64_seq(1 << 24, "bitplane bias")?;
        if arena.row_len() != p || arena.num_chunks() != partition.k() || bias_acc.len() != p {
            return wire::err("bitplane: arena/bias shape disagrees with partition");
        }
        // every chunk table must hold exactly 2^m_i rows (plane indexes
        // gather up to row 2^m_i - 1 at eval time)
        for (c, chunk) in partition.chunks.iter().enumerate() {
            if chunk.len() >= 28 || arena.chunk_rows(c) != 1usize << chunk.len() {
                return wire::err(format!("bitplane: chunk {c} row count mismatch"));
            }
        }
        let stride = partition.max_chunk() as u32;
        let spread = spread_table(fmt.bits, stride);
        Ok(DenseBitplaneLut { partition, fmt, p, arena, bias_acc, spread, stride })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::from_acc;
    use crate::util::Rng;

    fn ref_affine(w: &[f32], b: &[f32], p: usize, q: usize, x: &[f32]) -> Vec<f32> {
        (0..p)
            .map(|o| b[o] + (0..q).map(|i| w[o * q + i] * x[i]).sum::<f32>())
            .collect()
    }

    fn random_case(p: usize, q: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..p * q).map(|_| rng.normal() * 0.5).collect(),
            (0..p).map(|_| rng.normal() * 0.1).collect(),
            (0..q).map(|_| rng.f32()).collect(),
        )
    }

    #[test]
    fn matches_reference_on_quantized_input() {
        let (p, q) = (6, 16);
        let (w, b, x) = random_case(p, q, 3);
        let fmt = FixedFormat::new(5);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 4), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            assert!(
                (from_acc(a, 0) - want[o]).abs() < 1e-4,
                "{} vs {}",
                from_acc(a, 0),
                want[o]
            );
        }
    }

    #[test]
    fn agrees_with_whole_code_lut() {
        use crate::lut::dense::DenseWholeLut;
        let (p, q) = (4, 8);
        let (w, b, x) = random_case(p, q, 9);
        let fmt = FixedFormat::new(3);
        let whole =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        let plane =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt)
                .unwrap();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        let a1 = whole.eval_f32(&x, &mut c1);
        let a2 = plane.eval_f32(&x, &mut c2);
        for (x1, x2) in a1.iter().zip(&a2) {
            assert!((from_acc(*x1, 0) - from_acc(*x2, 0)).abs() < 1e-5);
        }
        // bitplane does n× the lookups of whole-code
        assert_eq!(c2.lut_evals, c1.lut_evals * fmt.bits as u64);
    }

    #[test]
    fn lookup_count_is_n_times_k() {
        let (p, q) = (3, 12);
        let (w, b, x) = random_case(p, q, 1);
        let fmt = FixedFormat::new(4);
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 3), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let _ = lut.eval_f32(&x, &mut ctr);
        assert_eq!(ctr.lut_evals, (4 * 4) as u64); // n=4 planes, k=4 chunks
        assert_eq!(ctr.mults, 0);
    }

    #[test]
    fn packed_and_general_paths_agree() {
        // n=9 disables the packed path (n > 8); n=3 enables it. The two
        // implementations must agree bit-exactly on the same weights.
        let (p, q) = (4, 12);
        let (w, b, _) = random_case(p, q, 57);
        let mut rng = Rng::new(58);
        for m in [2, 3, 4, 6] {
            let packed = DenseBitplaneLut::build(
                &w, &b, p, q, Partition::contiguous(q, m), FixedFormat::new(3),
            )
            .unwrap();
            assert!(packed.spread.is_some(), "m={m} should take the packed path");
            let general = DenseBitplaneLut::build(
                &w, &b, p, q, Partition::contiguous(q, m), FixedFormat::new(9),
            )
            .unwrap();
            assert!(general.spread.is_none(), "n=9 must use the general path");
            // cross-check: evaluate the packed bank on random codes and
            // compare against a hand-rolled plane gather
            let codes: Vec<u32> = (0..q).map(|_| rng.below(8) as u32).collect();
            let mut ctr = Counters::default();
            let acc = packed.eval_codes(&codes, &mut ctr);
            let mut want = packed.bias_acc.clone();
            for (c, chunk) in packed.partition.chunks.iter().enumerate() {
                for j in 0..3u32 {
                    let mut idx = 0usize;
                    for (e, &col) in chunk.iter().enumerate() {
                        idx |= (((codes[col] >> j) & 1) as usize) << e;
                    }
                    let base: usize =
                        (0..c).map(|cc| packed.arena.chunk_entries(cc)).sum();
                    for o in 0..p {
                        want[o] += packed.arena.entry(base + idx * p + o) << j;
                    }
                }
            }
            assert_eq!(acc, want, "m={m}");
        }
    }

    #[test]
    fn eval_batch_bit_exact_with_per_sample() {
        let (p, q) = (5, 14);
        let (w, b, _) = random_case(p, q, 61);
        let mut rng = Rng::new(62);
        for (m, bits) in [(1, 3), (3, 3), (7, 4), (14, 3), (4, 9)] {
            let fmt = FixedFormat::new(bits);
            let lut =
                DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            let batch = 6;
            let codes: Vec<u32> = (0..batch * q)
                .map(|_| rng.below(fmt.levels() as usize) as u32)
                .collect();
            let mut out = vec![0i64; batch * p];
            let mut cb = vec![Counters::default(); batch];
            lut.eval_batch(&codes, batch, &mut out, &mut cb);
            for s in 0..batch {
                let mut cs = Counters::default();
                let single = lut.eval_codes(&codes[s * q..(s + 1) * q], &mut cs);
                assert_eq!(
                    &out[s * p..(s + 1) * p],
                    single.as_slice(),
                    "m={m} bits={bits} sample {s}"
                );
                assert_eq!(cb[s], cs, "m={m} bits={bits}: sample {s} counters diverge");
                cb[s].assert_multiplier_less();
            }
        }
    }

    #[test]
    fn forced_kernels_agree_bit_exactly() {
        use crate::lut::kernel;
        let (p, q) = (5, 14);
        let (w, b, _) = random_case(p, q, 71);
        let mut rng = Rng::new(72);
        // singleton, packed, packed paper-config, and general paths;
        // batches chosen to hit full 4-lane steps and ragged tails
        for (m, bits) in [(1, 3), (3, 3), (14, 3), (4, 9)] {
            let fmt = FixedFormat::new(bits);
            let lut =
                DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            for batch in [1usize, 5, 8] {
                let codes: Vec<u32> = (0..batch * q)
                    .map(|_| rng.below(fmt.levels() as usize) as u32)
                    .collect();
                let run = |k: kernel::Kernel| {
                    let _g = kernel::force(k);
                    let mut out = vec![0i64; batch * p];
                    let mut cb = vec![Counters::default(); batch];
                    lut.eval_batch(&codes, batch, &mut out, &mut cb);
                    (out, cb)
                };
                let (o_s, c_s) = run(kernel::Kernel::Scalar);
                let (o_v, c_v) = run(kernel::Kernel::Avx2);
                assert_eq!(o_s, o_v, "m={m} bits={bits} batch={batch}");
                assert_eq!(c_s, c_v, "m={m} bits={bits} batch={batch}");
            }
        }
    }

    #[test]
    fn wire_roundtrip_rebuilds_packed_path() {
        let (p, q) = (5, 14);
        let (w, b, _) = random_case(p, q, 63);
        for (m, bits) in [(14, 3), (4, 9)] {
            let fmt = FixedFormat::new(bits);
            let lut =
                DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            let mut buf = Vec::new();
            lut.write_wire(&mut buf, false);
            let back = DenseBitplaneLut::read_wire(
                &mut crate::lut::wire::Reader::new(&buf),
                &crate::lut::wire::WireCtx::v1(),
            )
            .unwrap();
            assert_eq!(back.spread.is_some(), lut.spread.is_some(), "m={m} bits={bits}");
            assert_eq!(back.stride, lut.stride);
            assert_eq!(back.bias_acc, lut.bias_acc);
            let mut rng = Rng::new(64);
            let codes: Vec<u32> =
                (0..q).map(|_| rng.below(fmt.levels() as usize) as u32).collect();
            let mut c1 = Counters::default();
            let mut c2 = Counters::default();
            assert_eq!(
                lut.eval_codes(&codes, &mut c1),
                back.eval_codes(&codes, &mut c2)
            );
            assert_eq!(c1, c2);
        }
    }

    #[test]
    fn size_is_exponential_in_m_not_in_n() {
        let (p, q) = (10, 8);
        let w = vec![0.0f32; p * q];
        let b = vec![0.0f32; p];
        let s3 = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(3),
        )
        .unwrap()
        .size_bits(16);
        let s8 = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(8),
        )
        .unwrap()
        .size_bits(16);
        // bitplane table size is independent of input precision n
        assert_eq!(s3, s8);
        assert_eq!(s3, 4 * 4 * 10 * 16); // k=4, 2^2 rows, p=10, 16-bit
    }

    #[test]
    fn zero_input_gives_bias() {
        let (p, q) = (3, 6);
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal()).collect();
        let b = vec![1.0f32, -2.0, 0.5];
        let lut = DenseBitplaneLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(4),
        )
        .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&vec![0.0; q], &mut ctr);
        for (o, &a) in acc.iter().enumerate() {
            assert!((from_acc(a, 0) - b[o]).abs() < 1e-6);
        }
    }
}
