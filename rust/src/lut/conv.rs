//! Convolutional LUTs (paper §Convolutional layers using LUT, Fig. 2).
//!
//! The convolution's weight matrix is (block-)circulant, so one table is
//! shared by *every* spatial block — the table is indexed by the block's
//! pixel bits and returns the block's dilated output patch (an
//! `(m+2r) x (m+2r)` support for an `m x m` block under a
//! `(2r+1) x (2r+1)` filter). Spatial shift-invariance plays the same
//! role the binary shift plays for bitplanes, and we exploit both: the
//! same table serves all blocks *and* all bitplanes.
//!
//! Tables are per input channel (different channels have different
//! filter taps, so they cannot share), which is exactly how the paper's
//! conv2 cost scales. Storage is one contiguous [`TableArena`] (one
//! "chunk" per input channel); [`ConvLut::eval_batch`] is
//! channel-outer / sample-inner so each channel's table is streamed
//! once per batch, with the padded accumulator image provided by the
//! caller's scratch (zero per-call allocations).

use super::arena::{with_arena, ArenaEntry, TableArena};
use super::{to_acc, wire, LutError, Partition, MAX_TABLE_BYTES};
use crate::engine::counters::Counters;
use crate::quant::FixedFormat;

/// LUT bank for one 'same'-padded conv layer.
#[derive(Debug)]
pub struct ConvLut {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    /// Filter half-width r (filter is (2r+1) x (2r+1)).
    pub r: usize,
    /// Spatial block edge m.
    pub m: usize,
    pub fmt: FixedFormat,
    /// arena chunk ci, row idx, entry (py*pe + px)*cout + o — one chunk
    /// per input channel, shared across blocks and bitplanes. Entries at
    /// LSB-plane accumulator scale.
    arena: TableArena,
    /// patch edge = m + 2r
    pe: usize,
    bias_acc: Vec<i64>,
}

impl ConvLut {
    /// Build from an NHWC filter `[2r+1, 2r+1, cin, cout]` + bias.
    pub fn build(
        filter: &[f32],
        bias: &[f32],
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        r: usize,
        m: usize,
        fmt: FixedFormat,
    ) -> Result<Self, LutError> {
        let fs = 2 * r + 1;
        assert_eq!(filter.len(), fs * fs * cin * cout);
        assert_eq!(bias.len(), cout);
        if h % m != 0 || w % m != 0 {
            return Err(LutError::BadPartition(format!(
                "block {m} does not tile {h}x{w}"
            )));
        }
        let a = m * m;
        if a >= 24 {
            return Err(LutError::TooLarge { rows: 1u128 << a, cols: cout });
        }
        let rows = 1usize << a;
        let pe = m + 2 * r;
        let patch = pe * pe * cout;
        // checked: rows * patch * 8 can wrap usize on huge configs
        match rows.checked_mul(patch).and_then(|e| e.checked_mul(8)) {
            Some(bytes) if bytes <= MAX_TABLE_BYTES => {}
            _ => return Err(LutError::TooLarge { rows: rows as u128, cols: patch }),
        }
        let lsb = (-(fmt.bits as f64)).exp2();
        let mut tables = Vec::with_capacity(cin);
        for ci in 0..cin {
            let mut table = vec![0i64; rows * patch];
            for idx in 0..rows {
                let prow = &mut table[idx * patch..(idx + 1) * patch];
                for bit in 0..a {
                    if (idx >> bit) & 1 == 0 {
                        continue;
                    }
                    let (dy, dx) = (bit / m, bit % m);
                    for ky in 0..fs {
                        let py = dy + 2 * r - ky;
                        for kx in 0..fs {
                            let px = dx + 2 * r - kx;
                            let base = (py * pe + px) * cout;
                            let fbase = (ky * fs + kx) * cin * cout + ci * cout;
                            for o in 0..cout {
                                prow[base + o] +=
                                    to_acc(filter[fbase + o] as f64 * lsb);
                            }
                        }
                    }
                }
            }
            tables.push(table);
        }
        let bias_acc = bias.iter().map(|&v| to_acc(v as f64)).collect();
        let arena = TableArena::from_tables(&tables, patch);
        Ok(ConvLut { h, w, cin, cout, r, m, fmt, arena, pe, bias_acc })
    }

    /// The arena (diagnostics: width, residency).
    pub fn arena(&self) -> &TableArena {
        &self.arena
    }

    /// Evaluate the convolution over a quantized NHWC input
    /// `[h, w, cin]` given as codes. Returns accumulator image
    /// `[h, w, cout]`. Pure gathers, shifts and adds.
    pub fn eval_codes(&self, codes: &[u32], ctr: &mut Counters) -> Vec<i64> {
        let mut out = vec![0i64; self.h * self.w * self.cout];
        let mut pad = Vec::new();
        self.eval_batch(codes, 1, &mut out, &mut pad, std::slice::from_mut(ctr));
        out
    }

    /// Batched evaluation: `codes` row-major `batch x (h·w·cin)`, `out`
    /// `batch x (h·w·cout)` (overwritten), `ctrs` one counter row per
    /// sample. `pad` is caller-provided scratch for the padded
    /// accumulator images (resized as needed and reused across calls —
    /// zero steady-state allocations). Loop order is channel-outer /
    /// sample-inner so each channel's shared table is streamed once per
    /// batch.
    pub fn eval_batch(
        &self,
        codes: &[u32],
        batch: usize,
        out: &mut [i64],
        pad: &mut Vec<i64>,
        ctrs: &mut [Counters],
    ) {
        let (h, w, r) = (self.h, self.w, self.r);
        assert_eq!(codes.len(), batch * h * w * self.cin);
        assert_eq!(out.len(), batch * h * w * self.cout);
        assert_eq!(ctrs.len(), batch);
        let (ph, pw) = (h + 2 * r, w + 2 * r);
        let pimg = ph * pw * self.cout;
        pad.clear();
        pad.resize(batch * pimg, 0);
        with_arena!(self.arena, E => self.eval_batch_impl::<E>(codes, batch, pad, ctrs));
        super::crop_add_bias(pad, out, batch, h, w, r, self.cout, &self.bias_acc);
        let blocks = (h / self.m) * (w / self.m);
        for ctr in ctrs.iter_mut() {
            ctr.lut_evals += (blocks * self.fmt.bits as usize * self.cin) as u64;
            ctr.adds += (h * w * self.cout) as u64;
        }
    }

    /// Dispatches between the scalar reference loops and the AVX2 lane
    /// kernel (see [`crate::lut::kernel`]); both perform the identical
    /// per-sample multiset of shifted patch-row adds, so outputs and
    /// counters are bit-identical.
    fn eval_batch_impl<E: super::kernel::LaneRow>(
        &self,
        codes: &[u32],
        batch: usize,
        pad: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if crate::lut::kernel::active() == crate::lut::kernel::Kernel::Avx2 {
                // SAFETY: active() returns Avx2 only on CPUs with AVX2.
                unsafe { self.eval_batch_avx2::<E>(codes, batch, pad, ctrs) };
                return;
            }
        }
        self.eval_batch_scalar::<E>(codes, batch, pad, ctrs);
    }

    fn eval_batch_scalar<E: ArenaEntry>(
        &self,
        codes: &[u32],
        batch: usize,
        pad: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let (h, w, r, m, pe) = (self.h, self.w, self.r, self.m, self.pe);
        let n = self.fmt.bits;
        let (ph, pw) = (h + 2 * r, w + 2 * r);
        let pimg = ph * pw * self.cout;
        let simg = h * w * self.cin;
        let patch = pe * pe * self.cout;
        for ci in 0..self.cin {
            let table = self.arena.chunk_slice::<E>(ci);
            for s in 0..batch {
                let scodes = &codes[s * simg..(s + 1) * simg];
                let spad = &mut pad[s * pimg..(s + 1) * pimg];
                for by in 0..h / m {
                    for bx in 0..w / m {
                        for j in 0..n {
                            // gather plane-j bits of the block, channel ci
                            let mut idx = 0usize;
                            for dy in 0..m {
                                for dx in 0..m {
                                    let pix = ((by * m + dy) * w + (bx * m + dx))
                                        * self.cin
                                        + ci;
                                    idx |= (((scodes[pix] >> j) & 1) as usize)
                                        << (dy * m + dx);
                                }
                            }
                            if idx == 0 {
                                // zero row: skipped gather, lookup still
                                // charged (per sample, in eval_batch)
                                continue;
                            }
                            let prow = &table[idx * patch..(idx + 1) * patch];
                            // patch origin in padded coords = block origin
                            let oy0 = by * m;
                            let ox0 = bx * m;
                            for py in 0..pe {
                                let dst = ((oy0 + py) * pw + ox0) * self.cout;
                                let src = py * pe * self.cout;
                                let drow = &mut spad[dst..dst + pe * self.cout];
                                let srow = &prow[src..src + pe * self.cout];
                                for (d, t) in drow.iter_mut().zip(srow) {
                                    *d += t.widen() << j;
                                }
                            }
                            ctrs[s].shift_adds += (pe * pe * self.cout) as u64;
                        }
                    }
                }
            }
        }
    }

    /// AVX2 twin of [`Self::eval_batch_scalar`]: the block-bit index
    /// build is unchanged (m² single-bit deposits), but each of the pe
    /// patch-row accumulations (`pe·cout` entries wide) runs 4×i64
    /// lanes per step. Same per-sample adds as the scalar path.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_batch_avx2<E: super::kernel::LaneRow>(
        &self,
        codes: &[u32],
        batch: usize,
        pad: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let (h, w, r, m, pe) = (self.h, self.w, self.r, self.m, self.pe);
        let n = self.fmt.bits;
        let (ph, pw) = (h + 2 * r, w + 2 * r);
        let pimg = ph * pw * self.cout;
        let simg = h * w * self.cin;
        let patch = pe * pe * self.cout;
        for ci in 0..self.cin {
            let table = self.arena.chunk_table::<E>(ci);
            for s in 0..batch {
                let scodes = &codes[s * simg..(s + 1) * simg];
                let spad = &mut pad[s * pimg..(s + 1) * pimg];
                for by in 0..h / m {
                    for bx in 0..w / m {
                        for j in 0..n {
                            let mut idx = 0usize;
                            for dy in 0..m {
                                for dx in 0..m {
                                    let pix = ((by * m + dy) * w + (bx * m + dx))
                                        * self.cin
                                        + ci;
                                    idx |= (((scodes[pix] >> j) & 1) as usize)
                                        << (dy * m + dx);
                                }
                            }
                            if idx == 0 {
                                continue;
                            }
                            let prow = table.row(idx);
                            let oy0 = by * m;
                            let ox0 = bx * m;
                            for py in 0..pe {
                                let dst = ((oy0 + py) * pw + ox0) * self.cout;
                                let src = py * pe * self.cout;
                                E::shift_add_row_avx2(
                                    &mut spad[dst..dst + pe * self.cout],
                                    &prow[src..src + pe * self.cout],
                                    j,
                                );
                            }
                            ctrs[s].shift_adds += patch as u64;
                        }
                    }
                }
            }
        }
    }

    /// Quantize f32 NHWC input (values in [0,1]) then evaluate.
    pub fn eval_f32(&self, x: &[f32], ctr: &mut Counters) -> Vec<i64> {
        let codes: Vec<u32> = x.iter().map(|&v| self.fmt.quantize(v)).collect();
        self.eval_codes(&codes, ctr)
    }

    /// The spatial partition this bank implies (for planner cross-checks).
    pub fn partition(&self) -> Partition {
        Partition::square_blocks(self.h, self.w, self.m)
    }

    /// Materialised size in bits at r_o-bit entries:
    /// cin tables × 2^(m²) rows × (m+2r)²·cout entries.
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.arena.total_entries() as u64 * r_o as u64
    }

    /// Serialize for the `.ltm` artifact. `aligned` selects the v2
    /// layout (64-byte-aligned entry block).
    pub fn write_wire(&self, out: &mut Vec<u8>, aligned: bool) {
        for v in [self.h, self.w, self.cin, self.cout, self.r, self.m] {
            wire::put_u64(out, v as u64);
        }
        wire::put_u32(out, self.fmt.bits);
        self.arena.write_wire(out, aligned);
        wire::put_i64_seq(out, &self.bias_acc);
    }

    /// Deserialize a bank written by [`ConvLut::write_wire`].
    pub fn read_wire(r: &mut wire::Reader, ctx: &wire::WireCtx) -> wire::Result<ConvLut> {
        const DIM_CAP: usize = 1 << 20;
        let h = r.len_capped(DIM_CAP, "conv h")?;
        let w = r.len_capped(DIM_CAP, "conv w")?;
        let cin = r.len_capped(DIM_CAP, "conv cin")?;
        let cout = r.len_capped(DIM_CAP, "conv cout")?;
        let rr = r.len_capped(DIM_CAP, "conv r")?;
        let m = r.len_capped(DIM_CAP, "conv m")?;
        let bits = r.u32()?;
        if !(1..=16).contains(&bits) {
            return wire::err(format!("conv: bad input bits {bits}"));
        }
        if m == 0 || h == 0 || w == 0 || h % m != 0 || w % m != 0 {
            return wire::err("conv: block does not tile the image");
        }
        let fmt = FixedFormat::new(bits);
        let arena = TableArena::read_wire(r, ctx)?;
        let bias_acc = r.i64_seq(DIM_CAP, "conv bias")?;
        let pe = m + 2 * rr;
        if arena.num_chunks() != cin
            || arena.row_len() != pe * pe * cout
            || bias_acc.len() != cout
        {
            return wire::err("conv: arena/bias shape disagrees with geometry");
        }
        // every channel table must hold exactly 2^(m²) rows
        let a = m * m;
        if a >= 24 || (0..cin).any(|c| arena.chunk_rows(c) != 1usize << a) {
            return wire::err("conv: channel table row count mismatch");
        }
        Ok(ConvLut { h, w, cin, cout, r: rr, m, fmt, arena, pe, bias_acc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::from_acc;
    use crate::tensor::{conv::conv2d_same, Tensor};
    use crate::util::Rng;

    /// Run the reference conv on the quantized input and compare.
    fn check_against_reference(
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        r: usize,
        m: usize,
        bits: u32,
        seed: u64,
    ) {
        let fs = 2 * r + 1;
        let mut rng = Rng::new(seed);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.05).collect();
        let x: Vec<f32> = (0..h * w * cin).map(|_| rng.f32()).collect();
        let fmt = FixedFormat::new(bits);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();

        let lut = ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        assert_eq!(ctr.mults, 0, "conv LUT path must be multiplier-less");

        let want = conv2d_same(
            &Tensor::new(&[1, h, w, cin], xq),
            &Tensor::new(&[fs, fs, cin, cout], filter),
            &Tensor::new(&[cout], bias),
        );
        for (i, &a) in acc.iter().enumerate() {
            let g = from_acc(a, 0);
            let e = want.data()[i];
            assert!((g - e).abs() < 1e-3, "i={i}: {g} vs {e}");
        }
    }

    #[test]
    fn single_channel_3x3_filter() {
        check_against_reference(6, 6, 1, 2, 1, 2, 3, 1);
    }

    #[test]
    fn multi_channel_input() {
        check_against_reference(4, 4, 3, 2, 1, 2, 3, 2);
    }

    #[test]
    fn five_by_five_filter_like_lenet() {
        check_against_reference(8, 8, 1, 4, 2, 2, 4, 3);
    }

    #[test]
    fn block_size_does_not_change_result() {
        let (h, w, cin, cout, r) = (4, 4, 1, 2, 1);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(4);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..h * w * cin).map(|_| rng.f32()).collect();
        let fmt = FixedFormat::new(3);
        let mut outs = Vec::new();
        for m in [1, 2, 4] {
            let lut =
                ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
            let mut ctr = Counters::default();
            outs.push(
                lut.eval_f32(&x, &mut ctr)
                    .iter()
                    .map(|&a| from_acc(a, 0))
                    .collect::<Vec<f32>>(),
            );
        }
        for o in &outs[1..] {
            for (a, b) in o.iter().zip(&outs[0]) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn eval_batch_bit_exact_with_per_sample() {
        let (h, w, cin, cout, r, m, bits) = (4, 4, 2, 3, 1, 2, 3);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(91);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(bits);
        let lut = ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
        let batch = 3;
        let simg = h * w * cin;
        let codes: Vec<u32> =
            (0..batch * simg).map(|_| rng.below(1 << bits) as u32).collect();
        let mut out = vec![0i64; batch * h * w * cout];
        let mut pad = Vec::new();
        let mut cb = vec![Counters::default(); batch];
        lut.eval_batch(&codes, batch, &mut out, &mut pad, &mut cb);
        let oimg = h * w * cout;
        for s in 0..batch {
            let mut cs = Counters::default();
            let single = lut.eval_codes(&codes[s * simg..(s + 1) * simg], &mut cs);
            assert_eq!(&out[s * oimg..(s + 1) * oimg], single.as_slice(), "sample {s}");
            assert_eq!(cb[s], cs, "per-sample counter attribution at sample {s}");
            cb[s].assert_multiplier_less();
        }
    }

    #[test]
    fn forced_kernels_agree_bit_exactly() {
        use crate::lut::kernel;
        let (h, w, cin, cout, r, m, bits) = (4, 4, 2, 3, 1, 2, 3);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(93);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(bits);
        let lut = ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
        let simg = h * w * cin;
        for batch in [1usize, 3] {
            let codes: Vec<u32> =
                (0..batch * simg).map(|_| rng.below(1 << bits) as u32).collect();
            let run = |k: kernel::Kernel| {
                let _g = kernel::force(k);
                let mut out = vec![0i64; batch * h * w * cout];
                let mut pad = Vec::new();
                let mut cb = vec![Counters::default(); batch];
                lut.eval_batch(&codes, batch, &mut out, &mut pad, &mut cb);
                (out, cb)
            };
            let (o_s, c_s) = run(kernel::Kernel::Scalar);
            let (o_v, c_v) = run(kernel::Kernel::Avx2);
            assert_eq!(o_s, o_v, "batch={batch}");
            assert_eq!(c_s, c_v, "batch={batch}");
        }
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let (h, w, cin, cout, r, m, bits) = (4, 4, 2, 3, 1, 2, 3);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(95);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(bits);
        let lut = ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
        let mut buf = Vec::new();
        lut.write_wire(&mut buf, false);
        let back = ConvLut::read_wire(
            &mut crate::lut::wire::Reader::new(&buf),
            &crate::lut::wire::WireCtx::v1(),
        )
        .unwrap();
        let codes: Vec<u32> =
            (0..h * w * cin).map(|_| rng.below(1 << bits) as u32).collect();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        assert_eq!(lut.eval_codes(&codes, &mut c1), back.eval_codes(&codes, &mut c2));
        assert_eq!(c1, c2);
    }

    #[test]
    fn lookup_count_formula() {
        // blocks * planes * cin lookups
        let (h, w, cin, cout, r, m, bits) = (8, 8, 2, 3, 1, 2, 4);
        let fs = 2 * r + 1;
        let filter = vec![0.1f32; fs * fs * cin * cout];
        let bias = vec![0.0f32; cout];
        let fmt = FixedFormat::new(bits);
        let lut = ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, fmt).unwrap();
        let mut ctr = Counters::default();
        let x = vec![0.7f32; h * w * cin];
        let _ = lut.eval_f32(&x, &mut ctr);
        let blocks = (h / m) * (w / m);
        assert_eq!(ctr.lut_evals, (blocks * bits as usize * cin) as u64);
    }

    #[test]
    fn size_formula_matches_paper_patch_geometry() {
        // a = m², c = (m+2r)² — paper's example geometry
        let (h, w, cin, cout, r, m) = (8, 8, 1, 1, 2, 2);
        let filter = vec![0.0f32; 25];
        let bias = vec![0.0f32];
        let lut =
            ConvLut::build(&filter, &bias, h, w, cin, cout, r, m, FixedFormat::new(3))
                .unwrap();
        // 2^(2*2) rows * (2+4)^2 patch * 16 bits
        assert_eq!(lut.size_bits(16), 16 * 36 * 16);
    }

    #[test]
    fn rejects_non_tiling_block() {
        let filter = vec![0.0f32; 9];
        let bias = vec![0.0f32];
        let err = ConvLut::build(&filter, &bias, 5, 5, 1, 1, 1, 2, FixedFormat::new(2))
            .unwrap_err();
        assert!(matches!(err, LutError::BadPartition(_)));
    }
}
