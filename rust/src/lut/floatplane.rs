//! Binary16 floating-point LUT bank (paper §Floating point formats,
//! Fig. 1): the mantissa is split into bitplanes (the same LUT serves
//! all 11 planes) while the *entire 5-bit exponent* is part of every
//! index. A chunk of `m` elements therefore indexes `m·(1+t)` bits, and
//! the table holds `Σ_s w[o,s] · bit_s · 2^(e_s - bias - frac_bits)` —
//! the shift structure of the float format is baked into the table.
//!
//! Inputs are assumed nonnegative (post-ReLU), matching the paper's
//! "the sign bit is always 0 ... reduce the LUT size by half".
//!
//! Tables live in a contiguous [`TableArena`]; entries at `FACC = 44`
//! scale exceed i32, so this bank is the arena's designed i64 fallback.
//! [`DenseFloatLut::eval_batch_f16`] runs chunk-outer / sample-inner.

use super::arena::{with_arena, ArenaEntry, TableArena};
use super::{wire, LutError, Partition, MAX_TABLE_BYTES};
use crate::engine::counters::Counters;
use crate::quant::f16::{F16, EXP_BIAS, FRAC_BITS, SIG_BITS};


/// Scale for float-path accumulators: entries are value * 2^FACC at the
/// LSB mantissa plane; plane j contributes entry << j.
pub const FACC: i32 = 44;

/// Number of exponent bits indexed per element (t in the paper).
pub const EXP_BITS: u32 = 5;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FloatLutConfig {
    /// Mantissa bitplanes evaluated (≤ 11). The paper uses all 11; fewer
    /// planes trade accuracy for ops (an ablation axis).
    pub planes: u32,
}

impl Default for FloatLutConfig {
    fn default() -> Self {
        FloatLutConfig { planes: SIG_BITS }
    }
}

/// One table per chunk: rows = 2^(m·(1+5)), cols = p.
#[derive(Debug)]
pub struct DenseFloatLut {
    pub partition: Partition,
    pub p: usize,
    pub cfg: FloatLutConfig,
    arena: TableArena,
    bias_acc: Vec<i64>,
}

impl DenseFloatLut {
    pub fn build(
        w: &[f32],
        b: &[f32],
        p: usize,
        q: usize,
        partition: Partition,
        cfg: FloatLutConfig,
    ) -> Result<Self, LutError> {
        assert_eq!(w.len(), p * q);
        assert_eq!(b.len(), p);
        partition.validate()?;
        assert_eq!(partition.q, q);
        // loud failure, never a silent clamp: an out-of-range plane
        // count (possible via plan JSON) would otherwise compile to a
        // model that cannot round-trip through the `.ltm` loader
        if cfg.planes == 0 || cfg.planes > SIG_BITS {
            return Err(LutError::BadConfig(format!(
                "float planes {} outside 1..={SIG_BITS}",
                cfg.planes
            )));
        }
        let per_elem_bits = 1 + EXP_BITS; // 1 mantissa bit + whole exponent
        let mut tables = Vec::with_capacity(partition.k());
        for chunk in &partition.chunks {
            let m = chunk.len() as u32;
            let idx_bits = m * per_elem_bits;
            if idx_bits >= 26 {
                return Err(LutError::TooLarge { rows: 1u128 << idx_bits, cols: p });
            }
            let rows = 1usize << idx_bits;
            // checked: rows * p * 8 can wrap usize on huge configs
            match rows.checked_mul(p).and_then(|e| e.checked_mul(8)) {
                Some(bytes) if bytes <= MAX_TABLE_BYTES => {}
                _ => return Err(LutError::TooLarge { rows: rows as u128, cols: p }),
            }
            let mut table = vec![0i64; rows * p];
            for idx in 0..rows {
                let row = &mut table[idx * p..(idx + 1) * p];
                for (e, &col) in chunk.iter().enumerate() {
                    let field = (idx >> (e as u32 * per_elem_bits)) as u32
                        & ((1 << per_elem_bits) - 1);
                    let bit = field & 1;
                    if bit == 0 {
                        continue;
                    }
                    let exp_raw = (field >> 1) & 0x1F;
                    // normals: 2^(e-15-10); subnormals (e=0): 2^(1-15-10)
                    let scale_exp =
                        exp_raw.max(1) as i32 - EXP_BIAS - FRAC_BITS as i32;
                    let scale = ((scale_exp + FACC) as f64).exp2();
                    for (o, r) in row.iter_mut().enumerate() {
                        *r += (w[o * q + col] as f64 * scale).round() as i64;
                    }
                }
            }
            tables.push(table);
        }
        let bias_acc = b
            .iter()
            .map(|&v| (v as f64 * (FACC as f64).exp2()).round() as i64)
            .collect();
        let arena = TableArena::from_tables(&tables, p);
        Ok(DenseFloatLut { partition, p, cfg, arena, bias_acc })
    }

    /// The arena (diagnostics: width, residency).
    pub fn arena(&self) -> &TableArena {
        &self.arena
    }

    /// Evaluate `Wx + b` from binary16 inputs. For each chunk and each
    /// mantissa plane j, the index interleaves (per element) the plane's
    /// significand bit with the full 5-bit exponent; the table output is
    /// shifted left by j and accumulated. The same table serves all
    /// planes — the paper's Fig. 1.
    pub fn eval_f16(&self, x: &[F16], ctr: &mut Counters) -> Vec<i64> {
        let mut acc = vec![0i64; self.p];
        self.eval_batch_f16(x, 1, &mut acc, std::slice::from_mut(ctr));
        acc
    }

    /// Batched evaluation: `x` row-major `batch x q`, `out` `batch x p`
    /// (overwritten), `ctrs` one counter row per sample. Chunk-outer /
    /// sample-inner; data-dependent shift-adds land on the exact sample
    /// that incurred them.
    pub fn eval_batch_f16(
        &self,
        x: &[F16],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let q = self.partition.q;
        let p = self.p;
        assert_eq!(x.len(), batch * q);
        assert_eq!(out.len(), batch * p);
        assert_eq!(ctrs.len(), batch);
        for s in 0..batch {
            out[s * p..(s + 1) * p].copy_from_slice(&self.bias_acc);
        }
        let planes = self.cfg.planes.min(SIG_BITS);
        with_arena!(self.arena, E => self.eval_batch_impl::<E>(x, batch, out, ctrs));
        let k = self.partition.k() as u64;
        for ctr in ctrs.iter_mut() {
            ctr.adds += p as u64; // bias adds
            ctr.lut_evals += planes as u64 * k;
        }
    }

    /// Dispatches between the scalar reference loops and the AVX2 lane
    /// kernel (see [`crate::lut::kernel`]); both perform the identical
    /// per-sample multiset of shifted row adds, so outputs and counters
    /// are bit-identical.
    fn eval_batch_impl<E: super::kernel::LaneRow>(
        &self,
        x: &[F16],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if crate::lut::kernel::active() == crate::lut::kernel::Kernel::Avx2 {
                // SAFETY: active() returns Avx2 only on CPUs with AVX2.
                unsafe { self.eval_batch_avx2::<E>(x, batch, out, ctrs) };
                return;
            }
        }
        self.eval_batch_scalar::<E>(x, batch, out, ctrs);
    }

    fn eval_batch_scalar<E: ArenaEntry>(
        &self,
        x: &[F16],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let q = self.partition.q;
        let p = self.p;
        let planes = self.cfg.planes.min(SIG_BITS);
        let lo = SIG_BITS - planes;
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = self.arena.chunk_slice::<E>(c);
            // fast path for singleton chunks (the paper's m=1 layout):
            // for a fixed element the exponent is constant across
            // planes, so ONE row — table[(exp<<1)|1] — serves every
            // mantissa plane; iterate the significand's set bits.
            if let [col] = chunk.as_slice() {
                for s in 0..batch {
                    let h = x[s * q + col];
                    debug_assert_eq!(h.sign(), 0, "float LUT path expects ReLU-nonneg input");
                    let mut sig = (h.significand11() >> lo) << lo; // drop truncated planes
                    if sig == 0 {
                        continue;
                    }
                    let row_idx = ((h.exponent() << 1) | 1) as usize;
                    let row = &table[row_idx * p..(row_idx + 1) * p];
                    let acc = &mut out[s * p..(s + 1) * p];
                    while sig != 0 {
                        let j = sig.trailing_zeros();
                        for (a, r) in acc.iter_mut().zip(row) {
                            *a += r.widen() << j;
                        }
                        ctrs[s].shift_adds += p as u64;
                        sig &= sig - 1;
                    }
                }
                continue;
            }
            // packed (mantissa, exponent) path: all m ≤ 4 index fields
            // of a sample ride one u64 pair — `exp_part` holds each
            // element's (exponent << 1) at bit 6e (the per-element
            // index field is 1 + EXP_BITS = 6 bits wide), `sigs` holds
            // its 11-bit significand at bit 16e. Per plane j the index
            // is `exp_part | mant`, where `mant` folds bit j of every
            // significand down to its element's bit 6e (16e → 6e is a
            // right shift by 10e, so three shifted ORs cover e ≤ 3).
            let m = chunk.len();
            debug_assert!(m <= 4); // idx_bits = 6m < 26 by build/read_wire
            let fold_mask: u64 = (0..m).map(|e| 1u64 << (6 * e)).sum();
            for s in 0..batch {
                let srow = &x[s * q..(s + 1) * q];
                let mut exp_part = 0u64;
                let mut sigs = 0u64;
                for (e, &col) in chunk.iter().enumerate() {
                    let h = srow[col];
                    debug_assert_eq!(h.sign(), 0, "float LUT path expects ReLU-nonneg input");
                    exp_part |= ((h.exponent() as u64) << 1) << (6 * e);
                    sigs |= (h.significand11() as u64) << (16 * e);
                }
                let acc = &mut out[s * p..(s + 1) * p];
                // drop the lowest (SIG_BITS - planes) planes if truncating
                for j in lo..SIG_BITS {
                    let y = (sigs >> j) & 0x0001_0001_0001_0001;
                    let mant = (y | (y >> 10) | (y >> 20) | (y >> 30)) & fold_mask;
                    if mant == 0 {
                        // rows whose mantissa bits are ALL zero are
                        // identically zero (the exponent only scales a
                        // set bit) — skip the gather+add entirely; in
                        // hardware this is the row-enable line; the
                        // lookup is still charged (in eval_batch_f16).
                        continue;
                    }
                    let idx = (exp_part | mant) as usize;
                    let row = &table[idx * p..(idx + 1) * p];
                    for (a, r) in acc.iter_mut().zip(row) {
                        *a += r.widen() << j;
                    }
                    ctrs[s].shift_adds += p as u64;
                }
            }
        }
    }

    /// AVX2 twin of [`Self::eval_batch_scalar`]: the index packing is
    /// the same u64 (mantissa, exponent) fold — the F16 fields are too
    /// narrow to gather safely in lanes — but every row accumulation
    /// runs 4×i64 lanes per step. Same per-sample add multiset as the
    /// scalar path, so outputs and counters match bit-for-bit.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_batch_avx2<E: super::kernel::LaneRow>(
        &self,
        x: &[F16],
        batch: usize,
        out: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let q = self.partition.q;
        let p = self.p;
        let planes = self.cfg.planes.min(SIG_BITS);
        let lo = SIG_BITS - planes;
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = self.arena.chunk_table::<E>(c);
            if let [col] = chunk.as_slice() {
                for s in 0..batch {
                    let h = x[s * q + col];
                    debug_assert_eq!(h.sign(), 0, "float LUT path expects ReLU-nonneg input");
                    let mut sig = (h.significand11() >> lo) << lo;
                    if sig == 0 {
                        continue;
                    }
                    let row = table.row(((h.exponent() << 1) | 1) as usize);
                    let acc = &mut out[s * p..(s + 1) * p];
                    while sig != 0 {
                        let j = sig.trailing_zeros();
                        E::shift_add_row_avx2(acc, row, j);
                        ctrs[s].shift_adds += p as u64;
                        sig &= sig - 1;
                    }
                }
                continue;
            }
            let m = chunk.len();
            debug_assert!(m <= 4); // idx_bits = 6m < 26 by build/read_wire
            let fold_mask: u64 = (0..m).map(|e| 1u64 << (6 * e)).sum();
            for s in 0..batch {
                let srow = &x[s * q..(s + 1) * q];
                let mut exp_part = 0u64;
                let mut sigs = 0u64;
                for (e, &col) in chunk.iter().enumerate() {
                    let h = srow[col];
                    debug_assert_eq!(h.sign(), 0, "float LUT path expects ReLU-nonneg input");
                    exp_part |= ((h.exponent() as u64) << 1) << (6 * e);
                    sigs |= (h.significand11() as u64) << (16 * e);
                }
                let acc = &mut out[s * p..(s + 1) * p];
                for j in lo..SIG_BITS {
                    let y = (sigs >> j) & 0x0001_0001_0001_0001;
                    let mant = (y | (y >> 10) | (y >> 20) | (y >> 30)) & fold_mask;
                    if mant == 0 {
                        continue;
                    }
                    E::shift_add_row_avx2(acc, table.row((exp_part | mant) as usize), j);
                    ctrs[s].shift_adds += p as u64;
                }
            }
        }
    }

    /// Convenience: quantize f32 inputs through binary16 then evaluate.
    pub fn eval_f32(&self, x: &[f32], ctr: &mut Counters) -> Vec<i64> {
        let h: Vec<F16> = x.iter().map(|&v| F16::from_f32(v.max(0.0))).collect();
        self.eval_f16(&h, ctr)
    }

    /// Decode an accumulator value to f32.
    pub fn acc_to_f32(a: i64) -> f32 {
        (a as f64 * (-(FACC as f64)).exp2()) as f32
    }

    /// Size in bits at r_o-bit entries: Σ_i 2^(m_i(1+t)) · p · r_o.
    /// With `halve_sign`, exploits the always-zero sign bit (not modeled
    /// in the index here; accounting hook for the paper's halving).
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.arena.total_entries() as u64 * r_o as u64
    }

    /// Serialize for the `.ltm` artifact. `aligned` selects the v2
    /// layout (64-byte-aligned entry block).
    pub fn write_wire(&self, out: &mut Vec<u8>, aligned: bool) {
        self.partition.write_wire(out);
        wire::put_u64(out, self.p as u64);
        wire::put_u32(out, self.cfg.planes);
        self.arena.write_wire(out, aligned);
        wire::put_i64_seq(out, &self.bias_acc);
    }

    /// Deserialize a bank written by [`DenseFloatLut::write_wire`].
    pub fn read_wire(r: &mut wire::Reader, ctx: &wire::WireCtx) -> wire::Result<DenseFloatLut> {
        let partition = Partition::read_wire(r)?;
        let p = r.len_capped(1 << 24, "float dense p")?;
        let planes = r.u32()?;
        if planes == 0 || planes > SIG_BITS {
            return wire::err(format!("float dense: bad plane count {planes}"));
        }
        let arena = TableArena::read_wire(r, ctx)?;
        let bias_acc = r.i64_seq(1 << 24, "float dense bias")?;
        if arena.row_len() != p || arena.num_chunks() != partition.k() || bias_acc.len() != p {
            return wire::err("float dense: arena/bias shape disagrees with partition");
        }
        // every chunk table must hold exactly 2^(m_i·(1+t)) rows
        for (c, chunk) in partition.chunks.iter().enumerate() {
            let idx_bits = chunk.len() as u32 * (1 + EXP_BITS);
            if idx_bits >= 26 || arena.chunk_rows(c) != 1usize << idx_bits {
                return wire::err(format!("float dense: chunk {c} row count mismatch"));
            }
        }
        Ok(DenseFloatLut {
            partition,
            p,
            cfg: FloatLutConfig { planes },
            arena,
            bias_acc,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ref_affine(w: &[f32], b: &[f32], p: usize, q: usize, x: &[f32]) -> Vec<f32> {
        (0..p)
            .map(|o| b[o] + (0..q).map(|i| w[o * q + i] * x[i]).sum::<f32>())
            .collect()
    }

    fn random_case(p: usize, q: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            (0..p * q).map(|_| rng.normal() * 0.5).collect(),
            (0..p).map(|_| rng.normal() * 0.1).collect(),
            // mixed magnitudes to exercise the exponent path
            (0..q).map(|_| rng.f32() * 8.0 + 0.001).collect(),
        )
    }

    #[test]
    fn matches_reference_on_f16_input() {
        let (p, q) = (5, 10);
        let (w, b, x) = random_case(p, q, 21);
        let xq: Vec<f32> = x.iter().map(|&v| F16::fake_quant(v)).collect();
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
        )
        .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            let got = DenseFloatLut::acc_to_f32(a);
            assert!(
                (got - want[o]).abs() < 1e-3 * want[o].abs().max(1.0),
                "{got} vs {}",
                want[o]
            );
        }
    }

    #[test]
    fn handles_subnormals_and_zero() {
        let (p, q) = (2, 3);
        let w = vec![1.0f32, 2.0, 3.0, -1.0, 0.5, 0.25];
        let b = vec![0.0f32, 0.0];
        let x = vec![0.0f32, 3.0e-8, 1.0]; // zero, f16-subnormal, one
        let xq: Vec<f32> = x.iter().map(|&v| F16::fake_quant(v)).collect();
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
        )
        .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            let got = DenseFloatLut::acc_to_f32(a);
            assert!((got - want[o]).abs() < 1e-6, "{got} vs {}", want[o]);
        }
    }

    #[test]
    fn lookups_are_planes_times_chunks() {
        let (p, q) = (3, 6);
        let (w, b, x) = random_case(p, q, 2);
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
        )
        .unwrap();
        let mut ctr = Counters::default();
        let _ = lut.eval_f32(&x, &mut ctr);
        assert_eq!(ctr.lut_evals, (SIG_BITS as u64) * q as u64);
        assert_eq!(ctr.mults, 0);
    }

    #[test]
    fn chunked_float_partition_matches_singletons() {
        let (p, q) = (4, 8);
        let (w, b, x) = random_case(p, q, 13);
        let single = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
        )
        .unwrap();
        let pair = DenseFloatLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FloatLutConfig::default(),
        )
        .unwrap();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        let a1 = single.eval_f32(&x, &mut c1);
        let a2 = pair.eval_f32(&x, &mut c2);
        for (u, v) in a1.iter().zip(&a2) {
            let (fu, fv) = (DenseFloatLut::acc_to_f32(*u), DenseFloatLut::acc_to_f32(*v));
            assert!((fu - fv).abs() < 1e-4 * fu.abs().max(1.0));
        }
        assert_eq!(c2.lut_evals * 2, c1.lut_evals);
    }

    #[test]
    fn eval_batch_bit_exact_with_per_sample() {
        let (p, q) = (4, 9);
        let (w, b, _) = random_case(p, q, 71);
        let mut rng = Rng::new(72);
        for m in [1, 2, 3] {
            let lut = DenseFloatLut::build(
                &w, &b, p, q, Partition::contiguous(q, m), FloatLutConfig::default(),
            )
            .unwrap();
            let batch = 4;
            let x: Vec<F16> = (0..batch * q)
                .map(|_| F16::from_f32(rng.f32() * 6.0))
                .collect();
            let mut out = vec![0i64; batch * p];
            let mut cb = vec![Counters::default(); batch];
            lut.eval_batch_f16(&x, batch, &mut out, &mut cb);
            for s in 0..batch {
                let mut cs = Counters::default();
                let single = lut.eval_f16(&x[s * q..(s + 1) * q], &mut cs);
                assert_eq!(&out[s * p..(s + 1) * p], single.as_slice(), "m={m} s={s}");
                assert_eq!(cb[s], cs, "m={m}: sample {s} counters diverge");
                cb[s].assert_multiplier_less();
            }
        }
    }

    #[test]
    fn forced_kernels_agree_bit_exactly() {
        use crate::lut::kernel;
        let (p, q) = (4, 9);
        let (w, b, _) = random_case(p, q, 81);
        let mut rng = Rng::new(82);
        // m=1 singleton fast path, m=2/3 packed-fold path; truncated
        // planes exercise the lo-plane drop; batches hit ragged tails
        for (m, planes) in [(1, 11), (2, 11), (3, 7)] {
            let lut = DenseFloatLut::build(
                &w, &b, p, q, Partition::contiguous(q, m), FloatLutConfig { planes },
            )
            .unwrap();
            for batch in [1usize, 6] {
                let x: Vec<F16> = (0..batch * q)
                    .map(|_| F16::from_f32(rng.f32() * 6.0))
                    .collect();
                let run = |k: kernel::Kernel| {
                    let _g = kernel::force(k);
                    let mut out = vec![0i64; batch * p];
                    let mut cb = vec![Counters::default(); batch];
                    lut.eval_batch_f16(&x, batch, &mut out, &mut cb);
                    (out, cb)
                };
                let (o_s, c_s) = run(kernel::Kernel::Scalar);
                let (o_v, c_v) = run(kernel::Kernel::Avx2);
                assert_eq!(o_s, o_v, "m={m} planes={planes} batch={batch}");
                assert_eq!(c_s, c_v, "m={m} planes={planes} batch={batch}");
            }
        }
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let (p, q) = (4, 9);
        let (w, b, x) = random_case(p, q, 73);
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig { planes: 7 },
        )
        .unwrap();
        let mut buf = Vec::new();
        lut.write_wire(&mut buf, false);
        let back = DenseFloatLut::read_wire(
            &mut crate::lut::wire::Reader::new(&buf),
            &crate::lut::wire::WireCtx::v1(),
        )
        .unwrap();
        assert_eq!(back.cfg, lut.cfg);
        assert_eq!(back.bias_acc, lut.bias_acc);
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        assert_eq!(lut.eval_f32(&x, &mut c1), back.eval_f32(&x, &mut c2));
        assert_eq!(c1, c2);
    }

    #[test]
    fn truncating_planes_degrades_gracefully() {
        let (p, q) = (4, 12);
        let (w, b, x) = random_case(p, q, 31);
        let full = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig { planes: 11 },
        )
        .unwrap();
        let trunc = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig { planes: 6 },
        )
        .unwrap();
        let mut c = Counters::default();
        let af: Vec<f32> =
            full.eval_f32(&x, &mut c).iter().map(|&a| DenseFloatLut::acc_to_f32(a)).collect();
        let at: Vec<f32> =
            trunc.eval_f32(&x, &mut c).iter().map(|&a| DenseFloatLut::acc_to_f32(a)).collect();
        // truncation error is bounded by dropped-plane mass: 2^-5 relative-ish
        for (f, t) in af.iter().zip(&at) {
            assert!((f - t).abs() < 0.3 * f.abs().max(1.0), "{f} vs {t}");
        }
    }

    #[test]
    fn size_formula_includes_exponent() {
        let (p, q) = (10, 4);
        let w = vec![0.0f32; p * q];
        let b = vec![0.0f32; p];
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
        )
        .unwrap();
        // q tables of 2^(1+5) rows x 10 entries x 16 bits
        assert_eq!(lut.size_bits(16), 4 * 64 * 10 * 16);
    }
}
