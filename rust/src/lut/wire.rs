//! Little-endian binary wire codec for the `.ltm` compiled-model
//! artifact (see `engine::artifact` for the container layout). The
//! vendored crate set has no serde/bincode, so the banks carry their
//! own field-by-field encoders — deliberately boring: fixed-width
//! integers, length-prefixed sequences, no varints, no padding.
//!
//! Reads are bounds-checked and length-capped so a truncated or
//! hostile payload surfaces as a [`WireError`], never a panic or an
//! attempted huge allocation (the artifact checksum catches flipped
//! bits before parsing; these checks are defense in depth).

/// Decode error: what was being read and why it failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error: {}", self.0)
    }
}

impl std::error::Error for WireError {}

pub type Result<T> = std::result::Result<T, WireError>;

pub(crate) fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(WireError(msg.into()))
}

/// Decode context threaded from the artifact container down to the
/// banks: which payload format the bytes were written under, and —
/// when the reader's buffer is a stable file mapping — the owner the
/// arenas may borrow their entry blocks from instead of copying.
#[derive(Clone, Copy, Default)]
pub struct WireCtx<'a> {
    /// v2 payloads carry an explicit alignment gap before each arena's
    /// entry block (64-byte-aligned in the file); v1 payloads are
    /// packed and always decode through the copying path.
    pub aligned: bool,
    /// Backing buffer of the reader when it outlives the decoded model
    /// (an `Arc`-held artifact mapping). `None` forces owned decoding.
    pub backing: Option<&'a std::sync::Arc<crate::bytes::ArtifactBytes>>,
}

impl WireCtx<'static> {
    /// Context for v1 payloads (packed, copying).
    pub fn v1() -> WireCtx<'static> {
        WireCtx { aligned: false, backing: None }
    }

    /// Context for v2 payloads decoded from a transient buffer
    /// (aligned layout, but nothing to borrow from).
    pub fn v2_copying() -> WireCtx<'static> {
        WireCtx { aligned: true, backing: None }
    }
}

// -- writers ------------------------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

/// Length-prefixed i64 sequence.
pub fn put_i64_seq(out: &mut Vec<u8>, seq: &[i64]) {
    put_usize(out, seq.len());
    for &v in seq {
        put_i64(out, v);
    }
}

// -- reader -------------------------------------------------------------

/// Bounds-checked cursor over a decoded payload.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Take the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return err(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(self.u32()? as i32)
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    /// A u64 length field validated against `cap` (rejects corrupt
    /// lengths before they become allocations).
    pub fn len_capped(&mut self, cap: usize, what: &str) -> Result<usize> {
        let v = self.u64()?;
        if v > cap as u64 {
            return err(format!("{what} length {v} exceeds cap {cap}"));
        }
        Ok(v as usize)
    }

    /// A u32 length field validated against `cap`.
    pub fn len_capped_u32(&mut self, cap: usize, what: &str) -> Result<usize> {
        let v = self.u32()?;
        if v as usize > cap {
            return err(format!("{what} length {v} exceeds cap {cap}"));
        }
        Ok(v as usize)
    }

    /// Length-prefixed i64 sequence (cap on element count).
    pub fn i64_seq(&mut self, cap: usize, what: &str) -> Result<Vec<i64>> {
        let n = self.len_capped(cap, what)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i64()?);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut b = Vec::new();
        put_u8(&mut b, 7);
        put_u16(&mut b, 0xBEEF);
        put_u32(&mut b, 0xDEAD_BEEF);
        put_u64(&mut b, u64::MAX - 1);
        put_i32(&mut b, -12345);
        put_i64(&mut b, i64::MIN + 3);
        let mut r = Reader::new(&b);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.i32().unwrap(), -12345);
        assert_eq!(r.i64().unwrap(), i64::MIN + 3);
        assert!(r.is_empty());
    }

    #[test]
    fn seq_roundtrip() {
        let mut b = Vec::new();
        put_i64_seq(&mut b, &[1, -2, 3]);
        let mut r = Reader::new(&b);
        assert_eq!(r.i64_seq(8, "seq").unwrap(), vec![1, -2, 3]);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut b = Vec::new();
        put_u64(&mut b, 42);
        b.truncate(5);
        let mut r = Reader::new(&b);
        assert!(r.u64().is_err());
    }

    #[test]
    fn oversized_length_rejected() {
        let mut b = Vec::new();
        put_u64(&mut b, 1 << 40);
        let mut r = Reader::new(&b);
        assert!(r.len_capped(1 << 20, "test").is_err());
    }
}
