//! The LUT framework (paper §"LUT framework and notation" and
//! §"Computing the affine operation Wx + b").
//!
//! A LUT is a function `I -> O` realised as a memory array indexed by the
//! β(I) bits of the input. The paper's core trick is *linearity*: split
//! the input vector `x` into `k` chunks `x_i`, build one table per chunk
//! holding `W x_i + b/k`, and sum the table outputs — `k` lookups and
//! `k-1` vector adds replace all `p·q` multiplies.
//!
//! Submodules:
//! * [`dense`]      — whole-code indexing (each chunk's full bit string).
//! * [`bitplane`]   — fixed-point bitplane decomposition with LUT reuse
//!                    across planes (§Fixed point formats).
//! * [`floatplane`] — binary16 mantissa-bitplane + full-exponent
//!                    indexing (§Floating point formats, Fig. 1).
//! * [`signed`]     — two's-complement MSB handling (§Dealing with
//!                    signed numbers, Fig. 3).
//! * [`conv`]       — convolutional LUTs with one shared table shifted
//!                    across space (§Convolutional layers, Fig. 2).
//! * [`cost`]       — the paper's size/op formulas, used by the planner.
//! * [`arena`]      — contiguous i32/i64 table arenas backing every bank
//!                    (the batched, table-stationary hot path).
//! * [`kernel`]     — scalar-vs-AVX2 kernel dispatch for the bank hot
//!                    loops (runtime feature detection, `TABLENET_KERNEL`
//!                    override, bit-exact by construction).

pub mod arena;
pub mod dense;
pub mod bitplane;
pub mod floatplane;
pub mod signed;
pub mod conv;
pub mod convfloat;
pub mod cost;
pub mod kernel;
pub mod scalar;
pub mod wire;



/// Fixed-point scale used for integer table entries: entries are stored
/// as `round(value * 2^ACC_FRAC)` in `i64`, accumulated with adds and
/// shifts only, and rescaled *once* at the layer boundary (the rescale
/// is folded into the next layer's quantizer, so the data path itself
/// stays multiplier-less — see `engine::counters` which proves it).
pub const ACC_FRAC: u32 = 32;

/// Maximum bytes a single materialised table may occupy. Configurations
/// beyond this are planner-only (the paper also reports configurations —
/// e.g. 32.7 GiB — it calls "not practical in current implementations").
pub const MAX_TABLE_BYTES: usize = 1 << 30;

/// Error type for LUT construction.
#[derive(Debug)]
pub enum LutError {
    /// Table would exceed [`MAX_TABLE_BYTES`].
    TooLarge { rows: u128, cols: usize },
    /// Partition does not cover the input exactly once.
    BadPartition(String),
    /// A bank parameter is outside its representable range.
    BadConfig(String),
}

impl std::fmt::Display for LutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LutError::TooLarge { rows, cols } => {
                write!(f, "LUT too large to materialise: {rows} rows x {cols} cols")
            }
            LutError::BadPartition(s) => write!(f, "bad partition: {s}"),
            LutError::BadConfig(s) => write!(f, "bad bank config: {s}"),
        }
    }
}

impl std::error::Error for LutError {}

/// A partition of input indices `0..q` into disjoint chunks (the paper's
/// `x = Σ_i x_i` segmentation; footnote 2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub q: usize,
    pub chunks: Vec<Vec<usize>>,
}

impl Partition {
    /// Contiguous chunks of size `m` (last chunk may be smaller).
    pub fn contiguous(q: usize, m: usize) -> Self {
        assert!(m >= 1);
        let chunks = (0..q)
            .collect::<Vec<_>>()
            .chunks(m)
            .map(|c| c.to_vec())
            .collect();
        Partition { q, chunks }
    }

    /// One chunk per element (the paper's `k = q, m_i = 1` extreme).
    pub fn singletons(q: usize) -> Self {
        Partition::contiguous(q, 1)
    }

    /// A single chunk covering everything (`k = 1`).
    pub fn whole(q: usize) -> Self {
        Partition { q, chunks: vec![(0..q).collect()] }
    }

    /// Square contiguous `m x m` pixel blocks of an `h x w` image,
    /// row-major over blocks — the layout the paper recommends for
    /// convolutional LUTs ("it is better to have the partition be in
    /// square contiguous blocks"). `h` and `w` must be divisible by `m`.
    pub fn square_blocks(h: usize, w: usize, m: usize) -> Self {
        assert!(h % m == 0 && w % m == 0, "{h}x{w} not divisible by {m}");
        let mut chunks = Vec::new();
        for by in 0..h / m {
            for bx in 0..w / m {
                let mut c = Vec::with_capacity(m * m);
                for dy in 0..m {
                    for dx in 0..m {
                        c.push((by * m + dy) * w + (bx * m + dx));
                    }
                }
                chunks.push(c);
            }
        }
        Partition { q: h * w, chunks }
    }

    /// Number of chunks k.
    pub fn k(&self) -> usize {
        self.chunks.len()
    }

    /// Largest chunk size.
    pub fn max_chunk(&self) -> usize {
        self.chunks.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Serialize for the `.ltm` artifact.
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        wire::put_u64(out, self.q as u64);
        wire::put_u64(out, self.chunks.len() as u64);
        for c in &self.chunks {
            wire::put_u64(out, c.len() as u64);
            for &i in c {
                wire::put_u64(out, i as u64);
            }
        }
    }

    /// Deserialize a partition written by [`Partition::write_wire`];
    /// the result is validated (exact cover) before being returned.
    pub fn read_wire(r: &mut wire::Reader) -> wire::Result<Partition> {
        const Q_CAP: usize = 1 << 24;
        let q = r.len_capped(Q_CAP, "partition q")?;
        let k = r.len_capped(Q_CAP, "partition chunk count")?;
        let mut chunks = Vec::with_capacity(k);
        for _ in 0..k {
            let m = r.len_capped(Q_CAP, "partition chunk len")?;
            let mut c = Vec::with_capacity(m);
            for _ in 0..m {
                c.push(r.len_capped(Q_CAP, "partition index")?);
            }
            chunks.push(c);
        }
        let p = Partition { q, chunks };
        p.validate().map_err(|e| wire::WireError(e.to_string()))?;
        Ok(p)
    }

    /// Validate: every index 0..q appears exactly once.
    pub fn validate(&self) -> Result<(), LutError> {
        let mut seen = vec![false; self.q];
        for c in &self.chunks {
            for &i in c {
                if i >= self.q {
                    return Err(LutError::BadPartition(format!("index {i} >= q {}", self.q)));
                }
                if seen[i] {
                    return Err(LutError::BadPartition(format!("index {i} duplicated")));
                }
                seen[i] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(LutError::BadPartition(format!("index {missing} uncovered")));
        }
        Ok(())
    }
}

/// Shared epilogue of the conv banks' batched evaluation: crop the
/// centre `h x w` of each sample's padded accumulator image (padding
/// `r`, channel count `cout`) into `out`, adding the bias once per
/// output element.
pub(crate) fn crop_add_bias(
    pad: &[i64],
    out: &mut [i64],
    batch: usize,
    h: usize,
    w: usize,
    r: usize,
    cout: usize,
    bias_acc: &[i64],
) {
    let pw = w + 2 * r;
    let pimg = (h + 2 * r) * pw * cout;
    let oimg = h * w * cout;
    debug_assert_eq!(pad.len(), batch * pimg);
    debug_assert_eq!(out.len(), batch * oimg);
    debug_assert_eq!(bias_acc.len(), cout);
    for s in 0..batch {
        let spad = &pad[s * pimg..(s + 1) * pimg];
        let sout = &mut out[s * oimg..(s + 1) * oimg];
        for y in 0..h {
            for x in 0..w {
                let src = ((y + r) * pw + (x + r)) * cout;
                let dst = (y * w + x) * cout;
                for o in 0..cout {
                    sout[dst + o] = spad[src + o] + bias_acc[o];
                }
            }
        }
    }
}

/// Convert an f32 to the shared fixed accumulator scale.
#[inline]
pub(crate) fn to_acc(v: f64) -> i64 {
    (v * (1u64 << ACC_FRAC) as f64).round() as i64
}

/// Convert an accumulator value back to f32 (layer boundary / display
/// only — never on the multiplier-less data path).
#[inline]
pub fn from_acc(v: i64, extra_shift: i32) -> f32 {
    // value = v * 2^-(ACC_FRAC + extra_shift)
    (v as f64 * (-(ACC_FRAC as i32 + extra_shift) as f64).exp2()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_partition_covers() {
        let p = Partition::contiguous(10, 3);
        assert_eq!(p.k(), 4);
        assert_eq!(p.chunks[3], vec![9]);
        p.validate().unwrap();
    }

    #[test]
    fn singletons_and_whole() {
        assert_eq!(Partition::singletons(5).k(), 5);
        assert_eq!(Partition::whole(5).k(), 1);
        Partition::singletons(5).validate().unwrap();
        Partition::whole(5).validate().unwrap();
    }

    #[test]
    fn square_blocks_cover_image() {
        let p = Partition::square_blocks(4, 6, 2);
        assert_eq!(p.k(), 6);
        assert!(p.chunks.iter().all(|c| c.len() == 4));
        p.validate().unwrap();
    }

    #[test]
    fn square_blocks_first_block_indices() {
        let p = Partition::square_blocks(4, 4, 2);
        assert_eq!(p.chunks[0], vec![0, 1, 4, 5]);
        assert_eq!(p.chunks[1], vec![2, 3, 6, 7]);
    }

    #[test]
    fn validate_catches_duplicates_and_gaps() {
        let dup = Partition { q: 3, chunks: vec![vec![0, 1], vec![1, 2]] };
        assert!(dup.validate().is_err());
        let gap = Partition { q: 3, chunks: vec![vec![0], vec![2]] };
        assert!(gap.validate().is_err());
    }

    #[test]
    fn acc_roundtrip() {
        for v in [0.0, 1.0, -0.5, 0.123456, 100.25] {
            let a = to_acc(v);
            let back = from_acc(a, 0);
            assert!((back - v as f32).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn from_acc_applies_shift() {
        let a = to_acc(8.0);
        assert!((from_acc(a, 3) - 1.0).abs() < 1e-6);
    }
}
