//! Convolutional LUT over binary16 inputs (the paper's CNN layers 2-4
//! configuration: "the mantissa is partitioned into 11 bitplanes and the
//! spatial partition is into single elements").
//!
//! Spatial partition is a single pixel (m = 1): the index per plane is
//! that pixel's (mantissa-bit, 5-bit exponent) field — 64 rows — and the
//! table returns the pixel's dilated (2r+1)² × cout output patch. One
//! table per input channel, shared by all pixels and all planes.
//!
//! Storage is a contiguous [`TableArena`] (one "chunk" per input
//! channel); [`ConvFloatLut::eval_batch_f16`] is channel-outer /
//! sample-inner with caller-provided padded scratch.

use super::arena::{with_arena, ArenaEntry, TableArena};
use super::floatplane::FACC;
use super::{wire, LutError, MAX_TABLE_BYTES};
use crate::engine::counters::Counters;
use crate::quant::f16::{F16, EXP_BIAS, FRAC_BITS, SIG_BITS};

/// Float-input conv LUT bank, m = 1.
#[derive(Debug)]
pub struct ConvFloatLut {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub r: usize,
    /// Mantissa planes evaluated (≤ 11).
    pub planes: u32,
    /// arena chunk ci, row idx, entry (py*pe+px)*cout + o; pe = 2r+1.
    arena: TableArena,
    bias_acc: Vec<i64>,
}

impl ConvFloatLut {
    /// Build from an NHWC filter `[2r+1, 2r+1, cin, cout]` + bias.
    pub fn build(
        filter: &[f32],
        bias: &[f32],
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        r: usize,
        planes: u32,
    ) -> Result<Self, LutError> {
        let fs = 2 * r + 1;
        assert_eq!(filter.len(), fs * fs * cin * cout);
        assert_eq!(bias.len(), cout);
        // loud failure, never a silent clamp (matches the dense float
        // bank; keeps every compiled model `.ltm`-loadable)
        if planes == 0 || planes > SIG_BITS {
            return Err(LutError::BadConfig(format!(
                "float planes {planes} outside 1..={SIG_BITS}"
            )));
        }
        let rows = 1usize << 6; // 1 mantissa bit + 5 exponent bits
        let pe = fs; // patch edge for m=1
        let patch = pe * pe * cout;
        // checked: rows * patch * 8 can wrap usize on huge configs
        match rows.checked_mul(patch).and_then(|e| e.checked_mul(8)) {
            Some(bytes) if bytes <= MAX_TABLE_BYTES => {}
            _ => return Err(LutError::TooLarge { rows: rows as u128, cols: patch }),
        }
        let mut tables = Vec::with_capacity(cin);
        for ci in 0..cin {
            let mut table = vec![0i64; rows * patch];
            for idx in 0..rows {
                let bit = idx & 1;
                if bit == 0 {
                    continue; // zero rows stay zero
                }
                let exp_raw = (idx >> 1) as u32 & 0x1F;
                let scale_exp = exp_raw.max(1) as i32 - EXP_BIAS - FRAC_BITS as i32;
                let scale = ((scale_exp + FACC) as f64).exp2();
                let prow = &mut table[idx * patch..(idx + 1) * patch];
                // pixel at patch centre: output offsets (2r-ky, 2r-kx)
                // relative to patch origin = pixel - r
                for ky in 0..fs {
                    let py = 2 * r - ky;
                    for kx in 0..fs {
                        let px = 2 * r - kx;
                        let base = (py * pe + px) * cout;
                        let fbase = (ky * fs + kx) * cin * cout + ci * cout;
                        for o in 0..cout {
                            prow[base + o] +=
                                (filter[fbase + o] as f64 * scale).round() as i64;
                        }
                    }
                }
            }
            tables.push(table);
        }
        let bias_acc = bias
            .iter()
            .map(|&v| (v as f64 * (FACC as f64).exp2()).round() as i64)
            .collect();
        let arena = TableArena::from_tables(&tables, patch);
        Ok(ConvFloatLut { h, w, cin, cout, r, planes, arena, bias_acc })
    }

    /// The arena (diagnostics: width, residency).
    pub fn arena(&self) -> &TableArena {
        &self.arena
    }

    /// Evaluate over an NHWC `[h, w, cin]` binary16 input. Returns
    /// accumulator image `[h, w, cout]` at FACC scale.
    pub fn eval_f16(&self, x: &[F16], ctr: &mut Counters) -> Vec<i64> {
        let mut out = vec![0i64; self.h * self.w * self.cout];
        let mut pad = Vec::new();
        self.eval_batch_f16(x, 1, &mut out, &mut pad, std::slice::from_mut(ctr));
        out
    }

    /// Batched evaluation: `x` row-major `batch x (h·w·cin)`, `out`
    /// `batch x (h·w·cout)` (overwritten), `ctrs` one counter row per
    /// sample. `pad` is caller-provided scratch reused across calls.
    /// Channel-outer / sample-inner.
    pub fn eval_batch_f16(
        &self,
        x: &[F16],
        batch: usize,
        out: &mut [i64],
        pad: &mut Vec<i64>,
        ctrs: &mut [Counters],
    ) {
        let (h, w, r) = (self.h, self.w, self.r);
        assert_eq!(x.len(), batch * h * w * self.cin);
        assert_eq!(out.len(), batch * h * w * self.cout);
        assert_eq!(ctrs.len(), batch);
        let (ph, pw) = (h + 2 * r, w + 2 * r);
        let pimg = ph * pw * self.cout;
        pad.clear();
        pad.resize(batch * pimg, 0);
        with_arena!(self.arena, E => self.eval_batch_impl::<E>(x, batch, pad, ctrs));
        super::crop_add_bias(pad, out, batch, h, w, r, self.cout, &self.bias_acc);
        let planes = self.planes.min(SIG_BITS);
        for ctr in ctrs.iter_mut() {
            ctr.lut_evals += (h * w * self.cin * planes as usize) as u64;
            ctr.adds += (h * w * self.cout) as u64;
        }
    }

    /// Dispatches between the scalar reference loops and the AVX2 lane
    /// kernel (see [`crate::lut::kernel`]); both perform the identical
    /// per-sample multiset of shifted patch-row adds, so outputs and
    /// counters are bit-identical.
    fn eval_batch_impl<E: super::kernel::LaneRow>(
        &self,
        x: &[F16],
        batch: usize,
        pad: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if crate::lut::kernel::active() == crate::lut::kernel::Kernel::Avx2 {
                // SAFETY: active() returns Avx2 only on CPUs with AVX2.
                unsafe { self.eval_batch_avx2::<E>(x, batch, pad, ctrs) };
                return;
            }
        }
        self.eval_batch_scalar::<E>(x, batch, pad, ctrs);
    }

    fn eval_batch_scalar<E: ArenaEntry>(
        &self,
        x: &[F16],
        batch: usize,
        pad: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let (h, w, r) = (self.h, self.w, self.r);
        let fs = 2 * r + 1;
        let pe = fs;
        let patch = pe * pe * self.cout;
        let (ph, pw) = (h + 2 * r, w + 2 * r);
        let pimg = ph * pw * self.cout;
        let simg = h * w * self.cin;
        let lo_plane = SIG_BITS - self.planes.min(SIG_BITS);
        for ci in 0..self.cin {
            let table = self.arena.chunk_slice::<E>(ci);
            for s in 0..batch {
                let sx = &x[s * simg..(s + 1) * simg];
                let spad = &mut pad[s * pimg..(s + 1) * pimg];
                for y in 0..h {
                    for xx in 0..w {
                        let hval = sx[(y * w + xx) * self.cin + ci];
                        debug_assert_eq!(
                            hval.sign(),
                            0,
                            "conv float LUT expects nonneg input"
                        );
                        // one row — table[(exp<<1)|1] — serves every plane
                        // of this pixel; iterate the significand's set
                        // bits and shift-add the patch (§Perf fast path,
                        // same trick as the dense float bank).
                        let mut sig = (hval.significand11() >> lo_plane) << lo_plane;
                        if sig == 0 {
                            continue;
                        }
                        let idx = ((hval.exponent() << 1) | 1) as usize;
                        let prow = &table[idx * patch..(idx + 1) * patch];
                        while sig != 0 {
                            let j = sig.trailing_zeros();
                            // patch origin in padded coords = (y, xx)
                            for py in 0..pe {
                                let dst = ((y + py) * pw + xx) * self.cout;
                                let src = py * pe * self.cout;
                                let dstrow = &mut spad[dst..dst + pe * self.cout];
                                let srcrow = &prow[src..src + pe * self.cout];
                                for (d, t) in dstrow.iter_mut().zip(srcrow) {
                                    *d += t.widen() << j;
                                }
                            }
                            ctrs[s].shift_adds += patch as u64;
                            sig &= sig - 1;
                        }
                    }
                }
            }
        }
    }

    /// AVX2 twin of [`Self::eval_batch_scalar`]: the per-pixel
    /// (exponent, set-bit) walk is unchanged, but each of the pe
    /// patch-row accumulations (`pe·cout` entries wide) runs 4×i64
    /// lanes per step. Same per-sample adds as the scalar path.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_batch_avx2<E: super::kernel::LaneRow>(
        &self,
        x: &[F16],
        batch: usize,
        pad: &mut [i64],
        ctrs: &mut [Counters],
    ) {
        let (h, w, r) = (self.h, self.w, self.r);
        let fs = 2 * r + 1;
        let pe = fs;
        let patch = pe * pe * self.cout;
        let (ph, pw) = (h + 2 * r, w + 2 * r);
        let pimg = ph * pw * self.cout;
        let simg = h * w * self.cin;
        let lo_plane = SIG_BITS - self.planes.min(SIG_BITS);
        for ci in 0..self.cin {
            let table = self.arena.chunk_table::<E>(ci);
            for s in 0..batch {
                let sx = &x[s * simg..(s + 1) * simg];
                let spad = &mut pad[s * pimg..(s + 1) * pimg];
                for y in 0..h {
                    for xx in 0..w {
                        let hval = sx[(y * w + xx) * self.cin + ci];
                        debug_assert_eq!(
                            hval.sign(),
                            0,
                            "conv float LUT expects nonneg input"
                        );
                        let mut sig = (hval.significand11() >> lo_plane) << lo_plane;
                        if sig == 0 {
                            continue;
                        }
                        let prow = table.row(((hval.exponent() << 1) | 1) as usize);
                        while sig != 0 {
                            let j = sig.trailing_zeros();
                            for py in 0..pe {
                                let dst = ((y + py) * pw + xx) * self.cout;
                                let src = py * pe * self.cout;
                                E::shift_add_row_avx2(
                                    &mut spad[dst..dst + pe * self.cout],
                                    &prow[src..src + pe * self.cout],
                                    j,
                                );
                            }
                            ctrs[s].shift_adds += patch as u64;
                            sig &= sig - 1;
                        }
                    }
                }
            }
        }
    }

    /// Size in bits at r_o-bit entries.
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.arena.total_entries() as u64 * r_o as u64
    }

    /// Serialize for the `.ltm` artifact. `aligned` selects the v2
    /// layout (64-byte-aligned entry block).
    pub fn write_wire(&self, out: &mut Vec<u8>, aligned: bool) {
        for v in [self.h, self.w, self.cin, self.cout, self.r] {
            wire::put_u64(out, v as u64);
        }
        wire::put_u32(out, self.planes);
        self.arena.write_wire(out, aligned);
        wire::put_i64_seq(out, &self.bias_acc);
    }

    /// Deserialize a bank written by [`ConvFloatLut::write_wire`].
    pub fn read_wire(r: &mut wire::Reader, ctx: &wire::WireCtx) -> wire::Result<ConvFloatLut> {
        const DIM_CAP: usize = 1 << 20;
        let h = r.len_capped(DIM_CAP, "convfloat h")?;
        let w = r.len_capped(DIM_CAP, "convfloat w")?;
        let cin = r.len_capped(DIM_CAP, "convfloat cin")?;
        let cout = r.len_capped(DIM_CAP, "convfloat cout")?;
        let rr = r.len_capped(DIM_CAP, "convfloat r")?;
        let planes = r.u32()?;
        if planes == 0 || planes > SIG_BITS {
            return wire::err(format!("convfloat: bad plane count {planes}"));
        }
        let arena = TableArena::read_wire(r, ctx)?;
        let bias_acc = r.i64_seq(DIM_CAP, "convfloat bias")?;
        let pe = 2 * rr + 1;
        if arena.num_chunks() != cin
            || arena.row_len() != pe * pe * cout
            || bias_acc.len() != cout
        {
            return wire::err("convfloat: arena/bias shape disagrees with geometry");
        }
        // every channel table must hold the fixed 2^6 (mantissa-bit ×
        // exponent) rows the m=1 float index gathers from
        if (0..cin).any(|c| arena.chunk_rows(c) != 1 << 6) {
            return wire::err("convfloat: channel table row count mismatch");
        }
        Ok(ConvFloatLut { h, w, cin, cout, r: rr, planes, arena, bias_acc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{conv::conv2d_same, Tensor};
    use crate::util::Rng;

    fn check(h: usize, w: usize, cin: usize, cout: usize, r: usize, seed: u64) {
        let fs = 2 * r + 1;
        let mut rng = Rng::new(seed);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.05).collect();
        let x: Vec<f32> =
            (0..h * w * cin).map(|_| rng.f32() * 4.0).collect();
        let xh: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
        let xq: Vec<f32> = xh.iter().map(|&hh| hh.to_f32()).collect();

        let lut =
            ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, SIG_BITS).unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f16(&xh, &mut ctr);
        ctr.assert_multiplier_less();

        let want = conv2d_same(
            &Tensor::new(&[1, h, w, cin], xq),
            &Tensor::new(&[fs, fs, cin, cout], filter),
            &Tensor::new(&[cout], bias),
        );
        for (i, &a) in acc.iter().enumerate() {
            let g = (a as f64 * (-(FACC as f64)).exp2()) as f32;
            let e = want.data()[i];
            assert!(
                (g - e).abs() < 2e-3 * e.abs().max(1.0),
                "i={i}: {g} vs {e}"
            );
        }
    }

    #[test]
    fn single_channel() {
        check(5, 5, 1, 2, 1, 41);
    }

    #[test]
    fn multi_channel_5x5_filter() {
        check(6, 6, 3, 4, 2, 42);
    }

    #[test]
    fn eval_count_is_pixels_planes_channels() {
        let (h, w, cin, cout, r) = (4, 4, 2, 1, 1);
        let fs = 2 * r + 1;
        let filter = vec![0.1f32; fs * fs * cin * cout];
        let bias = vec![0.0f32; cout];
        let lut = ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, 11).unwrap();
        let mut ctr = Counters::default();
        let x = vec![F16::from_f32(1.0); h * w * cin];
        let _ = lut.eval_f16(&x, &mut ctr);
        assert_eq!(ctr.lut_evals, (h * w * cin * 11) as u64);
    }

    #[test]
    fn eval_batch_bit_exact_with_per_sample() {
        let (h, w, cin, cout, r) = (4, 4, 2, 2, 1);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(93);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let lut =
            ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, SIG_BITS).unwrap();
        let batch = 3;
        let simg = h * w * cin;
        let x: Vec<F16> =
            (0..batch * simg).map(|_| F16::from_f32(rng.f32() * 4.0)).collect();
        let mut out = vec![0i64; batch * h * w * cout];
        let mut pad = Vec::new();
        let mut cb = vec![Counters::default(); batch];
        lut.eval_batch_f16(&x, batch, &mut out, &mut pad, &mut cb);
        let oimg = h * w * cout;
        for s in 0..batch {
            let mut cs = Counters::default();
            let single = lut.eval_f16(&x[s * simg..(s + 1) * simg], &mut cs);
            assert_eq!(&out[s * oimg..(s + 1) * oimg], single.as_slice(), "sample {s}");
            assert_eq!(cb[s], cs, "per-sample counter attribution at sample {s}");
            cb[s].assert_multiplier_less();
        }
    }

    #[test]
    fn forced_kernels_agree_bit_exactly() {
        use crate::lut::kernel;
        let (h, w, cin, cout, r) = (4, 4, 2, 2, 1);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(99);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let lut =
            ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, SIG_BITS).unwrap();
        let simg = h * w * cin;
        for batch in [1usize, 3] {
            let x: Vec<F16> =
                (0..batch * simg).map(|_| F16::from_f32(rng.f32() * 4.0)).collect();
            let run = |k: kernel::Kernel| {
                let _g = kernel::force(k);
                let mut out = vec![0i64; batch * h * w * cout];
                let mut pad = Vec::new();
                let mut cb = vec![Counters::default(); batch];
                lut.eval_batch_f16(&x, batch, &mut out, &mut pad, &mut cb);
                (out, cb)
            };
            let (o_s, c_s) = run(kernel::Kernel::Scalar);
            let (o_v, c_v) = run(kernel::Kernel::Avx2);
            assert_eq!(o_s, o_v, "batch={batch}");
            assert_eq!(c_s, c_v, "batch={batch}");
        }
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let (h, w, cin, cout, r) = (4, 4, 2, 2, 1);
        let fs = 2 * r + 1;
        let mut rng = Rng::new(97);
        let filter: Vec<f32> =
            (0..fs * fs * cin * cout).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
        let lut =
            ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, SIG_BITS).unwrap();
        let mut buf = Vec::new();
        lut.write_wire(&mut buf, false);
        let back = ConvFloatLut::read_wire(
            &mut crate::lut::wire::Reader::new(&buf),
            &crate::lut::wire::WireCtx::v1(),
        )
        .unwrap();
        let x: Vec<F16> =
            (0..h * w * cin).map(|_| F16::from_f32(rng.f32() * 4.0)).collect();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        assert_eq!(lut.eval_f16(&x, &mut c1), back.eval_f16(&x, &mut c2));
        assert_eq!(c1, c2);
    }

    #[test]
    fn zero_input_gives_bias() {
        let (h, w, cin, cout, r) = (3, 3, 1, 2, 1);
        let filter = vec![0.5f32; 9 * cout];
        let bias = vec![1.0f32, -1.0];
        let lut = ConvFloatLut::build(&filter, &bias, h, w, cin, cout, r, 11).unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f16(&vec![F16(0); h * w * cin], &mut ctr);
        for px in 0..h * w {
            let a0 = (acc[px * 2] as f64 * (-(FACC as f64)).exp2()) as f32;
            let a1 = (acc[px * 2 + 1] as f64 * (-(FACC as f64)).exp2()) as f32;
            assert!((a0 - 1.0).abs() < 1e-6);
            assert!((a1 + 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn size_matches_paper_geometry() {
        // cin tables × 2^6 rows × (2r+1)²·cout entries × r_o bits
        let lut = ConvFloatLut::build(
            &vec![0.0; 25 * 32 * 64],
            &vec![0.0; 64],
            14,
            14,
            32,
            64,
            2,
            11,
        )
        .unwrap();
        assert_eq!(lut.size_bits(16), 32 * 64 * (25 * 64) * 16);
    }
}
