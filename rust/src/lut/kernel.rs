//! Kernel dispatch: one switch between the scalar evaluation loops and
//! the AVX2 multi-lane gather/accumulate paths (§Perf).
//!
//! Every LUT bank's `eval_batch` funnels through [`active`] exactly
//! once per call and then runs either its scalar implementation or its
//! `#[target_feature(enable = "avx2")]` twin. Selection order:
//!
//! 1. a thread-local scoped override installed by [`force`] — used by
//!    tests and benches to compare both paths in-process without
//!    touching global state;
//! 2. the `TABLENET_KERNEL` environment variable (`scalar` | `avx2`),
//!    read once per process — the operational override for CI legs and
//!    A/B runs. An unknown value fails loudly; `avx2` on a CPU without
//!    AVX2 prints a visible notice and falls back to scalar rather
//!    than executing illegal instructions;
//! 3. runtime feature detection (`is_x86_64_feature_detected!`).
//!
//! The scalar path is the reference: both kernels perform the *same*
//! multiset of row adds per sample (i64 adds and left-shifts are
//! associative and commutative, and lane order never crosses a sample
//! boundary), so outputs and per-sample [`Counters`] are bit-identical
//! — asserted by the kernel-parity proptests.
//!
//! ```
//! use tablenet::lut::kernel::{self, Kernel};
//!
//! let ambient = kernel::active();       // whatever env/CPU selects
//! {
//!     let _guard = kernel::force(Kernel::Scalar);
//!     assert_eq!(kernel::active(), Kernel::Scalar);
//!     assert!(kernel::describe().ends_with("(forced)"));
//! }                                     // guard dropped: override gone
//! assert_eq!(kernel::active(), ambient);
//! ```
//!
//! [`Counters`]: crate::engine::counters::Counters

use std::cell::Cell;
use std::sync::OnceLock;

use super::arena::ArenaEntry;

/// Environment variable that pins the kernel for the whole process.
pub const ENV_VAR: &str = "TABLENET_KERNEL";

/// An evaluation kernel: which implementation of the bank hot loops
/// runs. `Scalar` exists on every target; `Avx2` is only ever selected
/// on x86_64 CPUs that report the feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable one-row-at-a-time loops — the bit-exact reference.
    Scalar,
    /// 4×i64-lane row accumulation and `vpgatherdd`/`vpgatherqq` index
    /// gathers via `core::arch::x86_64`.
    Avx2,
}

impl Kernel {
    /// Stable lowercase name (used in `TABLENET_KERNEL`, bench JSON and
    /// the inspect/serve banners).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
        }
    }
}

/// True when this CPU can execute the AVX2 paths.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_64_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Process-wide `TABLENET_KERNEL` override, parsed once. Unknown values
/// abort (a typo must never silently run the wrong kernel); a forced
/// `avx2` without CPU support degrades to scalar with a visible notice
/// so CI legs on heterogeneous runners skip gracefully.
fn env_kernel() -> Option<Kernel> {
    static ENV: OnceLock<Option<Kernel>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var(ENV_VAR) {
        Err(_) => None,
        Ok(v) => match v.as_str() {
            "scalar" => Some(Kernel::Scalar),
            "avx2" => {
                if avx2_available() {
                    Some(Kernel::Avx2)
                } else {
                    eprintln!(
                        "tablenet: {ENV_VAR}=avx2 requested but this CPU lacks AVX2; \
                         running the scalar kernel"
                    );
                    Some(Kernel::Scalar)
                }
            }
            other => panic!("{ENV_VAR} must be 'scalar' or 'avx2', got '{other}'"),
        },
    })
}

thread_local! {
    /// Scoped per-thread override (tests/benches); beats the env var so
    /// an in-process A/B comparison works even under `TABLENET_KERNEL`.
    static FORCED: Cell<Option<Kernel>> = const { Cell::new(None) };
}

/// Guard returned by [`force`]; restores the previous per-thread
/// override (supporting nesting) when dropped.
pub struct ForceGuard {
    prev: Option<Kernel>,
}

impl Drop for ForceGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        FORCED.with(|f| f.set(prev));
    }
}

/// Force `k` on the current thread until the guard drops. Forcing
/// `Avx2` on a CPU without AVX2 degrades to `Scalar` (with a notice):
/// the guard must never cause an illegal-instruction fault.
#[must_use = "the forced kernel reverts when this guard is dropped"]
pub fn force(k: Kernel) -> ForceGuard {
    let k = if k == Kernel::Avx2 && !avx2_available() {
        eprintln!("tablenet: kernel::force(avx2) without CPU support; forcing scalar");
        Kernel::Scalar
    } else {
        k
    };
    let prev = FORCED.with(|f| f.replace(Some(k)));
    ForceGuard { prev }
}

/// The kernel the bank hot loops run right now on this thread:
/// [`force`] override, then `TABLENET_KERNEL`, then CPU detection.
/// Guaranteed to return `Avx2` only when [`avx2_available`] is true.
pub fn active() -> Kernel {
    if let Some(k) = FORCED.with(|f| f.get()) {
        return k;
    }
    if let Some(k) = env_kernel() {
        return k;
    }
    static DETECTED: OnceLock<Kernel> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if avx2_available() {
            Kernel::Avx2
        } else {
            Kernel::Scalar
        }
    })
}

/// One-line provenance for banners (`tablenet inspect`, serve startup):
/// which kernel is active and why.
pub fn describe() -> String {
    if let Some(k) = FORCED.with(|f| f.get()) {
        return format!("{} (forced)", k.name());
    }
    if let Some(k) = env_kernel() {
        return format!("{} ({ENV_VAR})", k.name());
    }
    if avx2_available() {
        "avx2 (auto-detected)".to_string()
    } else {
        "scalar (cpu lacks avx2)".to_string()
    }
}

/// Row-accumulate primitives the AVX2 bank paths are generic over —
/// the software analogue of the exemplar's N parallel units per cycle:
/// four i64 accumulator lanes per step, with a scalar tail for the
/// remainder, bit-exact with the scalar loops (same wrapping adds and
/// left-shifts, independent per element).
pub trait LaneRow: ArenaEntry {
    /// `acc[i] += (row[i] as i64) << j` across the whole row.
    ///
    /// # Safety
    /// The CPU must support AVX2 (callers are dispatched via
    /// [`active`], which guarantees it). `acc` and `row` must have
    /// equal lengths and `j < 64`.
    unsafe fn shift_add_row_avx2(acc: &mut [i64], row: &[Self], j: u32);

    /// `acc[i] += row[i] as i64` across the whole row.
    ///
    /// # Safety
    /// Same contract as [`LaneRow::shift_add_row_avx2`].
    unsafe fn add_row_avx2(acc: &mut [i64], row: &[Self]);
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! The `#[target_feature]` bodies. Kept as free functions because
    //! trait methods cannot carry the attribute; the [`LaneRow`] impls
    //! delegate here.

    use super::LaneRow;
    use std::arch::x86_64::*;

    /// # Safety
    /// AVX2 required; `acc.len() == row.len()`; `j < 64`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn shift_add_row_i32(acc: &mut [i64], row: &[i32], j: u32) {
        debug_assert_eq!(acc.len(), row.len());
        debug_assert!(j < 64);
        let n = acc.len();
        let cnt = _mm_cvtsi32_si128(j as i32);
        let mut i = 0usize;
        while i + 4 <= n {
            let r = _mm_loadu_si128(row.as_ptr().add(i) as *const __m128i);
            let wide = _mm256_cvtepi32_epi64(r);
            let shifted = _mm256_sll_epi64(wide, cnt);
            let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_add_epi64(a, shifted),
            );
            i += 4;
        }
        while i < n {
            *acc.get_unchecked_mut(i) =
                acc.get_unchecked(i).wrapping_add((*row.get_unchecked(i) as i64) << j);
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 required; `acc.len() == row.len()`; `j < 64`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn shift_add_row_i64(acc: &mut [i64], row: &[i64], j: u32) {
        debug_assert_eq!(acc.len(), row.len());
        debug_assert!(j < 64);
        let n = acc.len();
        let cnt = _mm_cvtsi32_si128(j as i32);
        let mut i = 0usize;
        while i + 4 <= n {
            let r = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
            let shifted = _mm256_sll_epi64(r, cnt);
            let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_add_epi64(a, shifted),
            );
            i += 4;
        }
        while i < n {
            *acc.get_unchecked_mut(i) =
                acc.get_unchecked(i).wrapping_add(*row.get_unchecked(i) << j);
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 required; `acc.len() == row.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_row_i32(acc: &mut [i64], row: &[i32]) {
        debug_assert_eq!(acc.len(), row.len());
        let n = acc.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let r = _mm_loadu_si128(row.as_ptr().add(i) as *const __m128i);
            let wide = _mm256_cvtepi32_epi64(r);
            let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_add_epi64(a, wide),
            );
            i += 4;
        }
        while i < n {
            *acc.get_unchecked_mut(i) =
                acc.get_unchecked(i).wrapping_add(*row.get_unchecked(i) as i64);
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 required; `acc.len() == row.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_row_i64(acc: &mut [i64], row: &[i64]) {
        debug_assert_eq!(acc.len(), row.len());
        let n = acc.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let r = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
            let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(i) as *mut __m256i,
                _mm256_add_epi64(a, r),
            );
            i += 4;
        }
        while i < n {
            *acc.get_unchecked_mut(i) =
                acc.get_unchecked(i).wrapping_add(*row.get_unchecked(i));
            i += 1;
        }
    }

    impl LaneRow for i32 {
        #[inline]
        unsafe fn shift_add_row_avx2(acc: &mut [i64], row: &[i32], j: u32) {
            shift_add_row_i32(acc, row, j);
        }
        #[inline]
        unsafe fn add_row_avx2(acc: &mut [i64], row: &[i32]) {
            add_row_i32(acc, row);
        }
    }

    impl LaneRow for i64 {
        #[inline]
        unsafe fn shift_add_row_avx2(acc: &mut [i64], row: &[i64], j: u32) {
            shift_add_row_i64(acc, row, j);
        }
        #[inline]
        unsafe fn add_row_avx2(acc: &mut [i64], row: &[i64]) {
            add_row_i64(acc, row);
        }
    }
}

// Non-x86_64 targets still need the trait implemented (the bank code is
// generic over it), but `active()` can never select Avx2 there, so the
// bodies are the plain scalar loops and are unreachable in practice.
#[cfg(not(target_arch = "x86_64"))]
mod fallback {
    use super::LaneRow;

    impl LaneRow for i32 {
        unsafe fn shift_add_row_avx2(acc: &mut [i64], row: &[i32], j: u32) {
            for (a, &r) in acc.iter_mut().zip(row) {
                *a = a.wrapping_add((r as i64) << j);
            }
        }
        unsafe fn add_row_avx2(acc: &mut [i64], row: &[i32]) {
            for (a, &r) in acc.iter_mut().zip(row) {
                *a = a.wrapping_add(r as i64);
            }
        }
    }

    impl LaneRow for i64 {
        unsafe fn shift_add_row_avx2(acc: &mut [i64], row: &[i64], j: u32) {
            for (a, &r) in acc.iter_mut().zip(row) {
                *a = a.wrapping_add(r << j);
            }
        }
        unsafe fn add_row_avx2(acc: &mut [i64], row: &[i64]) {
            for (a, &r) in acc.iter_mut().zip(row) {
                *a = a.wrapping_add(r);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Avx2.name(), "avx2");
    }

    #[test]
    fn active_is_consistent_with_detection() {
        // without a force guard, active() never invents AVX2 on a CPU
        // that lacks it (the env var may legitimately pin scalar)
        let k = active();
        if k == Kernel::Avx2 {
            assert!(avx2_available());
        }
        assert!(!describe().is_empty());
    }

    #[test]
    fn force_guard_nests_and_restores() {
        let outer = active();
        {
            let _g1 = force(Kernel::Scalar);
            assert_eq!(active(), Kernel::Scalar);
            {
                let _g2 = force(Kernel::Avx2);
                // either avx2 (supported) or the documented degrade
                let inner = active();
                assert_eq!(
                    inner,
                    if avx2_available() { Kernel::Avx2 } else { Kernel::Scalar }
                );
                assert!(describe().ends_with("(forced)"));
            }
            assert_eq!(active(), Kernel::Scalar);
        }
        assert_eq!(active(), outer);
    }

    #[test]
    fn lane_primitives_match_scalar_reference() {
        if !avx2_available() {
            eprintln!("skipping lane primitive test: no AVX2 on this CPU");
            return;
        }
        // odd lengths exercise the scalar tails; extreme values
        // exercise wrapping behavior
        let row32: Vec<i32> = (0..11)
            .map(|i| [i32::MIN, -3, 0, 7, i32::MAX][i % 5] ^ (i as i32))
            .collect();
        let row64: Vec<i64> = (0..11)
            .map(|i| [i64::MIN / 2, -9, 0, 13, i64::MAX / 2][i % 5] ^ (i as i64))
            .collect();
        for j in [0u32, 1, 7, 31, 63] {
            let base: Vec<i64> = (0..11).map(|i| (i as i64) * 1_000_003 - 5).collect();
            let mut want = base.clone();
            for (a, &r) in want.iter_mut().zip(&row32) {
                *a = a.wrapping_add((r as i64) << j);
            }
            let mut got = base.clone();
            // SAFETY: avx2_available() checked above
            unsafe { i32::shift_add_row_avx2(&mut got, &row32, j) };
            assert_eq!(got, want, "i32 shift_add j={j}");

            let mut want = base.clone();
            for (a, &r) in want.iter_mut().zip(&row64) {
                *a = a.wrapping_add(r << j);
            }
            let mut got = base.clone();
            // SAFETY: avx2_available() checked above
            unsafe { i64::shift_add_row_avx2(&mut got, &row64, j) };
            assert_eq!(got, want, "i64 shift_add j={j}");
        }
        let base: Vec<i64> = (0..11).map(|i| (i as i64) - 4).collect();
        let mut want = base.clone();
        for (a, &r) in want.iter_mut().zip(&row32) {
            *a = a.wrapping_add(r as i64);
        }
        let mut got = base.clone();
        // SAFETY: avx2_available() checked above
        unsafe { i32::add_row_avx2(&mut got, &row32) };
        assert_eq!(got, want, "i32 add");
        let mut want = base.clone();
        for (a, &r) in want.iter_mut().zip(&row64) {
            *a = a.wrapping_add(r);
        }
        let mut got = base;
        // SAFETY: avx2_available() checked above
        unsafe { i64::add_row_avx2(&mut got, &row64) };
        assert_eq!(got, want, "i64 add");
    }
}
