//! The paper's cost formulas — sizes in bits and operation counts — as
//! pure functions of the configuration, independent of whether a table
//! is actually materialisable. The planner sweeps these to regenerate
//! Figs. 5, 7 and 8 and the in-text configurations (including the ones
//! the paper itself calls impractical, e.g. the 32.7 GiB MLP).
//!
//! Op-count convention: the paper's MLP accounting is exact under
//! `adds = (n·k − 1) · p` per layer (all `n·k` table outputs folded into
//! one accumulator: n·k−1 vector adds of p elements) — this reproduces
//! the in-text 1,330,678 (whole-code, n=1) and 14,652,918 (bitplaned)
//! MLP numbers to the digit. The two linear-classifier in-text numbers
//! use slightly different conventions (n·(k−1)·p and n·k·p); we expose
//! all three so the harness can print each.



/// How a chunk's bits index the table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexMode {
    /// Whole code: all r_I bits of each of the m elements at once.
    WholeFixed { r_i: u32 },
    /// One bitplane at a time, n = r_i planes, table reused (fixed pt).
    BitplaneFixed { r_i: u32 },
    /// One mantissa plane + the full t-bit exponent per element
    /// (binary16: planes = 11, t = 5).
    FloatPlanes { planes: u32, exp_bits: u32 },
}

impl IndexMode {
    /// Bits of table index contributed by ONE element of a chunk.
    pub fn index_bits_per_elem(&self) -> u32 {
        match *self {
            IndexMode::WholeFixed { r_i } => r_i,
            IndexMode::BitplaneFixed { .. } => 1,
            IndexMode::FloatPlanes { exp_bits, .. } => 1 + exp_bits,
        }
    }

    /// Number of table evaluations per chunk (the n in n·k).
    pub fn evals_per_chunk(&self) -> u32 {
        match *self {
            IndexMode::WholeFixed { .. } => 1,
            IndexMode::BitplaneFixed { r_i } => r_i,
            IndexMode::FloatPlanes { planes, .. } => planes,
        }
    }
}

/// Cost of one dense layer `p x q` under a uniform chunk size `m`
/// (last chunk may be ragged — handled exactly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DenseCost {
    /// Number of tables (k).
    pub num_luts: u64,
    /// Total table bits: Σ_i 2^(m_i · index_bits) · p · r_o.
    pub size_bits: u64,
    /// Table reads: n·k.
    pub lut_evals: u64,
    /// (n·k − 1)·p — the paper's MLP convention.
    pub adds: u64,
    /// n·(k−1)·p — the paper's Fig. 5 "1650" convention.
    pub adds_exclusive: u64,
    /// n·k·p — every table output charged.
    pub adds_inclusive: u64,
    /// Reference multiply-and-adds for the same layer: p·q.
    pub ref_macs: u64,
}

/// Compute dense-layer costs. `q` inputs, `p` outputs, chunk size `m`,
/// `r_o` output bits per table entry.
pub fn dense_cost(q: u64, p: u64, m: u64, mode: IndexMode, r_o: u32) -> DenseCost {
    assert!(m >= 1 && m <= q);
    let k = q / m + if q % m != 0 { 1 } else { 0 };
    let n = mode.evals_per_chunk() as u64;
    let ib = mode.index_bits_per_elem() as u64;
    // exact over ragged last chunk (saturating — whole-code configs can
    // exceed u128 for large m, and the paper itself quotes such configs
    // only to call them impractical)
    let full = q / m;
    let rem = q % m;
    let mut size: u128 = sat_mul(
        sat_mul(full as u128, pow2(m * ib)),
        (p * r_o as u64) as u128,
    );
    if rem > 0 {
        size = size.saturating_add(sat_mul(pow2(rem * ib), (p * r_o as u64) as u128));
    }
    DenseCost {
        num_luts: k,
        size_bits: size.min(u64::MAX as u128) as u64,
        lut_evals: n * k,
        adds: (n * k - 1) * p,
        adds_exclusive: n * (k - 1) * p,
        adds_inclusive: n * k * p,
        ref_macs: p * q,
    }
}

/// Cost of one conv layer under the paper's geometry: input `h x w` with
/// `cin` channels, filter `(2r+1)²`, `cout` features, spatial block
/// `m x m`. One table per input channel, shared across blocks and
/// planes. The output patch has c = (m+2r)² · cout entries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvCost {
    pub num_luts: u64,
    pub size_bits: u64,
    pub lut_evals: u64,
    /// Patch accumulation shift-adds: evals · (m+2r)² · cout.
    pub adds: u64,
    pub ref_macs: u64,
}

pub fn conv_cost(
    h: u64,
    w: u64,
    cin: u64,
    cout: u64,
    r: u64,
    m: u64,
    mode: IndexMode,
    r_o: u32,
) -> ConvCost {
    let a = m * m; // elements per block
    let c = (m + 2 * r) * (m + 2 * r) * cout; // patch entries
    let blocks = (h / m) * (w / m);
    let n = mode.evals_per_chunk() as u64;
    let ib = mode.index_bits_per_elem() as u64;
    let size: u128 = sat_mul(
        sat_mul(cin as u128, pow2(a * ib)),
        (c * r_o as u64) as u128,
    );
    let evals = blocks * n * cin;
    let fs = 2 * r + 1;
    ConvCost {
        num_luts: cin,
        size_bits: size.min(u64::MAX as u128) as u64,
        lut_evals: evals,
        adds: evals * c,
        ref_macs: h * w * fs * fs * cin * cout,
    }
}

fn pow2(e: u64) -> u128 {
    if e >= 127 {
        u128::MAX
    } else {
        1u128 << e
    }
}

fn sat_mul(a: u128, b: u128) -> u128 {
    a.saturating_mul(b)
}

/// Stochastic rounding LUT size: R · 2^β(I) · β(O) (paper formula).
pub fn stochastic_rounding_size_bits(r_phases: u64, beta_i: u32, beta_o: u32) -> u64 {
    r_phases.saturating_mul(1u64 << beta_i).saturating_mul(beta_o as u64)
}

/// Scalar-nonlinearity LUT size: 2^β(I) · β(O) (paper §Computing a
/// nonlinear function f with LUT — e.g. 2^37 bits for f32->f32, 128 KiB
/// for f16->f16).
pub fn scalar_fn_size_bits(beta_i: u32, beta_o: u32) -> u64 {
    if beta_i >= 64 {
        return u64::MAX;
    }
    (1u64 << beta_i).saturating_mul(beta_o as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 8 * 1024 * 1024; // bits per MiB
    const KIB: u64 = 8 * 1024;
    const GIB: u64 = 8 * 1024 * 1024 * 1024;

    #[test]
    fn paper_linear_56_luts_17_5_mib() {
        // 784 pixels at 3 bits, chunks of 14 -> 56 LUTs, 2^14·... rows?
        // Paper: "56 LUTs with a total combined size of 17.5 MiB, 168
        // LUT evaluations" — bitplane mode: 2^14 rows × 10 outputs ×
        // 16-bit entries × 56 tables.
        let c = dense_cost(784, 10, 14, IndexMode::BitplaneFixed { r_i: 3 }, 16);
        assert_eq!(c.num_luts, 56);
        assert_eq!(c.lut_evals, 168);
        assert_eq!(c.size_bits, 56 * (1 << 14) * 10 * 16);
        assert!((c.size_bits as f64 / MIB as f64 - 17.5).abs() < 0.01);
        // Fig.5 convention
        assert_eq!(c.adds_exclusive, 1650);
    }

    #[test]
    fn paper_linear_784_luts_30_6_kib() {
        // "784 LUTs totaling about 30.6 KiB ... 23520 shift-and-add"
        // NOTE: at m=1 and 3-bit whole-code indexing, size = 784·2^3·10·
        // r_o bits. 30.6 KiB needs r_o=4... The paper's point is parity
        // with the 30.7 KiB reference model; with 16-bit entries the
        // bitplane m=1 config gives 784·2·10·16 bits = 30.6 KiB. m_i=1
        // bitplane tables have 2^1 rows.
        let c = dense_cost(784, 10, 1, IndexMode::BitplaneFixed { r_i: 3 }, 16);
        assert_eq!(c.num_luts, 784);
        assert_eq!(c.size_bits, 784 * 2 * 10 * 16);
        assert!((c.size_bits as f64 / KIB as f64 - 30.625).abs() < 0.1);
        assert_eq!(c.adds_inclusive, 23520);
    }

    #[test]
    fn paper_mlp_whole_binary16_counts() {
        // layers: 784->1024, 1024->512, 512->10; whole-16-bit indexing.
        // "2320 LUTs ... 1330678 addition operations"
        let l1 = dense_cost(784, 1024, 1, IndexMode::WholeFixed { r_i: 16 }, 16);
        let l2 = dense_cost(1024, 512, 1, IndexMode::WholeFixed { r_i: 16 }, 16);
        let l3 = dense_cost(512, 10, 1, IndexMode::WholeFixed { r_i: 16 }, 16);
        assert_eq!(l1.num_luts + l2.num_luts + l3.num_luts, 2320);
        assert_eq!(l1.adds + l2.adds + l3.adds, 1_330_678);
        assert_eq!(l1.ref_macs + l2.ref_macs + l3.ref_macs, 1_332_224);
    }

    #[test]
    fn paper_mlp_whole_binary16_size_32_7_gib() {
        // with the sign bit elided (always 0 after ReLU): 15-bit index
        // for the two hidden layers, 8-bit fixed for the input layer.
        let l1 = dense_cost(784, 1024, 1, IndexMode::WholeFixed { r_i: 8 }, 16);
        let l2 = dense_cost(1024, 512, 1, IndexMode::WholeFixed { r_i: 15 }, 16);
        let l3 = dense_cost(512, 10, 1, IndexMode::WholeFixed { r_i: 15 }, 16);
        let total = l1.size_bits + l2.size_bits + l3.size_bits;
        let gib = total as f64 / GIB as f64;
        assert!((gib - 32.7).abs() < 0.7, "got {gib} GiB");
    }

    #[test]
    fn paper_mlp_bitplaned_counts() {
        // "2320 LUTs with a combined size of 162.6 MiB and 14652918
        // shift-and-add operations" — 11 planes, 5-bit exponent, m=1.
        let fp = IndexMode::FloatPlanes { planes: 11, exp_bits: 5 };
        let l1 = dense_cost(784, 1024, 1, fp, 16);
        let l2 = dense_cost(1024, 512, 1, fp, 16);
        let l3 = dense_cost(512, 10, 1, fp, 16);
        assert_eq!(l1.adds + l2.adds + l3.adds, 14_652_918);
        let total_size = l1.size_bits + l2.size_bits + l3.size_bits;
        let mib = total_size as f64 / MIB as f64;
        assert!((mib - 162.6).abs() < 1.0, "got {mib} MiB");
    }

    #[test]
    fn conv_patch_geometry() {
        // paper: m x m input block -> (m+2r) x (m+2r) output block
        let c = conv_cost(28, 28, 1, 32, 2, 2, IndexMode::BitplaneFixed { r_i: 8 }, 16);
        assert_eq!(c.num_luts, 1);
        // 2^4 rows × 36·32 entries × 16 bits
        assert_eq!(c.size_bits, 16 * 36 * 32 * 16);
        // 196 blocks × 8 planes
        assert_eq!(c.lut_evals, 196 * 8);
    }

    #[test]
    fn conv_ref_macs() {
        let c = conv_cost(28, 28, 1, 32, 2, 2, IndexMode::BitplaneFixed { r_i: 8 }, 16);
        assert_eq!(c.ref_macs, 28 * 28 * 25 * 32);
    }

    #[test]
    fn bitplane_size_independent_of_precision() {
        let a = dense_cost(100, 10, 4, IndexMode::BitplaneFixed { r_i: 3 }, 16);
        let b = dense_cost(100, 10, 4, IndexMode::BitplaneFixed { r_i: 8 }, 16);
        assert_eq!(a.size_bits, b.size_bits);
        assert!(b.lut_evals > a.lut_evals);
    }

    #[test]
    fn whole_size_exponential_in_m() {
        let m2 = dense_cost(16, 4, 2, IndexMode::WholeFixed { r_i: 4 }, 16);
        let m4 = dense_cost(16, 4, 4, IndexMode::WholeFixed { r_i: 4 }, 16);
        // doubling m squares the per-table rows but halves the count
        assert_eq!(m4.size_bits, m2.size_bits * (1 << 8) / 2);
    }

    #[test]
    fn scalar_fn_sizes_from_paper() {
        // f32 -> f32: 2^37 bits = 16 GiB
        assert_eq!(scalar_fn_size_bits(32, 32), 1u64 << 37);
        // f16 -> f16: 128 KiB
        assert_eq!(scalar_fn_size_bits(16, 16) / 8 / 1024, 128);
    }

    #[test]
    fn stochastic_size_formula() {
        assert_eq!(stochastic_rounding_size_bits(16, 8, 4), 16 * 256 * 4);
    }

    #[test]
    fn ragged_chunks_exact() {
        // q=10, m=3 -> chunks 3,3,3,1
        let c = dense_cost(10, 2, 3, IndexMode::WholeFixed { r_i: 2 }, 16);
        assert_eq!(c.num_luts, 4);
        assert_eq!(c.size_bits, (3 * (1 << 6) + (1 << 2)) * 2 * 16);
    }
}
