//! Whole-code dense LUT bank: each chunk's *entire* bit string indexes
//! its table (the paper's base construction in §"Computing the affine
//! operation Wx + b").
//!
//! For a `p x q` weight matrix, a chunk of `m` input elements quantized
//! to `r_I` bits each gets a table of `2^(m·r_I)` rows × `p` entries,
//! where row `idx` holds `W·x_chunk(idx) + b/k` — the bias is *baked
//! into the tables* (1/k per chunk), so summing the k table rows yields
//! `Wx + b` with zero multiplies.
//!
//! Storage is a contiguous [`TableArena`] (i32-narrowed when entries
//! fit); [`DenseWholeLut::eval_batch`] runs chunk-outer / sample-inner
//! so each table is streamed once per batch, not once per sample.

use super::arena::{with_arena, ArenaEntry, TableArena};
use super::{to_acc, wire, LutError, Partition, MAX_TABLE_BYTES};
use crate::engine::counters::Counters;
use crate::quant::FixedFormat;

/// One table per chunk; entries in the shared fixed accumulator scale,
/// flattened into a single arena.
#[derive(Debug)]
pub struct DenseWholeLut {
    pub partition: Partition,
    pub fmt: FixedFormat,
    pub p: usize,
    /// Chunk c's table occupies arena rows `0..2^(m_c * r_I)`, each of
    /// `p` entries.
    arena: TableArena,
}

impl DenseWholeLut {
    /// Build from weights `w` (row-major `p x q`), bias `b` (`p`), a
    /// partition of the q inputs and the input fixed-point format.
    ///
    /// Table row for index `idx`: the chunk's elements are decoded from
    /// the concatenated codes (element 0 of the chunk in the *least*
    /// significant `r_I` bits), dequantized, and pushed through W.
    pub fn build(
        w: &[f32],
        b: &[f32],
        p: usize,
        q: usize,
        partition: Partition,
        fmt: FixedFormat,
    ) -> Result<Self, LutError> {
        assert_eq!(w.len(), p * q);
        assert_eq!(b.len(), p);
        partition.validate()?;
        assert_eq!(partition.q, q);
        let k = partition.k() as f64;
        let r_i = fmt.bits;
        let mut tables = Vec::with_capacity(partition.k());
        for chunk in &partition.chunks {
            let m = chunk.len();
            let idx_bits = (m as u32) * r_i;
            if idx_bits >= 28 {
                let rows = if idx_bits >= 127 { u128::MAX } else { 1u128 << idx_bits };
                return Err(LutError::TooLarge { rows, cols: p });
            }
            let rows = 1usize << idx_bits;
            // checked: rows * p * 8 can wrap usize on huge configs
            match rows.checked_mul(p).and_then(|e| e.checked_mul(8)) {
                Some(bytes) if bytes <= MAX_TABLE_BYTES => {}
                _ => return Err(LutError::TooLarge { rows: rows as u128, cols: p }),
            }
            let mut table = vec![0i64; rows * p];
            for idx in 0..rows {
                let row = &mut table[idx * p..(idx + 1) * p];
                for (e, &col) in chunk.iter().enumerate() {
                    let code = ((idx >> (e as u32 * r_i)) as u32) & ((1 << r_i) - 1);
                    let xv = fmt.dequantize(code) as f64;
                    if xv == 0.0 {
                        continue;
                    }
                    for (o, r) in row.iter_mut().enumerate() {
                        *r += to_acc(xv * w[o * q + col] as f64);
                    }
                }
                for (o, r) in row.iter_mut().enumerate() {
                    *r += to_acc(b[o] as f64 / k);
                }
            }
            tables.push(table);
        }
        let arena = TableArena::from_tables(&tables, p);
        Ok(DenseWholeLut { partition, fmt, p, arena })
    }

    /// The arena (diagnostics: width, residency).
    pub fn arena(&self) -> &TableArena {
        &self.arena
    }

    /// Evaluate `Wx + b` for a quantized input (codes, length q) into an
    /// accumulator vector. Pure gathers and adds; `ctr` records the op
    /// mix (and would record any multiply — there are none).
    pub fn eval_codes(&self, codes: &[u32], ctr: &mut Counters) -> Vec<i64> {
        let mut acc = vec![0i64; self.p];
        self.eval_batch(codes, 1, &mut acc, std::slice::from_mut(ctr));
        acc
    }

    /// Batched evaluation over `batch` samples: `codes` is row-major
    /// `batch x q`, `out` is `batch x p` (overwritten), `ctrs` is one
    /// counter row per sample (exact per-sample attribution). Loop order
    /// is *chunk-outer, sample-inner* — each chunk's table is streamed
    /// once per batch. Bit-exact with per-sample
    /// [`DenseWholeLut::eval_codes`] (integer adds in identical
    /// per-sample order), zero allocations.
    pub fn eval_batch(&self, codes: &[u32], batch: usize, out: &mut [i64], ctrs: &mut [Counters]) {
        assert_eq!(codes.len(), batch * self.partition.q);
        assert_eq!(out.len(), batch * self.p);
        assert_eq!(ctrs.len(), batch);
        out.fill(0);
        with_arena!(self.arena, E => self.eval_batch_impl::<E>(codes, batch, out));
        // whole-code op counts are uniform per sample: k lookups and
        // k·p adds each — attributed outside the gather loop
        let k = self.partition.k() as u64;
        for ctr in ctrs.iter_mut() {
            ctr.lut_evals += k;
            ctr.adds += k * self.p as u64;
        }
    }

    /// Dispatches between the scalar reference loop and the AVX2 lane
    /// kernel (see [`crate::lut::kernel`]); both perform the identical
    /// per-sample row adds, so outputs are bit-identical.
    fn eval_batch_impl<E: super::kernel::LaneRow>(
        &self,
        codes: &[u32],
        batch: usize,
        out: &mut [i64],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if crate::lut::kernel::active() == crate::lut::kernel::Kernel::Avx2 {
                // SAFETY: active() returns Avx2 only on CPUs with AVX2.
                unsafe { self.eval_batch_avx2::<E>(codes, batch, out) };
                return;
            }
        }
        self.eval_batch_scalar::<E>(codes, batch, out);
    }

    fn eval_batch_scalar<E: ArenaEntry>(&self, codes: &[u32], batch: usize, out: &mut [i64]) {
        let q = self.partition.q;
        let p = self.p;
        let r_i = self.fmt.bits;
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = self.arena.chunk_slice::<E>(c);
            for s in 0..batch {
                let srow = &codes[s * q..(s + 1) * q];
                let mut idx = 0usize;
                for (e, &col) in chunk.iter().enumerate() {
                    idx |= (srow[col] as usize) << (e as u32 * r_i);
                }
                let row = &table[idx * p..(idx + 1) * p];
                let acc = &mut out[s * p..(s + 1) * p];
                for (a, r) in acc.iter_mut().zip(row) {
                    *a += r.widen();
                }
            }
        }
    }

    /// AVX2 twin of [`Self::eval_batch_scalar`]: four samples' arena
    /// indices are built per step — one `vpgatherdd` per chunk element
    /// pulls the four samples' codes, zero-extended to u64 lanes and
    /// OR-shifted into place — and row adds run 4×i64 lanes per step.
    /// Same per-sample adds as the scalar path, bit-identical output.
    ///
    /// # Safety
    /// The CPU must support AVX2.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_batch_avx2<E: super::kernel::LaneRow>(
        &self,
        codes: &[u32],
        batch: usize,
        out: &mut [i64],
    ) {
        use std::arch::x86_64::*;
        let q = self.partition.q;
        let p = self.p;
        let r_i = self.fmt.bits;
        for (c, chunk) in self.partition.chunks.iter().enumerate() {
            let table = self.arena.chunk_table::<E>(c);
            debug_assert!(3 * q <= i32::MAX as usize);
            let lane_off = _mm_setr_epi32(0, q as i32, (2 * q) as i32, (3 * q) as i32);
            let mut s0 = 0usize;
            while s0 + 4 <= batch {
                let mut idx4 = _mm256_setzero_si256();
                for (e, &col) in chunk.iter().enumerate() {
                    // SAFETY: gathered element offsets are (s0 + l)·q +
                    // col with l < 4 and s0 + 3 < batch, all below
                    // codes.len() = batch·q.
                    let base = codes.as_ptr().add(s0 * q + col) as *const i32;
                    let cv = _mm_i32gather_epi32::<4>(base, lane_off);
                    let wide = _mm256_cvtepu32_epi64(cv);
                    idx4 = _mm256_or_si256(
                        idx4,
                        _mm256_sll_epi64(wide, _mm_cvtsi32_si128((e as u32 * r_i) as i32)),
                    );
                }
                let mut idx = [0u64; 4];
                _mm256_storeu_si256(idx.as_mut_ptr() as *mut __m256i, idx4);
                for (l, &i) in idx.iter().enumerate() {
                    let s = s0 + l;
                    let acc = &mut out[s * p..(s + 1) * p];
                    E::add_row_avx2(acc, table.row(i as usize));
                }
                s0 += 4;
            }
            // ragged tail: scalar index build, lane-wide row adds
            for s in s0..batch {
                let srow = &codes[s * q..(s + 1) * q];
                let mut idx = 0usize;
                for (e, &col) in chunk.iter().enumerate() {
                    idx |= (srow[col] as usize) << (e as u32 * r_i);
                }
                let acc = &mut out[s * p..(s + 1) * p];
                E::add_row_avx2(acc, table.row(idx));
            }
        }
    }

    /// Quantize an f32 input (values in [0,1]) then evaluate.
    pub fn eval_f32(&self, x: &[f32], ctr: &mut Counters) -> Vec<i64> {
        let codes: Vec<u32> = x.iter().map(|&v| self.fmt.quantize(v)).collect();
        self.eval_codes(&codes, ctr)
    }

    /// Total materialised size in bits, counting entries at `r_o` bits
    /// each (the paper's accounting; the in-memory i32/i64 arena is an
    /// artifact of the software simulation, see DESIGN notes in README).
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.arena.total_entries() as u64 * r_o as u64
    }

    /// Serialize for the `.ltm` artifact (partition, format, arena).
    /// `aligned` selects the v2 layout (64-byte-aligned entry block).
    pub fn write_wire(&self, out: &mut Vec<u8>, aligned: bool) {
        self.partition.write_wire(out);
        wire::put_u32(out, self.fmt.bits);
        wire::put_u64(out, self.p as u64);
        self.arena.write_wire(out, aligned);
    }

    /// Deserialize a bank written by [`DenseWholeLut::write_wire`].
    pub fn read_wire(r: &mut wire::Reader, ctx: &wire::WireCtx) -> wire::Result<DenseWholeLut> {
        let partition = Partition::read_wire(r)?;
        let bits = r.u32()?;
        if !(1..=16).contains(&bits) {
            return wire::err(format!("dense whole: bad input bits {bits}"));
        }
        let fmt = FixedFormat::new(bits);
        let p = r.len_capped(1 << 24, "dense whole p")?;
        let arena = TableArena::read_wire(r, ctx)?;
        if arena.row_len() != p || arena.num_chunks() != partition.k() {
            return wire::err("dense whole: arena shape disagrees with partition");
        }
        // every chunk must hold exactly 2^(m_i·bits) rows, else a code
        // in range would gather out of bounds at eval time
        for (c, chunk) in partition.chunks.iter().enumerate() {
            let idx_bits = chunk.len() as u32 * bits;
            if idx_bits >= 28 || arena.chunk_rows(c) != 1usize << idx_bits {
                return wire::err(format!("dense whole: chunk {c} row count mismatch"));
            }
        }
        Ok(DenseWholeLut { partition, fmt, p, arena })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::from_acc;
    use crate::util::Rng;

    /// Reference float evaluation for comparison.
    fn ref_affine(w: &[f32], b: &[f32], p: usize, q: usize, x: &[f32]) -> Vec<f32> {
        (0..p)
            .map(|o| {
                b[o] + (0..q).map(|i| w[o * q + i] * x[i]).sum::<f32>()
            })
            .collect()
    }

    fn random_case(
        p: usize,
        q: usize,
        seed: u64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let x: Vec<f32> = (0..q).map(|_| rng.f32()).collect();
        (w, b, x)
    }

    #[test]
    fn matches_reference_on_quantized_input() {
        let (p, q) = (5, 12);
        let (w, b, x) = random_case(p, q, 42);
        let fmt = FixedFormat::new(4);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
        let lut = DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 3), fmt)
            .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let got: Vec<f32> = acc.iter().map(|&a| from_acc(a, 0)).collect();
        let want = ref_affine(&w, &b, p, q, &xq);
        for (g, w_) in got.iter().zip(&want) {
            assert!((g - w_).abs() < 1e-4, "{g} vs {w_}");
        }
    }

    #[test]
    fn zero_multiplies_on_eval_path() {
        let (p, q) = (3, 8);
        let (w, b, x) = random_case(p, q, 7);
        let fmt = FixedFormat::new(3);
        let lut =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        let mut ctr = Counters::default();
        let _ = lut.eval_f32(&x, &mut ctr);
        assert_eq!(ctr.mults, 0);
        assert_eq!(ctr.lut_evals, 4); // k = 8/2
        assert_eq!(ctr.adds, 4 * p as u64);
    }

    #[test]
    fn bias_fully_recovered_across_chunks() {
        // zero weights: output must be exactly b regardless of partition
        let (p, q) = (4, 9);
        let w = vec![0.0f32; p * q];
        let b = vec![0.25f32, -1.5, 3.0, 0.0];
        let x = vec![0.5f32; q];
        for m in [1, 2, 3, 9] {
            let lut = DenseWholeLut::build(
                &w,
                &b,
                p,
                q,
                Partition::contiguous(q, m),
                FixedFormat::new(2),
            )
            .unwrap();
            let mut ctr = Counters::default();
            let acc = lut.eval_f32(&x, &mut ctr);
            for (o, &a) in acc.iter().enumerate() {
                assert!((from_acc(a, 0) - b[o]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn partition_choice_does_not_change_result() {
        let (p, q) = (4, 12);
        let (w, b, x) = random_case(p, q, 11);
        let fmt = FixedFormat::new(3);
        let mut results = Vec::new();
        for m in [1, 2, 4, 6] {
            let lut =
                DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            let mut ctr = Counters::default();
            let acc = lut.eval_f32(&x, &mut ctr);
            results.push(acc.iter().map(|&a| from_acc(a, 0)).collect::<Vec<f32>>());
        }
        for r in &results[1..] {
            for (a, b_) in r.iter().zip(&results[0]) {
                assert!((a - b_).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn eval_batch_bit_exact_with_per_sample() {
        let (p, q) = (6, 10);
        let (w, b, _) = random_case(p, q, 19);
        let fmt = FixedFormat::new(3);
        let lut =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        let mut rng = Rng::new(77);
        let batch = 5;
        let codes: Vec<u32> =
            (0..batch * q).map(|_| rng.below(fmt.levels() as usize) as u32).collect();
        let mut out = vec![0i64; batch * p];
        let mut cb = vec![Counters::default(); batch];
        lut.eval_batch(&codes, batch, &mut out, &mut cb);
        for s in 0..batch {
            let mut cs = Counters::default();
            let single = lut.eval_codes(&codes[s * q..(s + 1) * q], &mut cs);
            assert_eq!(&out[s * p..(s + 1) * p], single.as_slice(), "sample {s}");
            assert_eq!(cb[s], cs, "per-sample counter attribution at sample {s}");
            cb[s].assert_multiplier_less();
        }
    }

    #[test]
    fn forced_kernels_agree_bit_exactly() {
        use crate::lut::kernel;
        let (p, q) = (6, 10);
        let (w, b, _) = random_case(p, q, 91);
        let fmt = FixedFormat::new(3);
        let mut rng = Rng::new(92);
        for m in [1usize, 2, 5] {
            let lut =
                DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, m), fmt)
                    .unwrap();
            for batch in [1usize, 6, 8] {
                let codes: Vec<u32> = (0..batch * q)
                    .map(|_| rng.below(fmt.levels() as usize) as u32)
                    .collect();
                let run = |k: kernel::Kernel| {
                    let _g = kernel::force(k);
                    let mut out = vec![0i64; batch * p];
                    let mut cb = vec![Counters::default(); batch];
                    lut.eval_batch(&codes, batch, &mut out, &mut cb);
                    (out, cb)
                };
                let (o_s, c_s) = run(kernel::Kernel::Scalar);
                let (o_v, c_v) = run(kernel::Kernel::Avx2);
                assert_eq!(o_s, o_v, "m={m} batch={batch}");
                assert_eq!(c_s, c_v, "m={m} batch={batch}");
            }
        }
    }

    #[test]
    fn wire_roundtrip_is_bit_exact() {
        let (p, q) = (4, 10);
        let (w, b, _) = random_case(p, q, 29);
        let fmt = FixedFormat::new(3);
        let lut =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        let mut buf = Vec::new();
        lut.write_wire(&mut buf, false);
        let back = DenseWholeLut::read_wire(
            &mut crate::lut::wire::Reader::new(&buf),
            &crate::lut::wire::WireCtx::v1(),
        )
        .unwrap();
        assert_eq!(back.partition, lut.partition);
        assert_eq!(back.fmt, lut.fmt);
        let mut rng = Rng::new(30);
        let codes: Vec<u32> =
            (0..q).map(|_| rng.below(fmt.levels() as usize) as u32).collect();
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        assert_eq!(lut.eval_codes(&codes, &mut c1), back.eval_codes(&codes, &mut c2));
        assert_eq!(c1, c2);
    }

    #[test]
    fn size_formula() {
        let (p, q) = (10, 8);
        let w = vec![0.0f32; p * q];
        let b = vec![0.0f32; p];
        let fmt = FixedFormat::new(3);
        let lut =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        // k=4 chunks of m=2 -> 4 * 2^(2*3) * 10 * 16 bits at r_O=16
        assert_eq!(lut.size_bits(16), 4 * 64 * 10 * 16);
    }

    #[test]
    fn arena_is_narrowed_for_typical_weights() {
        let (p, q) = (4, 8);
        let (w, b, _) = random_case(p, q, 23);
        let lut = DenseWholeLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FixedFormat::new(3),
        )
        .unwrap();
        // |entry| <= ~m * 2^32; typical |w| ~ 0.5 keeps entries in i32
        // often but not always — just assert the arena is coherent.
        let total = lut.arena().total_entries();
        assert_eq!(total, 4 * 64 * p);
        assert_eq!(lut.arena().row_len(), p);
    }

    #[test]
    fn rejects_oversized_tables() {
        let (p, q) = (10, 32);
        let w = vec![0.0f32; p * q];
        let b = vec![0.0f32; p];
        let fmt = FixedFormat::new(8);
        let err =
            DenseWholeLut::build(&w, &b, p, q, Partition::whole(q), fmt).unwrap_err();
        assert!(matches!(err, LutError::TooLarge { .. }));
    }
}
