//! Signed-input handling (paper §Dealing with signed numbers, Fig. 3).
//!
//! A two's-complement code `x` with MSB `s` represents `x_b - s·2^(n-1)`
//! where `x_b` is the magnitude bitstring. The paper's architecture
//! applies the *same* LUTs to the magnitude bitplanes and once more to
//! the MSB plane, shifting the MSB result left by `n-1` bits and
//! *subtracting* it. This wrapper implements exactly that on top of the
//! unsigned [`DenseBitplaneLut`].

use super::bitplane::DenseBitplaneLut;
use super::{LutError, Partition};
use crate::engine::counters::Counters;
use crate::quant::{FixedFormat, SignedFixedFormat};

/// Signed bitplane LUT: reuses one unsigned table bank for both the
/// magnitude planes and the sign plane.
#[derive(Debug)]
pub struct SignedBitplaneLut {
    pub fmt: SignedFixedFormat,
    inner: DenseBitplaneLut,
}

impl SignedBitplaneLut {
    pub fn build(
        w: &[f32],
        b: &[f32],
        p: usize,
        q: usize,
        partition: Partition,
        fmt: SignedFixedFormat,
    ) -> Result<Self, LutError> {
        // The inner bank is built for an unsigned (n-1)-bit magnitude
        // format over [0,1): code LSB = 2^-(n-1).
        let inner = DenseBitplaneLut::build(
            w,
            b,
            p,
            q,
            partition,
            FixedFormat::new(fmt.bits - 1),
        )?;
        Ok(SignedBitplaneLut { fmt, inner })
    }

    /// Evaluate `Wx + b` for signed values in [-1, 1).
    pub fn eval_f32(&self, x: &[f32], ctr: &mut Counters) -> Vec<i64> {
        let codes: Vec<u32> = x.iter().map(|&v| self.fmt.quantize(v)).collect();
        self.eval_codes(&codes, ctr)
    }

    /// Evaluate from two's-complement codes.
    pub fn eval_codes(&self, codes: &[u32], ctr: &mut Counters) -> Vec<i64> {
        let n = self.fmt.bits;
        // magnitude part: planes 0..n-1 via the unsigned bank
        let mag_codes: Vec<u32> =
            codes.iter().map(|&c| self.fmt.magnitude_bits(c)).collect();
        let mut acc = self.inner.eval_codes(&mag_codes, ctr);

        // sign part: feed the MSB plane through the SAME tables (the
        // paper's reuse), shift by n-1, subtract. We reuse eval_codes
        // with the MSB placed at plane 0, then shift the delta.
        let msb_codes: Vec<u32> = codes.iter().map(|&c| self.fmt.msb(c)).collect();
        // Build a zero-bias evaluation: eval includes the bias, so
        // subtract it back out before shifting.
        let msb_acc = self.inner.eval_codes(&msb_codes, ctr);
        let zero_acc = self.inner.eval_codes(&vec![0; codes.len()], ctr);
        for ((a, m), z) in acc.iter_mut().zip(&msb_acc).zip(&zero_acc) {
            let contrib = m - z; // pure W·msb at plane 0 scale
            *a -= contrib << (n - 1);
            ctr.shift_adds += 1;
        }
        acc
    }

    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.inner.size_bits(r_o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::from_acc;
    use crate::util::Rng;

    fn ref_affine(w: &[f32], b: &[f32], p: usize, q: usize, x: &[f32]) -> Vec<f32> {
        (0..p)
            .map(|o| b[o] + (0..q).map(|i| w[o * q + i] * x[i]).sum::<f32>())
            .collect()
    }

    #[test]
    fn matches_reference_on_signed_input() {
        let (p, q) = (5, 10);
        let mut rng = Rng::new(17);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let x: Vec<f32> = (0..q).map(|_| rng.range(-1.0, 1.0)).collect();
        let fmt = SignedFixedFormat::new(6);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.dequantize(fmt.quantize(v))).collect();
        let lut =
            SignedBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            assert!(
                (from_acc(a, 0) - want[o]).abs() < 1e-4,
                "{} vs {}",
                from_acc(a, 0),
                want[o]
            );
        }
        assert_eq!(ctr.mults, 0);
    }

    #[test]
    fn negative_only_input() {
        let (p, q) = (2, 4);
        let w = vec![1.0f32; p * q];
        let b = vec![0.0f32; p];
        let fmt = SignedFixedFormat::new(5);
        let x = vec![-0.5f32; q];
        let lut =
            SignedBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        for &a in &acc {
            assert!((from_acc(a, 0) + 2.0).abs() < 1e-4, "{}", from_acc(a, 0));
        }
    }

    #[test]
    fn nonnegative_input_matches_unsigned_bank() {
        // with MSB=0 everywhere the signed wrapper reduces to unsigned
        let (p, q) = (3, 6);
        let mut rng = Rng::new(23);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..q).map(|_| rng.f32() * 0.49).collect();
        let fmt = SignedFixedFormat::new(6);
        let lut =
            SignedBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 3), fmt)
                .unwrap();
        let mut ctr = Counters::default();
        let acc = lut.eval_f32(&x, &mut ctr);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.dequantize(fmt.quantize(v))).collect();
        let want = ref_affine(&w, &b, p, q, &xq);
        for (o, &a) in acc.iter().enumerate() {
            assert!((from_acc(a, 0) - want[o]).abs() < 1e-4);
        }
    }

    #[test]
    fn extremes_quantize_correctly() {
        let fmt = SignedFixedFormat::new(4);
        assert_eq!(fmt.dequantize(fmt.quantize(-1.0)), -1.0);
        let near_one = fmt.dequantize(fmt.quantize(0.999));
        assert!((near_one - 0.875).abs() < 1e-6); // 7/8 is the max code
    }
}
