//! Contiguous table arenas — the storage substrate of the batched,
//! table-stationary evaluation engine.
//!
//! Every LUT bank used to hold its per-chunk tables as boxed
//! `Vec<Vec<i64>>`: one heap allocation per chunk, 8 bytes per entry,
//! no locality between neighbouring chunks. The arena flattens a bank
//! into **one** allocation with per-chunk entry offsets, and *narrows*
//! entries to `i32` when every entry fits — half the bytes per cache
//! line on the row-gather hot path. Entry magnitudes usually do fit:
//! at `ACC_FRAC = 32` a fixed-point table entry is
//! `round(w · 2^(32-bits))`, within i32 for the weight scales the
//! trained models produce. When any entry does not fit (the float banks
//! at `FACC = 44` never do), the arena falls back to `i64` — the
//! overflow check is the narrowing itself, performed once at build
//! time; evaluation is generic over the entry width and bit-exact in
//! both (entries are widened to `i64` before accumulation).
//!
//! Storage is owned-or-borrowed ([`Entries`]): a freshly built or
//! v1-loaded arena owns a `Vec`, while an arena decoded from a mapped
//! v2 artifact *borrows* its entry block straight out of the mapping
//! (the v2 wire format 64-byte-aligns each entry block in the file
//! precisely so this reinterpretation is valid). Evaluation code never
//! sees the difference — both deref to the same `&[E]`.

use super::wire::{self, WireCtx};
use crate::bytes::ArtifactBytes;
use std::sync::Arc;

/// File alignment of v2 arena entry blocks: one cache line, which also
/// satisfies `align_of` for both entry widths.
pub const ENTRY_ALIGN: usize = 64;

/// An arena's entry block: owned on the heap, or borrowed zero-copy
/// from a mapped artifact kept alive by the `Arc`.
pub enum Entries<E> {
    Owned(Vec<E>),
    Borrowed {
        ptr: *const E,
        len: usize,
        _owner: Arc<ArtifactBytes>,
    },
}

// SAFETY: the borrowed region is an immutable PROT_READ mapping owned
// (transitively) by the Arc, so shared references from any thread are
// sound exactly as they are for the owned Vec.
unsafe impl<E: Send + Sync> Send for Entries<E> {}
unsafe impl<E: Send + Sync> Sync for Entries<E> {}

impl<E> std::ops::Deref for Entries<E> {
    type Target = [E];
    #[inline]
    fn deref(&self) -> &[E] {
        match self {
            Entries::Owned(v) => v,
            // SAFETY: constructed only by `read_entries` from a
            // bounds-checked, alignment-checked sub-slice of `_owner`,
            // which the Arc keeps alive for the life of `self`.
            Entries::Borrowed { ptr, len, .. } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
        }
    }
}

impl<E> std::fmt::Debug for Entries<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Entries::Owned(v) => write!(f, "Entries::Owned({} entries)", v.len()),
            Entries::Borrowed { len, .. } => {
                write!(f, "Entries::Borrowed({len} entries)")
            }
        }
    }
}

impl<E> Entries<E> {
    /// True when the entries are borrowed from a mapped artifact.
    pub fn is_borrowed(&self) -> bool {
        matches!(self, Entries::Borrowed { .. })
    }
}

/// Backing storage: narrowed (`i32`) when every entry fits, else `i64`.
#[derive(Debug)]
pub enum ArenaStore {
    I32(Entries<i32>),
    I64(Entries<i64>),
}

/// Diagnostics card of one arena's storage (surfaced per stage by
/// `tablenet inspect` and the serve banner).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaResidency {
    /// Entry-block bytes (heap-resident when owned, mapped when borrowed).
    pub bytes: usize,
    /// Entries narrowed to `i32`.
    pub narrow: bool,
    /// Borrowed zero-copy from a mapped artifact (false = owned copy).
    pub borrowed: bool,
}

/// One flat allocation holding every chunk's table back to back.
#[derive(Debug)]
pub struct TableArena {
    store: ArenaStore,
    /// Entry offset of chunk `c`'s table; `offsets[num_chunks]` = total.
    offsets: Vec<usize>,
    /// Entries per row (uniform within a bank: `p` for dense banks, the
    /// dilated patch size for conv banks).
    row_len: usize,
}

impl TableArena {
    /// Flatten per-chunk tables (entries in `i64` accumulator scale)
    /// into one arena, narrowing to `i32` when possible.
    pub fn from_tables(tables: &[Vec<i64>], row_len: usize) -> TableArena {
        let mut offsets = Vec::with_capacity(tables.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for t in tables {
            debug_assert_eq!(t.len() % row_len.max(1), 0);
            total += t.len();
            offsets.push(total);
        }
        let narrow = tables
            .iter()
            .flat_map(|t| t.iter())
            .all(|&v| i32::try_from(v).is_ok());
        let store = if narrow {
            let mut flat = Vec::with_capacity(total);
            for t in tables {
                flat.extend(t.iter().map(|&v| v as i32));
            }
            ArenaStore::I32(Entries::Owned(flat))
        } else {
            let mut flat = Vec::with_capacity(total);
            for t in tables {
                flat.extend_from_slice(t);
            }
            ArenaStore::I64(Entries::Owned(flat))
        };
        TableArena { store, offsets, row_len }
    }

    pub fn store(&self) -> &ArenaStore {
        &self.store
    }

    /// True when entries are stored narrowed to `i32`.
    pub fn is_narrow(&self) -> bool {
        matches!(self.store, ArenaStore::I32(_))
    }

    /// True when the entry block is borrowed from a mapped artifact
    /// rather than owned on the heap.
    pub fn is_borrowed(&self) -> bool {
        match &self.store {
            ArenaStore::I32(e) => e.is_borrowed(),
            ArenaStore::I64(e) => e.is_borrowed(),
        }
    }

    /// Storage diagnostics: bytes, width, owned-vs-borrowed.
    pub fn residency(&self) -> ArenaResidency {
        ArenaResidency {
            bytes: self.resident_bytes(),
            narrow: self.is_narrow(),
            borrowed: self.is_borrowed(),
        }
    }

    pub fn num_chunks(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Total entries across all chunks.
    pub fn total_entries(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Entries in chunk `c`'s table.
    pub fn chunk_entries(&self, c: usize) -> usize {
        self.offsets[c + 1] - self.offsets[c]
    }

    /// Rows in chunk `c`'s table.
    pub fn chunk_rows(&self, c: usize) -> usize {
        self.chunk_entries(c) / self.row_len
    }

    /// Chunk `c`'s table as a typed slice; `E` must match the store
    /// width (banks dispatch on [`TableArena::store`] once per call).
    #[inline]
    pub fn chunk_slice<E: ArenaEntry>(&self, c: usize) -> &[E] {
        &E::entries(&self.store)[self.offsets[c]..self.offsets[c + 1]]
    }

    /// Chunk `c`'s table as a row-addressable view — the lane-friendly
    /// accessor the SIMD and scalar hot loops share: one bounds-checked
    /// slice per chunk up front, then `row(idx)` per gathered index
    /// instead of re-slicing the arena each time.
    #[inline]
    pub fn chunk_table<E: ArenaEntry>(&self, c: usize) -> ChunkTable<'_, E> {
        ChunkTable {
            entries: self.chunk_slice::<E>(c),
            row_len: self.row_len,
        }
    }

    /// Entry-block bytes of the arena (diagnostics / DESIGN
    /// accounting). Heap-resident when owned; mapped when borrowed.
    pub fn resident_bytes(&self) -> usize {
        match &self.store {
            ArenaStore::I32(v) => v.len() * 4,
            ArenaStore::I64(v) => v.len() * 8,
        }
    }

    /// Entry at flat index `i`, widened (tests / debugging).
    pub fn entry(&self, i: usize) -> i64 {
        match &self.store {
            ArenaStore::I32(v) => v[i] as i64,
            ArenaStore::I64(v) => v[i],
        }
    }

    /// Serialize the arena (store width preserved — the round-trip is
    /// bit-exact, including the i32-vs-i64 narrowing decision).
    ///
    /// With `aligned` (artifact v2), an explicit pad (one length byte +
    /// zeros) precedes the entry block so it starts on an
    /// [`ENTRY_ALIGN`]-byte boundary of `out` — callers write payloads
    /// directly into the container buffer, so offsets in `out` ARE file
    /// offsets and a mapped load can borrow the block in place.
    pub fn write_wire(&self, out: &mut Vec<u8>, aligned: bool) {
        wire::put_u64(out, self.row_len as u64);
        wire::put_u64(out, self.offsets.len() as u64);
        for &o in &self.offsets {
            wire::put_u64(out, o as u64);
        }
        match &self.store {
            ArenaStore::I32(v) => {
                wire::put_u8(out, 0);
                wire::put_u64(out, v.len() as u64);
                if aligned {
                    write_align_gap(out);
                }
                for &e in v.iter() {
                    wire::put_i32(out, e);
                }
            }
            ArenaStore::I64(v) => {
                wire::put_u8(out, 1);
                wire::put_u64(out, v.len() as u64);
                if aligned {
                    write_align_gap(out);
                }
                for &e in v.iter() {
                    wire::put_i64(out, e);
                }
            }
        }
    }

    /// Deserialize an arena written by [`TableArena::write_wire`]. With
    /// `ctx.backing` set (a mapped v2 artifact), the entry block is
    /// borrowed zero-copy when its alignment permits; otherwise it is
    /// copied onto the heap — bit-exact either way.
    pub fn read_wire(r: &mut wire::Reader, ctx: &WireCtx) -> wire::Result<TableArena> {
        // cap: entries bounded by the materialisation limit (i32 floor)
        let entry_cap = super::MAX_TABLE_BYTES / 4;
        let row_len = r.len_capped(entry_cap, "arena row_len")?;
        if row_len == 0 {
            // chunk_rows divides by row_len; banks never build empty rows
            return wire::err("arena row_len must be >= 1");
        }
        let n_off = r.len_capped(entry_cap, "arena offsets")?;
        if n_off == 0 {
            return wire::err("arena needs at least one offset");
        }
        let mut offsets = Vec::with_capacity(n_off);
        for _ in 0..n_off {
            offsets.push(r.len_capped(entry_cap, "arena offset")?);
        }
        if offsets[0] != 0 || offsets.windows(2).any(|w| w[0] > w[1]) {
            return wire::err("arena offsets must start at 0 and be non-decreasing");
        }
        let tag = r.u8()?;
        let total = r.len_capped(entry_cap, "arena entries")?;
        if total != *offsets.last().unwrap() {
            return wire::err("arena entry count disagrees with offsets");
        }
        if total % row_len != 0 {
            return wire::err("arena entries not divisible by row_len");
        }
        let store = match tag {
            0 => ArenaStore::I32(read_entries::<i32>(r, total, ctx)?),
            1 => ArenaStore::I64(read_entries::<i64>(r, total, ctx)?),
            other => return wire::err(format!("unknown arena store tag {other}")),
        };
        Ok(TableArena { store, offsets, row_len })
    }
}

/// Write the v2 alignment gap: one pad-length byte followed by that
/// many zeros, sized so the next byte of `out` lands on an
/// [`ENTRY_ALIGN`] boundary.
fn write_align_gap(out: &mut Vec<u8>) {
    let pad = (ENTRY_ALIGN - (out.len() + 1) % ENTRY_ALIGN) % ENTRY_ALIGN;
    wire::put_u8(out, pad as u8);
    out.resize(out.len() + pad, 0);
}

/// Decode `total` entries: skip the v2 alignment gap when present, then
/// either borrow the block from the mapped backing (zero-copy — the
/// fast path every serving load takes) or bulk-copy it onto the heap.
fn read_entries<E: ArenaEntry>(
    r: &mut wire::Reader,
    total: usize,
    ctx: &WireCtx,
) -> wire::Result<Entries<E>> {
    if ctx.aligned {
        let pad = r.u8()? as usize;
        if pad >= ENTRY_ALIGN {
            return wire::err(format!("arena alignment gap {pad} out of range"));
        }
        r.take(pad)?;
    }
    let bytes = r.take(total * std::mem::size_of::<E>())?;
    if let Some(owner) = ctx.backing {
        // entries are little-endian on disk: in-place reinterpretation
        // is valid only on LE targets with a properly aligned block.
        // `ctx.aligned` gates the borrow to v2 payloads — a v1 block
        // could be fortuitously aligned, but the v1 contract is "always
        // copies" (asserted by the compatibility matrix), and only v2
        // GUARANTEES the alignment rather than inheriting it by luck.
        if ctx.aligned
            && cfg!(target_endian = "little")
            && (bytes.as_ptr() as usize) % std::mem::align_of::<E>() == 0
            && owner.contains(bytes)
        {
            return Ok(Entries::Borrowed {
                ptr: bytes.as_ptr() as *const E,
                len: total,
                _owner: Arc::clone(owner),
            });
        }
    }
    // bulk decode: one bounds check for the whole entry block, then
    // chunked conversion — arenas dominate artifact size, and the
    // copying start-up path loads hundreds of MiB through here
    let mut v = Vec::with_capacity(total);
    v.extend(bytes.chunks_exact(std::mem::size_of::<E>()).map(E::from_le));
    Ok(Entries::Owned(v))
}

/// Row-addressable view of one chunk's table, shared by the scalar and
/// SIMD hot loops (see [`TableArena::chunk_table`]). Indexing does one
/// slice per row; the entry block itself was bounds-checked once when
/// the view was built.
#[derive(Clone, Copy)]
pub struct ChunkTable<'a, E> {
    pub(crate) entries: &'a [E],
    pub(crate) row_len: usize,
}

impl<'a, E: ArenaEntry> ChunkTable<'a, E> {
    /// Row `idx` of the table (`row_len` entries).
    #[inline(always)]
    pub fn row(&self, idx: usize) -> &'a [E] {
        &self.entries[idx * self.row_len..(idx + 1) * self.row_len]
    }

    /// The whole entry block, row-major.
    #[inline]
    pub fn entries(&self) -> &'a [E] {
        self.entries
    }

    /// Number of rows in this chunk's table.
    #[inline]
    pub fn rows(&self) -> usize {
        self.entries.len() / self.row_len
    }
}

/// Entry width the evaluation loops are generic over.
pub trait ArenaEntry: Copy + Send + Sync + 'static {
    fn widen(self) -> i64;
    fn entries(store: &ArenaStore) -> &[Self];
    /// Decode one entry from its little-endian wire bytes
    /// (`size_of::<Self>()` of them).
    fn from_le(bytes: &[u8]) -> Self;
}

impl ArenaEntry for i32 {
    #[inline(always)]
    fn widen(self) -> i64 {
        self as i64
    }
    #[inline]
    fn entries(store: &ArenaStore) -> &[i32] {
        match store {
            ArenaStore::I32(v) => v,
            ArenaStore::I64(_) => unreachable!("arena width mismatch: want i32"),
        }
    }
    #[inline]
    fn from_le(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl ArenaEntry for i64 {
    #[inline(always)]
    fn widen(self) -> i64 {
        self
    }
    #[inline]
    fn entries(store: &ArenaStore) -> &[i64] {
        match store {
            ArenaStore::I64(v) => v,
            ArenaStore::I32(_) => unreachable!("arena width mismatch: want i64"),
        }
    }
    #[inline]
    fn from_le(b: &[u8]) -> i64 {
        i64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

/// Dispatch an expression over the arena's entry width. Usage:
/// `with_arena!(self.arena, E => self.eval_impl::<E>(args))`.
macro_rules! with_arena {
    ($arena:expr, $E:ident => $body:expr) => {
        match $arena.store() {
            $crate::lut::arena::ArenaStore::I32(_) => {
                type $E = i32;
                $body
            }
            $crate::lut::arena::ArenaStore::I64(_) => {
                type $E = i64;
                $body
            }
        }
    };
}
pub(crate) use with_arena;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn narrows_when_entries_fit() {
        let tables = vec![vec![1i64, -2, 3, 4], vec![5, 6]];
        let a = TableArena::from_tables(&tables, 2);
        assert!(a.is_narrow());
        assert!(!a.is_borrowed());
        assert_eq!(a.num_chunks(), 2);
        assert_eq!(a.total_entries(), 6);
        assert_eq!(a.chunk_rows(0), 2);
        assert_eq!(a.chunk_rows(1), 1);
        assert_eq!(a.chunk_slice::<i32>(1), &[5, 6]);
        assert_eq!(a.entry(1), -2);
        assert_eq!(a.resident_bytes(), 24);
        assert_eq!(
            a.residency(),
            ArenaResidency { bytes: 24, narrow: true, borrowed: false }
        );
    }

    #[test]
    fn falls_back_to_i64_on_wide_entries() {
        let wide = i64::from(i32::MAX) + 1;
        let tables = vec![vec![0i64, wide]];
        let a = TableArena::from_tables(&tables, 1);
        assert!(!a.is_narrow());
        assert_eq!(a.chunk_slice::<i64>(0), &[0, wide]);
        assert_eq!(a.entry(1), wide);
        assert_eq!(a.resident_bytes(), 16);
    }

    #[test]
    fn negative_extreme_still_narrow() {
        let tables = vec![vec![i64::from(i32::MIN), i64::from(i32::MAX)]];
        let a = TableArena::from_tables(&tables, 1);
        assert!(a.is_narrow());
        assert_eq!(a.entry(0), i64::from(i32::MIN));
    }

    #[test]
    fn widen_roundtrips() {
        assert_eq!((-7i32).widen(), -7i64);
        assert_eq!(7i64.widen(), 7);
    }

    #[test]
    fn wire_roundtrip_preserves_store_width() {
        for aligned in [false, true] {
            for tables in [
                vec![vec![1i64, -2, 3, 4], vec![5, 6]],
                vec![vec![0i64, i64::from(i32::MAX) + 1]],
            ] {
                let row_len = tables[0].len().min(2);
                let a = TableArena::from_tables(&tables, row_len);
                let mut buf = Vec::new();
                a.write_wire(&mut buf, aligned);
                let ctx = if aligned { WireCtx::v2_copying() } else { WireCtx::v1() };
                let back =
                    TableArena::read_wire(&mut wire::Reader::new(&buf), &ctx).unwrap();
                assert_eq!(back.is_narrow(), a.is_narrow());
                assert_eq!(back.row_len(), a.row_len());
                assert_eq!(back.num_chunks(), a.num_chunks());
                for i in 0..a.total_entries() {
                    assert_eq!(back.entry(i), a.entry(i));
                }
            }
        }
    }

    #[test]
    fn aligned_write_lands_entries_on_boundary() {
        // whatever prefix length the container has written, the entry
        // block must start at a multiple of ENTRY_ALIGN of the buffer
        let a = TableArena::from_tables(&[vec![7i64; 32]], 4);
        for prefix in [0usize, 1, 7, 63, 64, 100] {
            let mut buf = vec![0xEEu8; prefix];
            a.write_wire(&mut buf, true);
            // entry block is the last 32*4 bytes (i32-narrowed)
            let start = buf.len() - 32 * 4;
            assert_eq!(start % ENTRY_ALIGN, 0, "prefix {prefix}: start {start}");
            // and it still decodes (reader consumes the explicit gap)
            let mut r = wire::Reader::new(&buf[prefix..]);
            let back = TableArena::read_wire(&mut r, &WireCtx::v2_copying()).unwrap();
            assert_eq!(back.total_entries(), 32);
            assert_eq!(back.entry(13), 7);
        }
    }

    #[test]
    fn mapped_backing_is_borrowed_zero_copy() {
        let tables = vec![vec![11i64, -22, 33, -44], vec![55, 66]];
        let a = TableArena::from_tables(&tables, 2);
        let mut buf = Vec::new();
        a.write_wire(&mut buf, true);
        // stand in for a mapped file: an Arc-owned buffer the decoder
        // is told it may borrow from (alignment decides eligibility)
        let owner = Arc::new(ArtifactBytes::Owned(buf));
        let bytes: &[u8] = &owner;
        // borrow requires the entry block aligned within this buffer;
        // Vec<u8> gives no alignment guarantee, so accept either
        // outcome but demand bit-exactness, and demand BORROWED when
        // the block alignment cooperates
        let ctx = WireCtx { aligned: true, backing: Some(&owner) };
        let back = TableArena::read_wire(&mut wire::Reader::new(bytes), &ctx).unwrap();
        for i in 0..a.total_entries() {
            assert_eq!(back.entry(i), a.entry(i));
        }
        let block_ptr = bytes[bytes.len() - 24..].as_ptr() as usize;
        if cfg!(target_endian = "little") && block_ptr % 4 == 0 {
            assert!(back.is_borrowed(), "aligned mapped block must be borrowed");
        }
        // without backing, the same bytes decode through the copy path
        let copied = TableArena::read_wire(
            &mut wire::Reader::new(bytes),
            &WireCtx::v2_copying(),
        )
        .unwrap();
        assert!(!copied.is_borrowed());
        assert_eq!(copied.entry(3), -44);
    }

    #[test]
    fn wire_rejects_truncation() {
        let a = TableArena::from_tables(&[vec![1i64, 2, 3, 4]], 2);
        for aligned in [false, true] {
            let mut buf = Vec::new();
            a.write_wire(&mut buf, aligned);
            buf.truncate(buf.len() - 3);
            let ctx = if aligned { WireCtx::v2_copying() } else { WireCtx::v1() };
            assert!(TableArena::read_wire(&mut wire::Reader::new(&buf), &ctx).is_err());
        }
    }

    #[test]
    fn dispatch_macro_selects_width() {
        let a = TableArena::from_tables(&[vec![1i64, 2]], 1);
        let total = with_arena!(a, E => {
            a.chunk_slice::<E>(0).iter().map(|e| e.widen()).sum::<i64>()
        });
        assert_eq!(total, 3);
    }
}
