//! Contiguous table arenas — the storage substrate of the batched,
//! table-stationary evaluation engine.
//!
//! Every LUT bank used to hold its per-chunk tables as boxed
//! `Vec<Vec<i64>>`: one heap allocation per chunk, 8 bytes per entry,
//! no locality between neighbouring chunks. The arena flattens a bank
//! into **one** allocation with per-chunk entry offsets, and *narrows*
//! entries to `i32` when every entry fits — half the bytes per cache
//! line on the row-gather hot path. Entry magnitudes usually do fit:
//! at `ACC_FRAC = 32` a fixed-point table entry is
//! `round(w · 2^(32-bits))`, within i32 for the weight scales the
//! trained models produce. When any entry does not fit (the float banks
//! at `FACC = 44` never do), the arena falls back to `i64` — the
//! overflow check is the narrowing itself, performed once at build
//! time; evaluation is generic over the entry width and bit-exact in
//! both (entries are widened to `i64` before accumulation).

/// Backing storage: narrowed (`i32`) when every entry fits, else `i64`.
#[derive(Debug)]
pub enum ArenaStore {
    I32(Vec<i32>),
    I64(Vec<i64>),
}

/// One flat allocation holding every chunk's table back to back.
#[derive(Debug)]
pub struct TableArena {
    store: ArenaStore,
    /// Entry offset of chunk `c`'s table; `offsets[num_chunks]` = total.
    offsets: Vec<usize>,
    /// Entries per row (uniform within a bank: `p` for dense banks, the
    /// dilated patch size for conv banks).
    row_len: usize,
}

impl TableArena {
    /// Flatten per-chunk tables (entries in `i64` accumulator scale)
    /// into one arena, narrowing to `i32` when possible.
    pub fn from_tables(tables: &[Vec<i64>], row_len: usize) -> TableArena {
        let mut offsets = Vec::with_capacity(tables.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for t in tables {
            debug_assert_eq!(t.len() % row_len.max(1), 0);
            total += t.len();
            offsets.push(total);
        }
        let narrow = tables
            .iter()
            .flat_map(|t| t.iter())
            .all(|&v| i32::try_from(v).is_ok());
        let store = if narrow {
            let mut flat = Vec::with_capacity(total);
            for t in tables {
                flat.extend(t.iter().map(|&v| v as i32));
            }
            ArenaStore::I32(flat)
        } else {
            let mut flat = Vec::with_capacity(total);
            for t in tables {
                flat.extend_from_slice(t);
            }
            ArenaStore::I64(flat)
        };
        TableArena { store, offsets, row_len }
    }

    pub fn store(&self) -> &ArenaStore {
        &self.store
    }

    /// True when entries are stored narrowed to `i32`.
    pub fn is_narrow(&self) -> bool {
        matches!(self.store, ArenaStore::I32(_))
    }

    pub fn num_chunks(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Total entries across all chunks.
    pub fn total_entries(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Entries in chunk `c`'s table.
    pub fn chunk_entries(&self, c: usize) -> usize {
        self.offsets[c + 1] - self.offsets[c]
    }

    /// Rows in chunk `c`'s table.
    pub fn chunk_rows(&self, c: usize) -> usize {
        self.chunk_entries(c) / self.row_len
    }

    /// Chunk `c`'s table as a typed slice; `E` must match the store
    /// width (banks dispatch on [`TableArena::store`] once per call).
    #[inline]
    pub fn chunk_slice<E: ArenaEntry>(&self, c: usize) -> &[E] {
        &E::entries(&self.store)[self.offsets[c]..self.offsets[c + 1]]
    }

    /// Resident bytes of the arena (diagnostics / DESIGN accounting).
    pub fn resident_bytes(&self) -> usize {
        match &self.store {
            ArenaStore::I32(v) => v.len() * 4,
            ArenaStore::I64(v) => v.len() * 8,
        }
    }

    /// Entry at flat index `i`, widened (tests / debugging).
    pub fn entry(&self, i: usize) -> i64 {
        match &self.store {
            ArenaStore::I32(v) => v[i] as i64,
            ArenaStore::I64(v) => v[i],
        }
    }
}

/// Entry width the evaluation loops are generic over.
pub trait ArenaEntry: Copy + 'static {
    fn widen(self) -> i64;
    fn entries(store: &ArenaStore) -> &[Self];
}

impl ArenaEntry for i32 {
    #[inline(always)]
    fn widen(self) -> i64 {
        self as i64
    }
    #[inline]
    fn entries(store: &ArenaStore) -> &[i32] {
        match store {
            ArenaStore::I32(v) => v,
            ArenaStore::I64(_) => unreachable!("arena width mismatch: want i32"),
        }
    }
}

impl ArenaEntry for i64 {
    #[inline(always)]
    fn widen(self) -> i64 {
        self
    }
    #[inline]
    fn entries(store: &ArenaStore) -> &[i64] {
        match store {
            ArenaStore::I64(v) => v,
            ArenaStore::I32(_) => unreachable!("arena width mismatch: want i64"),
        }
    }
}

/// Dispatch an expression over the arena's entry width. Usage:
/// `with_arena!(self.arena, E => self.eval_impl::<E>(args))`.
macro_rules! with_arena {
    ($arena:expr, $E:ident => $body:expr) => {
        match $arena.store() {
            $crate::lut::arena::ArenaStore::I32(_) => {
                type $E = i32;
                $body
            }
            $crate::lut::arena::ArenaStore::I64(_) => {
                type $E = i64;
                $body
            }
        }
    };
}
pub(crate) use with_arena;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn narrows_when_entries_fit() {
        let tables = vec![vec![1i64, -2, 3, 4], vec![5, 6]];
        let a = TableArena::from_tables(&tables, 2);
        assert!(a.is_narrow());
        assert_eq!(a.num_chunks(), 2);
        assert_eq!(a.total_entries(), 6);
        assert_eq!(a.chunk_rows(0), 2);
        assert_eq!(a.chunk_rows(1), 1);
        assert_eq!(a.chunk_slice::<i32>(1), &[5, 6]);
        assert_eq!(a.entry(1), -2);
        assert_eq!(a.resident_bytes(), 24);
    }

    #[test]
    fn falls_back_to_i64_on_wide_entries() {
        let wide = i64::from(i32::MAX) + 1;
        let tables = vec![vec![0i64, wide]];
        let a = TableArena::from_tables(&tables, 1);
        assert!(!a.is_narrow());
        assert_eq!(a.chunk_slice::<i64>(0), &[0, wide]);
        assert_eq!(a.entry(1), wide);
        assert_eq!(a.resident_bytes(), 16);
    }

    #[test]
    fn negative_extreme_still_narrow() {
        let tables = vec![vec![i64::from(i32::MIN), i64::from(i32::MAX)]];
        let a = TableArena::from_tables(&tables, 1);
        assert!(a.is_narrow());
        assert_eq!(a.entry(0), i64::from(i32::MIN));
    }

    #[test]
    fn widen_roundtrips() {
        assert_eq!((-7i32).widen(), -7i64);
        assert_eq!(7i64.widen(), 7);
    }

    #[test]
    fn dispatch_macro_selects_width() {
        let a = TableArena::from_tables(&[vec![1i64, 2]], 1);
        let total = with_arena!(a, E => {
            a.chunk_slice::<E>(0).iter().map(|e| e.widen()).sum::<i64>()
        });
        assert_eq!(total, 3);
    }
}
