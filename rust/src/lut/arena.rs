//! Contiguous table arenas — the storage substrate of the batched,
//! table-stationary evaluation engine.
//!
//! Every LUT bank used to hold its per-chunk tables as boxed
//! `Vec<Vec<i64>>`: one heap allocation per chunk, 8 bytes per entry,
//! no locality between neighbouring chunks. The arena flattens a bank
//! into **one** allocation with per-chunk entry offsets, and *narrows*
//! entries to `i32` when every entry fits — half the bytes per cache
//! line on the row-gather hot path. Entry magnitudes usually do fit:
//! at `ACC_FRAC = 32` a fixed-point table entry is
//! `round(w · 2^(32-bits))`, within i32 for the weight scales the
//! trained models produce. When any entry does not fit (the float banks
//! at `FACC = 44` never do), the arena falls back to `i64` — the
//! overflow check is the narrowing itself, performed once at build
//! time; evaluation is generic over the entry width and bit-exact in
//! both (entries are widened to `i64` before accumulation).

use super::wire;

/// Backing storage: narrowed (`i32`) when every entry fits, else `i64`.
#[derive(Debug)]
pub enum ArenaStore {
    I32(Vec<i32>),
    I64(Vec<i64>),
}

/// One flat allocation holding every chunk's table back to back.
#[derive(Debug)]
pub struct TableArena {
    store: ArenaStore,
    /// Entry offset of chunk `c`'s table; `offsets[num_chunks]` = total.
    offsets: Vec<usize>,
    /// Entries per row (uniform within a bank: `p` for dense banks, the
    /// dilated patch size for conv banks).
    row_len: usize,
}

impl TableArena {
    /// Flatten per-chunk tables (entries in `i64` accumulator scale)
    /// into one arena, narrowing to `i32` when possible.
    pub fn from_tables(tables: &[Vec<i64>], row_len: usize) -> TableArena {
        let mut offsets = Vec::with_capacity(tables.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for t in tables {
            debug_assert_eq!(t.len() % row_len.max(1), 0);
            total += t.len();
            offsets.push(total);
        }
        let narrow = tables
            .iter()
            .flat_map(|t| t.iter())
            .all(|&v| i32::try_from(v).is_ok());
        let store = if narrow {
            let mut flat = Vec::with_capacity(total);
            for t in tables {
                flat.extend(t.iter().map(|&v| v as i32));
            }
            ArenaStore::I32(flat)
        } else {
            let mut flat = Vec::with_capacity(total);
            for t in tables {
                flat.extend_from_slice(t);
            }
            ArenaStore::I64(flat)
        };
        TableArena { store, offsets, row_len }
    }

    pub fn store(&self) -> &ArenaStore {
        &self.store
    }

    /// True when entries are stored narrowed to `i32`.
    pub fn is_narrow(&self) -> bool {
        matches!(self.store, ArenaStore::I32(_))
    }

    pub fn num_chunks(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Total entries across all chunks.
    pub fn total_entries(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Entries in chunk `c`'s table.
    pub fn chunk_entries(&self, c: usize) -> usize {
        self.offsets[c + 1] - self.offsets[c]
    }

    /// Rows in chunk `c`'s table.
    pub fn chunk_rows(&self, c: usize) -> usize {
        self.chunk_entries(c) / self.row_len
    }

    /// Chunk `c`'s table as a typed slice; `E` must match the store
    /// width (banks dispatch on [`TableArena::store`] once per call).
    #[inline]
    pub fn chunk_slice<E: ArenaEntry>(&self, c: usize) -> &[E] {
        &E::entries(&self.store)[self.offsets[c]..self.offsets[c + 1]]
    }

    /// Resident bytes of the arena (diagnostics / DESIGN accounting).
    pub fn resident_bytes(&self) -> usize {
        match &self.store {
            ArenaStore::I32(v) => v.len() * 4,
            ArenaStore::I64(v) => v.len() * 8,
        }
    }

    /// Entry at flat index `i`, widened (tests / debugging).
    pub fn entry(&self, i: usize) -> i64 {
        match &self.store {
            ArenaStore::I32(v) => v[i] as i64,
            ArenaStore::I64(v) => v[i],
        }
    }

    /// Serialize the arena (store width preserved — the round-trip is
    /// bit-exact, including the i32-vs-i64 narrowing decision).
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        wire::put_u64(out, self.row_len as u64);
        wire::put_u64(out, self.offsets.len() as u64);
        for &o in &self.offsets {
            wire::put_u64(out, o as u64);
        }
        match &self.store {
            ArenaStore::I32(v) => {
                wire::put_u8(out, 0);
                wire::put_u64(out, v.len() as u64);
                for &e in v {
                    wire::put_i32(out, e);
                }
            }
            ArenaStore::I64(v) => {
                wire::put_u8(out, 1);
                wire::put_u64(out, v.len() as u64);
                for &e in v {
                    wire::put_i64(out, e);
                }
            }
        }
    }

    /// Deserialize an arena written by [`TableArena::write_wire`].
    pub fn read_wire(r: &mut wire::Reader) -> wire::Result<TableArena> {
        // cap: entries bounded by the materialisation limit (i32 floor)
        let entry_cap = super::MAX_TABLE_BYTES / 4;
        let row_len = r.len_capped(entry_cap, "arena row_len")?;
        if row_len == 0 {
            // chunk_rows divides by row_len; banks never build empty rows
            return wire::err("arena row_len must be >= 1");
        }
        let n_off = r.len_capped(entry_cap, "arena offsets")?;
        if n_off == 0 {
            return wire::err("arena needs at least one offset");
        }
        let mut offsets = Vec::with_capacity(n_off);
        for _ in 0..n_off {
            offsets.push(r.len_capped(entry_cap, "arena offset")?);
        }
        if offsets[0] != 0 || offsets.windows(2).any(|w| w[0] > w[1]) {
            return wire::err("arena offsets must start at 0 and be non-decreasing");
        }
        let tag = r.u8()?;
        let total = r.len_capped(entry_cap, "arena entries")?;
        if total != *offsets.last().unwrap() {
            return wire::err("arena entry count disagrees with offsets");
        }
        if total % row_len != 0 {
            return wire::err("arena entries not divisible by row_len");
        }
        // bulk decode: one bounds check for the whole entry block, then
        // chunked conversion — arenas dominate artifact size, and the
        // deployment start-up path loads hundreds of MiB through here
        let store = match tag {
            0 => {
                let bytes = r.take(total * 4)?;
                let mut v = Vec::with_capacity(total);
                v.extend(
                    bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])),
                );
                ArenaStore::I32(v)
            }
            1 => {
                let bytes = r.take(total * 8)?;
                let mut v = Vec::with_capacity(total);
                v.extend(bytes.chunks_exact(8).map(|c| {
                    i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                }));
                ArenaStore::I64(v)
            }
            other => return wire::err(format!("unknown arena store tag {other}")),
        };
        Ok(TableArena { store, offsets, row_len })
    }
}

/// Entry width the evaluation loops are generic over.
pub trait ArenaEntry: Copy + 'static {
    fn widen(self) -> i64;
    fn entries(store: &ArenaStore) -> &[Self];
}

impl ArenaEntry for i32 {
    #[inline(always)]
    fn widen(self) -> i64 {
        self as i64
    }
    #[inline]
    fn entries(store: &ArenaStore) -> &[i32] {
        match store {
            ArenaStore::I32(v) => v,
            ArenaStore::I64(_) => unreachable!("arena width mismatch: want i32"),
        }
    }
}

impl ArenaEntry for i64 {
    #[inline(always)]
    fn widen(self) -> i64 {
        self
    }
    #[inline]
    fn entries(store: &ArenaStore) -> &[i64] {
        match store {
            ArenaStore::I64(v) => v,
            ArenaStore::I32(_) => unreachable!("arena width mismatch: want i64"),
        }
    }
}

/// Dispatch an expression over the arena's entry width. Usage:
/// `with_arena!(self.arena, E => self.eval_impl::<E>(args))`.
macro_rules! with_arena {
    ($arena:expr, $E:ident => $body:expr) => {
        match $arena.store() {
            $crate::lut::arena::ArenaStore::I32(_) => {
                type $E = i32;
                $body
            }
            $crate::lut::arena::ArenaStore::I64(_) => {
                type $E = i64;
                $body
            }
        }
    };
}
pub(crate) use with_arena;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn narrows_when_entries_fit() {
        let tables = vec![vec![1i64, -2, 3, 4], vec![5, 6]];
        let a = TableArena::from_tables(&tables, 2);
        assert!(a.is_narrow());
        assert_eq!(a.num_chunks(), 2);
        assert_eq!(a.total_entries(), 6);
        assert_eq!(a.chunk_rows(0), 2);
        assert_eq!(a.chunk_rows(1), 1);
        assert_eq!(a.chunk_slice::<i32>(1), &[5, 6]);
        assert_eq!(a.entry(1), -2);
        assert_eq!(a.resident_bytes(), 24);
    }

    #[test]
    fn falls_back_to_i64_on_wide_entries() {
        let wide = i64::from(i32::MAX) + 1;
        let tables = vec![vec![0i64, wide]];
        let a = TableArena::from_tables(&tables, 1);
        assert!(!a.is_narrow());
        assert_eq!(a.chunk_slice::<i64>(0), &[0, wide]);
        assert_eq!(a.entry(1), wide);
        assert_eq!(a.resident_bytes(), 16);
    }

    #[test]
    fn negative_extreme_still_narrow() {
        let tables = vec![vec![i64::from(i32::MIN), i64::from(i32::MAX)]];
        let a = TableArena::from_tables(&tables, 1);
        assert!(a.is_narrow());
        assert_eq!(a.entry(0), i64::from(i32::MIN));
    }

    #[test]
    fn widen_roundtrips() {
        assert_eq!((-7i32).widen(), -7i64);
        assert_eq!(7i64.widen(), 7);
    }

    #[test]
    fn wire_roundtrip_preserves_store_width() {
        for tables in [
            vec![vec![1i64, -2, 3, 4], vec![5, 6]],
            vec![vec![0i64, i64::from(i32::MAX) + 1]],
        ] {
            let row_len = tables[0].len().min(2);
            let a = TableArena::from_tables(&tables, row_len);
            let mut buf = Vec::new();
            a.write_wire(&mut buf);
            let back = TableArena::read_wire(&mut wire::Reader::new(&buf)).unwrap();
            assert_eq!(back.is_narrow(), a.is_narrow());
            assert_eq!(back.row_len(), a.row_len());
            assert_eq!(back.num_chunks(), a.num_chunks());
            for i in 0..a.total_entries() {
                assert_eq!(back.entry(i), a.entry(i));
            }
        }
    }

    #[test]
    fn wire_rejects_truncation() {
        let a = TableArena::from_tables(&[vec![1i64, 2, 3, 4]], 2);
        let mut buf = Vec::new();
        a.write_wire(&mut buf);
        buf.truncate(buf.len() - 3);
        assert!(TableArena::read_wire(&mut wire::Reader::new(&buf)).is_err());
    }

    #[test]
    fn dispatch_macro_selects_width() {
        let a = TableArena::from_tables(&[vec![1i64, 2]], 1);
        let total = with_arena!(a, E => {
            a.chunk_slice::<E>(0).iter().map(|e| e.widen()).sum::<i64>()
        });
        assert_eq!(total, 3);
    }
}
