//! Dataset substrate: loads real MNIST-format IDX files when present,
//! otherwise generates + caches the deterministic synthetic corpus (see
//! [`synth`] and DESIGN.md §Substitutions). All consumers — the Rust
//! trainer, the engine harness and the JAX training path — read the same
//! IDX files, so the corpora are identical across languages.

pub mod idx;
pub mod synth;

use anyhow::{Context, Result};
use std::path::Path;
use synth::{Kind, IMG};

/// An in-memory split: f32 pixels in [0,1], row-major [n, 784].
#[derive(Debug, Clone)]
pub struct Split {
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
}

impl Split {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG * IMG..(i + 1) * IMG * IMG]
    }

    /// First `n` samples as a sub-split (cheap eval subsets).
    pub fn head(&self, n: usize) -> Split {
        let n = n.min(self.len());
        Split {
            images: self.images[..n * IMG * IMG].to_vec(),
            labels: self.labels[..n].to_vec(),
        }
    }

    fn from_u8(pixels: &[u8], labels: &[u8]) -> Split {
        Split {
            images: pixels.iter().map(|&v| v as f32 / 255.0).collect(),
            labels: labels.iter().map(|&l| l as usize).collect(),
        }
    }
}

/// Train + test splits.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: Kind,
    pub train: Split,
    pub test: Split,
}

/// File names used under the data dir (MNIST's own naming, so real
/// MNIST files can be dropped in directly).
fn file_names(kind: Kind) -> [String; 4] {
    let prefix = match kind {
        Kind::Digits => "",
        Kind::Fashion => "fashion-",
    };
    [
        format!("{prefix}train-images-idx3-ubyte"),
        format!("{prefix}train-labels-idx1-ubyte"),
        format!("{prefix}t10k-images-idx3-ubyte"),
        format!("{prefix}t10k-labels-idx1-ubyte"),
    ]
}

/// Load a dataset from IDX files under `dir`, generating + caching the
/// synthetic corpus if any file is missing.
pub fn load_or_generate(
    dir: &Path,
    kind: Kind,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> Result<Dataset> {
    let names = file_names(kind);
    let paths: Vec<_> = names.iter().map(|n| dir.join(n)).collect();
    if paths.iter().all(|p| p.exists()) {
        let tr_img = idx::load_images(&paths[0])?;
        let tr_lbl = idx::load_labels(&paths[1])?;
        let te_img = idx::load_images(&paths[2])?;
        let te_lbl = idx::load_labels(&paths[3])?;
        anyhow::ensure!(tr_img.n == tr_lbl.n, "train images/labels count mismatch");
        anyhow::ensure!(te_img.n == te_lbl.n, "test images/labels count mismatch");
        anyhow::ensure!(
            tr_img.rows == IMG && tr_img.cols == IMG,
            "expected 28x28 images"
        );
        let mut ds = Dataset {
            kind,
            train: Split::from_u8(&tr_img.data, &tr_lbl.data),
            test: Split::from_u8(&te_img.data, &te_lbl.data),
        };
        if n_train > 0 {
            ds.train = ds.train.head(n_train);
        }
        if n_test > 0 {
            ds.test = ds.test.head(n_test);
        }
        return Ok(ds);
    }
    // generate + cache
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating data dir {}", dir.display()))?;
    let (tr_px, tr_lb) = synth::generate(kind, n_train, seed);
    let (te_px, te_lb) = synth::generate(kind, n_test, seed ^ 0xDEAD_BEEF);
    idx::save_images(
        &paths[0],
        &idx::IdxImages { n: n_train, rows: IMG, cols: IMG, data: tr_px.clone() },
    )?;
    idx::save_labels(&paths[1], &idx::IdxLabels { n: n_train, data: tr_lb.clone() })?;
    idx::save_images(
        &paths[2],
        &idx::IdxImages { n: n_test, rows: IMG, cols: IMG, data: te_px.clone() },
    )?;
    idx::save_labels(&paths[3], &idx::IdxLabels { n: n_test, data: te_lb.clone() })?;
    Ok(Dataset {
        kind,
        train: Split::from_u8(&tr_px, &tr_lb),
        test: Split::from_u8(&te_px, &te_lb),
    })
}

/// Minibatch iterator over a split (deterministic order per epoch seed).
pub struct Batches<'a> {
    split: &'a Split,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> Batches<'a> {
    pub fn new(split: &'a Split, batch: usize, epoch_seed: u64) -> Batches<'a> {
        let mut rng = crate::util::Rng::new(epoch_seed);
        Batches { split, order: rng.permutation(split.len()), batch, pos: 0 }
    }
}

impl<'a> Iterator for Batches<'a> {
    /// (images flat [b, 784], labels [b])
    type Item = (Vec<f32>, Vec<usize>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let idxs = &self.order[self.pos..end];
        self.pos = end;
        let mut images = Vec::with_capacity(idxs.len() * IMG * IMG);
        let mut labels = Vec::with_capacity(idxs.len());
        for &i in idxs {
            images.extend_from_slice(self.split.image(i));
            labels.push(self.split.labels[i]);
        }
        Some((images, labels))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tablenet_data_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generate_and_reload_identical() {
        let dir = tmp_dir("reload");
        let a = load_or_generate(&dir, Kind::Digits, 50, 20, 1).unwrap();
        let b = load_or_generate(&dir, Kind::Digits, 50, 20, 999).unwrap();
        // second call loads from cache: seed must not matter
        assert_eq!(a.train.images, b.train.images);
        assert_eq!(a.test.labels, b.test.labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pixels_normalized() {
        let dir = tmp_dir("norm");
        let ds = load_or_generate(&dir, Kind::Fashion, 20, 10, 2).unwrap();
        assert!(ds.train.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batches_cover_everything_once() {
        let dir = tmp_dir("batch");
        let ds = load_or_generate(&dir, Kind::Digits, 37, 5, 3).unwrap();
        let mut seen = vec![0usize; 10];
        let mut total = 0;
        for (imgs, lbls) in Batches::new(&ds.train, 8, 42) {
            assert_eq!(imgs.len(), lbls.len() * 784);
            assert!(lbls.len() <= 8);
            for &l in &lbls {
                seen[l] += 1;
            }
            total += lbls.len();
        }
        assert_eq!(total, 37);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn head_truncates() {
        let dir = tmp_dir("head");
        let ds = load_or_generate(&dir, Kind::Digits, 30, 10, 4).unwrap();
        let h = ds.train.head(7);
        assert_eq!(h.len(), 7);
        assert_eq!(h.image(3), ds.train.image(3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kind_parse() {
        assert_eq!(Kind::parse("MNIST"), Some(Kind::Digits));
        assert_eq!(Kind::parse("fashion"), Some(Kind::Fashion));
        assert_eq!(Kind::parse("imagenet"), None);
    }
}
