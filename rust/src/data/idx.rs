//! IDX file codec (the MNIST distribution format): magic 0x0803 for
//! u8 image tensors, 0x0801 for u8 label vectors, big-endian dims.
//! Real MNIST/Fashion-MNIST files drop in unchanged; the synthetic
//! corpus is written through the same codec so every consumer exercises
//! one loader.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// u8 images: [n, rows, cols].
pub struct IdxImages {
    pub n: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

/// u8 labels: [n].
pub struct IdxLabels {
    pub n: usize,
    pub data: Vec<u8>,
}

fn read_u32_be<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_be_bytes(b))
}

/// Read an images file (magic 0x00000803).
pub fn read_images<R: Read>(mut r: R) -> Result<IdxImages> {
    let magic = read_u32_be(&mut r)?;
    if magic != 0x0803 {
        bail!("bad IDX image magic {magic:#010x} (expected 0x00000803)");
    }
    let n = read_u32_be(&mut r)? as usize;
    let rows = read_u32_be(&mut r)? as usize;
    let cols = read_u32_be(&mut r)? as usize;
    if n > 1 << 24 || rows > 4096 || cols > 4096 {
        bail!("IDX dims unreasonable: {n} x {rows} x {cols}");
    }
    let mut data = vec![0u8; n * rows * cols];
    r.read_exact(&mut data).context("IDX image payload truncated")?;
    Ok(IdxImages { n, rows, cols, data })
}

/// Read a labels file (magic 0x00000801).
pub fn read_labels<R: Read>(mut r: R) -> Result<IdxLabels> {
    let magic = read_u32_be(&mut r)?;
    if magic != 0x0801 {
        bail!("bad IDX label magic {magic:#010x} (expected 0x00000801)");
    }
    let n = read_u32_be(&mut r)? as usize;
    if n > 1 << 24 {
        bail!("IDX label count unreasonable: {n}");
    }
    let mut data = vec![0u8; n];
    r.read_exact(&mut data).context("IDX label payload truncated")?;
    Ok(IdxLabels { n, data })
}

/// Write an images file.
pub fn write_images<W: Write>(mut w: W, img: &IdxImages) -> Result<()> {
    assert_eq!(img.data.len(), img.n * img.rows * img.cols);
    w.write_all(&0x0803u32.to_be_bytes())?;
    w.write_all(&(img.n as u32).to_be_bytes())?;
    w.write_all(&(img.rows as u32).to_be_bytes())?;
    w.write_all(&(img.cols as u32).to_be_bytes())?;
    w.write_all(&img.data)?;
    Ok(())
}

/// Write a labels file.
pub fn write_labels<W: Write>(mut w: W, l: &IdxLabels) -> Result<()> {
    assert_eq!(l.data.len(), l.n);
    w.write_all(&0x0801u32.to_be_bytes())?;
    w.write_all(&(l.n as u32).to_be_bytes())?;
    w.write_all(&l.data)?;
    Ok(())
}

pub fn load_images(path: &Path) -> Result<IdxImages> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    read_images(std::io::BufReader::new(f))
}

pub fn load_labels(path: &Path) -> Result<IdxLabels> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    read_labels(std::io::BufReader::new(f))
}

pub fn save_images(path: &Path, img: &IdxImages) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write_images(std::io::BufWriter::new(f), img)
}

pub fn save_labels(path: &Path, l: &IdxLabels) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write_labels(std::io::BufWriter::new(f), l)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_roundtrip() {
        let img = IdxImages {
            n: 2,
            rows: 3,
            cols: 4,
            data: (0u8..24).collect(),
        };
        let mut buf = Vec::new();
        write_images(&mut buf, &img).unwrap();
        let back = read_images(&buf[..]).unwrap();
        assert_eq!(back.n, 2);
        assert_eq!(back.rows, 3);
        assert_eq!(back.cols, 4);
        assert_eq!(back.data, img.data);
    }

    #[test]
    fn labels_roundtrip() {
        let l = IdxLabels { n: 5, data: vec![0, 1, 2, 9, 4] };
        let mut buf = Vec::new();
        write_labels(&mut buf, &l).unwrap();
        let back = read_labels(&buf[..]).unwrap();
        assert_eq!(back.data, l.data);
    }

    #[test]
    fn rejects_wrong_magic() {
        let l = IdxLabels { n: 1, data: vec![7] };
        let mut buf = Vec::new();
        write_labels(&mut buf, &l).unwrap();
        assert!(read_images(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let img = IdxImages { n: 1, rows: 28, cols: 28, data: vec![0; 784] };
        let mut buf = Vec::new();
        write_images(&mut buf, &img).unwrap();
        buf.truncate(buf.len() - 100);
        assert!(read_images(&buf[..]).is_err());
    }

    #[test]
    fn big_endian_header() {
        let img = IdxImages { n: 1, rows: 2, cols: 2, data: vec![0; 4] };
        let mut buf = Vec::new();
        write_images(&mut buf, &img).unwrap();
        assert_eq!(&buf[0..4], &[0, 0, 8, 3]);
        assert_eq!(&buf[4..8], &[0, 0, 0, 1]);
    }
}
