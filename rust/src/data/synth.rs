//! Deterministic synthetic corpora standing in for MNIST and
//! Fashion-MNIST (the sandbox has no network access — see DESIGN.md
//! §Substitutions).
//!
//! * `digits`: stroke-font digits rendered at a jittered affine pose
//!   with bilinear anti-aliasing — reproducing the property the paper
//!   leans on ("the original NIST digits images are bilevel and the few
//!   grey levels were introduced into MNIST due to anti-aliasing"), so
//!   the 3-bit-input accuracy plateau of Figs. 4/6 is exercised by the
//!   same mechanism.
//! * `fashion`: textured garment silhouettes, 10 classes, deliberately
//!   harder (larger filled regions, class-overlapping shapes) so the
//!   reference accuracy lands well below the digits corpus — matching
//!   the paper's MNIST vs Fashion-MNIST gap in *direction and rough
//!   magnitude*.

use crate::util::Rng;

pub const IMG: usize = 28;

/// 5x7 bitmap font for digits 0-9 (each row is 5 bits, MSB left).
const FONT: [[u8; 7]; 10] = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// Sample the font glyph as a continuous field at (u, v) in glyph space
/// [0,5) x [0,7) with bilinear interpolation between cell centres.
fn glyph_field(digit: usize, u: f32, v: f32) -> f32 {
    let sample = |x: i32, y: i32| -> f32 {
        if x < 0 || x >= 5 || y < 0 || y >= 7 {
            0.0
        } else {
            ((FONT[digit][y as usize] >> (4 - x)) & 1) as f32
        }
    };
    let (x0, y0) = (u.floor(), v.floor());
    let (fx, fy) = (u - x0, v - y0);
    let (x0, y0) = (x0 as i32, y0 as i32);
    let a = sample(x0, y0) * (1.0 - fx) + sample(x0 + 1, y0) * fx;
    let b = sample(x0, y0 + 1) * (1.0 - fx) + sample(x0 + 1, y0 + 1) * fx;
    a * (1.0 - fy) + b * fy
}

/// Render one digit with a jittered pose, elastic warp, occlusion and
/// sensor noise — variation tuned so reference accuracies land in the
/// paper's MNIST regime (linear ≈ low 90s, MLP/CNN higher) rather than
/// at a saturated 100%.
pub fn render_digit(digit: usize, rng: &mut Rng) -> Vec<u8> {
    assert!(digit < 10);
    // pose jitter: scale, shear, rotation, translation
    let scale_x = rng.range(2.4, 3.7);
    let scale_y = rng.range(2.0, 3.1);
    let angle = rng.range(-0.22, 0.22);
    let shear = rng.range(-0.32, 0.32);
    let cx = 14.0 + rng.range(-2.8, 2.8);
    let cy = 14.0 + rng.range(-2.8, 2.8);
    let (sin, cos) = angle.sin_cos();
    let thick = rng.range(0.85, 1.35); // stroke gain
    let noise_amp = rng.range(0.05, 0.15);
    // low-frequency elastic warp (pseudo-handwriting wobble)
    let wfx = rng.range(0.15, 0.55);
    let wfy = rng.range(0.15, 0.55);
    let wax = rng.range(0.0, 0.5);
    let way = rng.range(0.0, 0.5);
    let wpx = rng.range(0.0, 6.28);
    let wpy = rng.range(0.0, 6.28);
    // occasional occluding bar
    let occlude = rng.f32() < 0.15;
    let occ_y = rng.below(IMG) as f32;
    let occ_h = rng.range(0.8, 1.8);

    let mut out = vec![0u8; IMG * IMG];
    for py in 0..IMG {
        for px in 0..IMG {
            // map pixel to glyph space (inverse affine + warp)
            let dx = px as f32 - cx;
            let dy = py as f32 - cy;
            let rx = cos * dx + sin * dy + wax * (wfy * dy + wpy).sin();
            let ry = -sin * dx + cos * dy + way * (wfx * dx + wpx).sin();
            let gx = rx / scale_x + shear * ry / scale_y + 2.5;
            let gy = ry / scale_y + 3.5;
            // 2x2 supersampling for anti-aliasing
            let mut v = 0.0;
            for (ox, oy) in [(-0.25, -0.25), (0.25, -0.25), (-0.25, 0.25), (0.25, 0.25)]
            {
                v += glyph_field(digit, gx + ox - 0.5, gy + oy - 0.5);
            }
            v = (v / 4.0 * thick).clamp(0.0, 1.0);
            if occlude && (py as f32 - occ_y).abs() < occ_h {
                v *= 0.35;
            }
            v += noise_amp * (rng.f32() - 0.5);
            // occasional salt speckle (sensor noise)
            if rng.f32() < 0.004 {
                v = rng.range(0.4, 1.0);
            }
            out[py * IMG + px] = (v.clamp(0.0, 1.0) * 255.0) as u8;
        }
    }
    out
}

/// Garment silhouette classes for the fashion corpus.
/// 0 tshirt, 1 trouser, 2 pullover, 3 dress, 4 coat,
/// 5 sandal, 6 shirt, 7 sneaker, 8 bag, 9 ankle boot.
fn silhouette(class: usize, x: f32, y: f32, p: &[f32; 4]) -> bool {
    // x, y in [0,1]; p are per-sample shape jitters in [0,1]
    let (w0, w1, h0, h1) = (p[0], p[1], p[2], p[3]);
    match class {
        0 => {
            // t-shirt: torso + short sleeves
            let torso = (0.32 - 0.08 * w0..0.68 + 0.08 * w0).contains(&x)
                && (0.22..0.85).contains(&y);
            let sleeves = (0.10..0.90).contains(&x) && (0.22..0.40 + 0.08 * h0).contains(&y);
            torso || sleeves
        }
        1 => {
            // trouser: two legs
            let waist = (0.30..0.70).contains(&x) && (0.12..0.30).contains(&y);
            let leg_l = (0.30..0.46 + 0.04 * w1).contains(&x) && (0.30..0.92).contains(&y);
            let leg_r = (0.54 - 0.04 * w1..0.70).contains(&x) && (0.30..0.92).contains(&y);
            waist || leg_l || leg_r
        }
        2 => {
            // pullover: torso + long sleeves
            let torso = (0.30..0.70).contains(&x) && (0.20..0.88).contains(&y);
            let sleeves = (0.06..0.94).contains(&x) && (0.20..0.75 + 0.1 * h1).contains(&y)
                && !(0.30..0.70).contains(&x)
                && (x < 0.30 + 0.02 || x > 0.70 - 0.02);
            torso || sleeves
        }
        3 => {
            // dress: narrow top flaring to wide hem
            let t = (y - 0.15).max(0.0) / 0.75;
            let half = 0.10 + (0.28 + 0.08 * w0) * t;
            (y > 0.15 && y < 0.92) && (x - 0.5).abs() < half
        }
        4 => {
            // coat: wide torso, long sleeves, open front line
            let torso = (0.26..0.74).contains(&x) && (0.15..0.92).contains(&y);
            let front = (x - 0.5).abs() < 0.015;
            let sleeves = (0.06..0.94).contains(&x) && (0.18..0.85).contains(&y)
                && !(0.26..0.74).contains(&x);
            (torso && !front) || sleeves
        }
        5 => {
            // sandal: sole + straps
            let sole = (0.10..0.90).contains(&x) && (0.70..0.82 + 0.06 * h0).contains(&y);
            let strap1 = ((x - 0.35).abs() < 0.05) && (0.45..0.70).contains(&y);
            let strap2 = ((x - 0.65).abs() < 0.05) && (0.45..0.70).contains(&y);
            let strap3 = ((y - 0.52).abs() < 0.04) && (0.30..0.70).contains(&x);
            sole || strap1 || strap2 || strap3
        }
        6 => {
            // shirt: torso + collar notch + long sleeves (vs pullover:
            // has button line)
            let torso = (0.30..0.70).contains(&x) && (0.18..0.88).contains(&y);
            let buttons = (x - 0.5).abs() < 0.02 && (0.25..0.85).contains(&y);
            let sleeves = (0.08..0.92).contains(&x) && (0.18..0.60).contains(&y)
                && !(0.30..0.70).contains(&x);
            (torso && !buttons) || sleeves
        }
        7 => {
            // sneaker: low profile wedge
            let body = (0.08..0.92).contains(&x)
                && (0.55..0.80).contains(&y)
                && (y > 0.80 - (x - 0.08) * (0.20 + 0.1 * h1));
            let sole = (0.08..0.92).contains(&x) && (0.78..0.86).contains(&y);
            body || sole
        }
        8 => {
            // bag: box + handle arc
            let body = (0.18..0.82).contains(&x) && (0.40..0.88).contains(&y);
            let dx = x - 0.5;
            let dy = y - 0.42;
            let rr = dx * dx + dy * dy;
            let handle = rr < 0.072 + 0.02 * w1 && rr > 0.038 && y < 0.42;
            body || handle
        }
        9 => {
            // ankle boot: shaft + foot
            let shaft = (0.30..0.62).contains(&x) && (0.18..0.70).contains(&y);
            let foot = (0.30..0.90).contains(&x) && (0.60..0.84).contains(&y);
            let sole = (0.28..0.92).contains(&x) && (0.82..0.88).contains(&y);
            shaft || foot || sole
        }
        _ => unreachable!(),
    }
}

/// Render one fashion item: silhouette + per-sample texture + pose
/// jitter, anti-aliased by supersampling.
pub fn render_fashion(class: usize, rng: &mut Rng) -> Vec<u8> {
    assert!(class < 10);
    let p = [rng.f32(), rng.f32(), rng.f32(), rng.f32()];
    let cx = rng.range(-0.13, 0.13);
    let cy = rng.range(-0.13, 0.13);
    let angle = rng.range(-0.24, 0.24);
    let (sin, cos) = angle.sin_cos();
    let sx = rng.range(0.78, 1.28); // anisotropic scale jitter
    let sy = rng.range(0.78, 1.28);
    // texture: 0 flat, 1 h-stripes, 2 v-stripes, 3 checker
    let tex = rng.below(4);
    let tex_freq = rng.range(5.0, 12.0);
    let base = rng.range(0.4, 0.95);
    let noise_amp = rng.range(0.10, 0.24);
    // low-frequency shading gradient (lighting variation)
    let grad = rng.range(-0.28, 0.28);
    let occlude = rng.f32() < 0.3;
    let occ_x = rng.f32();

    let mut out = vec![0u8; IMG * IMG];
    for py in 0..IMG {
        for px in 0..IMG {
            let mut v = 0.0f32;
            for (ox, oy) in [(0.25f32, 0.25f32), (0.75, 0.25), (0.25, 0.75), (0.75, 0.75)]
            {
                let mut x = (px as f32 + ox) / IMG as f32 - 0.5;
                let mut y = (py as f32 + oy) / IMG as f32 - 0.5;
                let rx = (cos * x - sin * y) * sx;
                let ry = (sin * x + cos * y) * sy;
                x = rx + 0.5 + cx;
                y = ry + 0.5 + cy;
                if occlude && (x - occ_x).abs() < 0.03 {
                    continue; // vertical fold/occlusion stripe
                }
                if silhouette(class, x, y, &p) {
                    let t = match tex {
                        1 => 0.75 + 0.25 * ((y * tex_freq).sin() > 0.0) as u8 as f32,
                        2 => 0.75 + 0.25 * ((x * tex_freq).sin() > 0.0) as u8 as f32,
                        3 => {
                            0.7 + 0.3
                                * (((x * tex_freq).sin() > 0.0)
                                    == ((y * tex_freq).sin() > 0.0))
                                    as u8 as f32
                        }
                        _ => 1.0,
                    };
                    v += base * t;
                }
            }
            let mut val = v / 4.0;
            val += grad * (py as f32 / IMG as f32 - 0.5);
            val += noise_amp * (rng.f32() - 0.5);
            if rng.f32() < 0.004 {
                val = rng.range(0.4, 1.0);
            }
            out[py * IMG + px] = (val.clamp(0.0, 1.0) * 255.0) as u8;
        }
    }
    out
}

/// Which synthetic corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Digits,
    Fashion,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" | "digits" => Some(Kind::Digits),
            "fashion" | "fashion-mnist" | "fashion_mnist" => Some(Kind::Fashion),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kind::Digits => "digits",
            Kind::Fashion => "fashion",
        }
    }
}

/// Generate `n` samples with balanced classes. Returns (pixels, labels);
/// pixels are u8 row-major [n, 28, 28].
pub fn generate(kind: Kind, n: usize, seed: u64) -> (Vec<u8>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let mut pixels = Vec::with_capacity(n * IMG * IMG);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        let img = match kind {
            Kind::Digits => render_digit(class, &mut rng),
            Kind::Fashion => render_fashion(class, &mut rng),
        };
        pixels.extend_from_slice(&img);
        labels.push(class as u8);
    }
    // deterministic shuffle so minibatches are class-mixed
    let mut order = rng.permutation(n);
    let mut sp = vec![0u8; pixels.len()];
    let mut sl = vec![0u8; n];
    for (dst, src) in order.drain(..).enumerate() {
        sp[dst * IMG * IMG..(dst + 1) * IMG * IMG]
            .copy_from_slice(&pixels[src * IMG * IMG..(src + 1) * IMG * IMG]);
        sl[dst] = labels[src];
    }
    (sp, sl)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_are_deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        assert_eq!(render_digit(3, &mut a), render_digit(3, &mut b));
    }

    #[test]
    fn digits_have_grey_levels_from_antialiasing() {
        let mut rng = Rng::new(2);
        let img = render_digit(8, &mut rng);
        let grey = img.iter().filter(|&&v| v > 20 && v < 235).count();
        assert!(grey > 20, "expected anti-aliased edges, got {grey} grey pixels");
    }

    #[test]
    fn digits_mostly_background() {
        let mut rng = Rng::new(3);
        for d in 0..10 {
            let img = render_digit(d, &mut rng);
            let ink: usize = img.iter().filter(|&&v| v > 128).count();
            assert!(ink > 20 && ink < 400, "digit {d} ink {ink}");
        }
    }

    #[test]
    fn digit_classes_are_distinct() {
        // mean per-class images should differ pairwise
        let mut protos = Vec::new();
        for d in 0..10 {
            let mut acc = vec![0f32; IMG * IMG];
            let mut rng = Rng::new(100 + d as u64);
            for _ in 0..8 {
                let img = render_digit(d, &mut rng);
                for (a, &v) in acc.iter_mut().zip(&img) {
                    *a += v as f32;
                }
            }
            protos.push(acc);
        }
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d: f32 = protos[i]
                    .iter()
                    .zip(&protos[j])
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                assert!(d > 10_000.0, "classes {i},{j} too similar: {d}");
            }
        }
    }

    #[test]
    fn fashion_classes_are_distinct() {
        let mut protos = Vec::new();
        for c in 0..10 {
            let mut acc = vec![0f32; IMG * IMG];
            let mut rng = Rng::new(200 + c as u64);
            for _ in 0..8 {
                let img = render_fashion(c, &mut rng);
                for (a, &v) in acc.iter_mut().zip(&img) {
                    *a += v as f32;
                }
            }
            protos.push(acc);
        }
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d: f32 = protos[i]
                    .iter()
                    .zip(&protos[j])
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                assert!(d > 8_000.0, "fashion classes {i},{j} too similar: {d}");
            }
        }
    }

    #[test]
    fn generate_is_balanced_and_shuffled() {
        let (px, lbl) = generate(Kind::Digits, 200, 7);
        assert_eq!(px.len(), 200 * 784);
        let mut counts = [0usize; 10];
        for &l in &lbl {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20));
        // shuffled: first 10 labels should not be 0..9 in order
        let in_order = lbl[..10].iter().enumerate().all(|(i, &l)| l as usize == i);
        assert!(!in_order);
    }

    #[test]
    fn generate_same_seed_same_data() {
        let a = generate(Kind::Fashion, 50, 11);
        let b = generate(Kind::Fashion, 50, 11);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }
}
