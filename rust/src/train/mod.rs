//! In-Rust SGD trainer (softmax cross-entropy, minibatch SGD, optional
//! quantization-aware inputs). The primary training path is JAX
//! (`python/compile/train.py`) — this trainer exists so the Rust stack
//! is self-contained end-to-end (paper's linear classifier trains in
//! seconds) and so tests can train tiny models without artifacts.

use crate::data::{Batches, Split};
use crate::nn::{Arch, Layer, Model};
use crate::quant::FixedFormat;
use crate::tensor::ops::{add_bias, cross_entropy, matmul, relu, softmax_rows, transpose};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Trainer hyper-parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lr: f32,
    pub steps: usize,
    pub batch: usize,
    pub seed: u64,
    /// Fake-quantize inputs to this many fixed-point bits during
    /// training (the paper's "insert quantization operations before the
    /// input"). None = full precision.
    pub input_bits: Option<u32>,
    /// L2 weight decay.
    pub weight_decay: f32,
    /// Print loss every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.1,
            steps: 2000,
            batch: 100,
            seed: 0x7AB1E7,
            input_bits: None,
            weight_decay: 1e-4,
            log_every: 0,
        }
    }
}

/// Dense-stack trainer state: weights + biases per layer, ReLU between.
pub struct DenseNet {
    /// (w [p,q], b [p]) per layer.
    pub layers: Vec<(Tensor, Tensor)>,
}

impl DenseNet {
    /// He-init a stack with the given layer widths, e.g. [784, 10] for
    /// the linear classifier or [784, 1024, 512, 10] for the MLP.
    pub fn init(widths: &[usize], rng: &mut Rng) -> DenseNet {
        assert!(widths.len() >= 2);
        let layers = widths
            .windows(2)
            .map(|wh| {
                let (q, p) = (wh[0], wh[1]);
                let std = (2.0 / q as f32).sqrt();
                (Tensor::randn(&[p, q], std, rng), Tensor::zeros(&[p]))
            })
            .collect();
        DenseNet { layers }
    }

    /// Forward pass keeping pre-activations for backprop.
    /// Returns (activations after each ReLU incl. input, logits).
    fn forward_cached(&self, x: &Tensor) -> (Vec<Tensor>, Tensor) {
        let mut acts = vec![x.clone()];
        let mut cur = x.clone();
        for (i, (w, b)) in self.layers.iter().enumerate() {
            let z = add_bias(&matmul(&cur, &transpose(w)), b);
            if i + 1 < self.layers.len() {
                cur = relu(&z);
                acts.push(cur.clone());
            } else {
                return (acts, z);
            }
        }
        unreachable!()
    }

    /// One SGD step on a batch; returns the loss.
    pub fn step(&mut self, x: &Tensor, labels: &[usize], lr: f32, wd: f32) -> f32 {
        let bsz = labels.len();
        let (acts, logits) = self.forward_cached(x);
        let probs = softmax_rows(&logits);
        let loss = cross_entropy(&probs, labels);

        // dL/dlogits = (probs - onehot) / b
        let c = logits.shape()[1];
        let mut delta = probs.data().to_vec();
        for (i, &l) in labels.iter().enumerate() {
            delta[i * c + l] -= 1.0;
        }
        for d in &mut delta {
            *d /= bsz as f32;
        }
        let mut delta = Tensor::new(&[bsz, c], delta);

        for li in (0..self.layers.len()).rev() {
            let a_in = &acts[li];
            // grads
            let gw = matmul(&transpose(&delta), a_in); // [p, q]
            let gb: Vec<f32> = {
                let (b_, p) = (delta.shape()[0], delta.shape()[1]);
                (0..p)
                    .map(|j| (0..b_).map(|i| delta.at2(i, j)).sum())
                    .collect()
            };
            // propagate before updating weights
            if li > 0 {
                let mut dprev = matmul(&delta, &self.layers[li].0); // [b, q]
                // ReLU mask from a_in (post-ReLU activations)
                for (d, &a) in dprev.data_mut().iter_mut().zip(a_in.data()) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = dprev;
            }
            // SGD + weight decay
            let (w, b) = &mut self.layers[li];
            for (wv, gv) in w.data_mut().iter_mut().zip(gw.data()) {
                *wv -= lr * (gv + wd * *wv);
            }
            for (bv, gv) in b.data_mut().iter_mut().zip(&gb) {
                *bv -= lr * gv;
            }
        }
        loss
    }

    /// Convert to an inference [`Model`] with the right architecture tag.
    pub fn into_model(self) -> Model {
        let n = self.layers.len();
        let arch = match n {
            1 => Arch::Linear,
            3 => Arch::Mlp,
            _ => Arch::Mlp, // generic dense stack: tag as MLP
        };
        let mut layers = Vec::new();
        for (i, (w, b)) in self.layers.into_iter().enumerate() {
            layers.push(Layer::Dense { w, b });
            if i + 1 < n {
                layers.push(Layer::Relu);
            }
        }
        Model { arch, layers, input_shape: vec![784] }
    }
}

/// Train a dense stack on a split. `widths` excludes nothing: pass the
/// full ladder (e.g. `[784, 10]`).
pub fn train_dense(split: &Split, widths: &[usize], cfg: &TrainConfig) -> Model {
    let mut rng = Rng::new(cfg.seed);
    let mut net = DenseNet::init(widths, &mut rng);
    let quant = cfg.input_bits.map(FixedFormat::new);
    let mut step = 0usize;
    let mut epoch = 0u64;
    'outer: loop {
        for (mut images, labels) in Batches::new(split, cfg.batch, cfg.seed ^ epoch) {
            if let Some(fmt) = quant {
                for v in &mut images {
                    *v = fmt.fake_quant(*v);
                }
            }
            let x = Tensor::new(&[labels.len(), widths[0]], images);
            let loss = net.step(&x, &labels, cfg.lr, cfg.weight_decay);
            step += 1;
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!("step {step}: loss {loss:.4}");
            }
            if step >= cfg.steps {
                break 'outer;
            }
        }
        epoch += 1;
    }
    net.into_model()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Kind;

    fn toy_dataset(kind: Kind, n: usize) -> Split {
        let (px, lb) = crate::data::synth::generate(kind, n, 77);
        Split {
            images: px.iter().map(|&v| v as f32 / 255.0).collect(),
            labels: lb.iter().map(|&l| l as usize).collect(),
        }
    }

    #[test]
    fn linear_learns_digits() {
        let train = toy_dataset(Kind::Digits, 600);
        let test = toy_dataset(Kind::Digits, 200);
        let cfg = TrainConfig { steps: 300, lr: 0.3, ..Default::default() };
        let model = train_dense(&train, &[784, 10], &cfg);
        let x = Tensor::new(&[test.len(), 784], test.images.clone());
        let acc = model.accuracy(&x, &test.labels);
        assert!(acc > 0.8, "linear classifier only reached {acc}");
    }

    #[test]
    fn quant_aware_training_still_learns() {
        let train = toy_dataset(Kind::Digits, 600);
        let cfg = TrainConfig {
            steps: 300,
            lr: 0.3,
            input_bits: Some(3),
            ..Default::default()
        };
        let model = train_dense(&train, &[784, 10], &cfg);
        // evaluate on 3-bit quantized inputs, as deployed
        let test = toy_dataset(Kind::Digits, 200);
        let fmt = FixedFormat::new(3);
        let xq: Vec<f32> = test.images.iter().map(|&v| fmt.fake_quant(v)).collect();
        let x = Tensor::new(&[test.len(), 784], xq);
        let acc = model.accuracy(&x, &test.labels);
        assert!(acc > 0.75, "QAT linear reached only {acc}");
    }

    #[test]
    fn tiny_mlp_beats_linear_on_fashion() {
        let train = toy_dataset(Kind::Fashion, 800);
        let test = toy_dataset(Kind::Fashion, 200);
        let lin = train_dense(
            &train,
            &[784, 10],
            &TrainConfig { steps: 250, lr: 0.2, ..Default::default() },
        );
        let mlp = train_dense(
            &train,
            &[784, 64, 10],
            &TrainConfig { steps: 400, lr: 0.2, ..Default::default() },
        );
        let x = Tensor::new(&[test.len(), 784], test.images.clone());
        let al = lin.accuracy(&x, &test.labels);
        let am = mlp.accuracy(&x, &test.labels);
        assert!(am > 0.6, "mlp acc {am}");
        assert!(am + 0.05 >= al, "mlp ({am}) should not lose badly to linear ({al})");
    }

    #[test]
    fn loss_decreases() {
        let train = toy_dataset(Kind::Digits, 300);
        let mut rng = Rng::new(5);
        let mut net = DenseNet::init(&[784, 10], &mut rng);
        let x = Tensor::new(&[100, 784], train.images[..100 * 784].to_vec());
        let labels = &train.labels[..100];
        let first = net.step(&x, labels, 0.2, 0.0);
        let mut last = first;
        for _ in 0..30 {
            last = net.step(&x, labels, 0.2, 0.0);
        }
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let train = toy_dataset(Kind::Digits, 100);
        let x = Tensor::new(&[50, 784], train.images[..50 * 784].to_vec());
        let labels = &train.labels[..50];
        let mut rng = Rng::new(6);
        let mut a = DenseNet::init(&[784, 10], &mut rng);
        let mut b = DenseNet { layers: a.layers.clone() };
        for _ in 0..20 {
            a.step(&x, labels, 0.1, 0.0);
            b.step(&x, labels, 0.1, 0.01);
        }
        let norm = |n: &DenseNet| -> f32 {
            n.layers[0].0.data().iter().map(|v| v * v).sum()
        };
        assert!(norm(&b) < norm(&a));
    }
}
