//! The `.ltm` compiled-model artifact: a versioned binary container
//! holding everything a deployment serves — the engine plan plus every
//! stage's tables and metadata. `serve`/`eval` can start from an
//! artifact without weights or recompilation, and the round-trip is
//! bit-exact (same classes, same logits, same zero-multiply counters;
//! asserted by `rust/tests/artifact_roundtrip.rs`).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic   b"LTM1"
//! u32     container version (1)
//! u32     plan JSON length | plan JSON (the EnginePlan, via config)
//! u32     stage count
//! stage*  u16 kind tag | u64 payload length | payload bytes
//! u64     FNV-1a 64 checksum of every preceding byte
//! ```
//!
//! Stage payloads are owned by the stage modules (`Stage::write_payload`
//! / `read_stage`), so new stage kinds serialize without touching this
//! container. The trailing checksum rejects truncation and bit rot
//! before any payload is parsed.

use crate::engine::stages::{read_stage, Stage, StageKind};
use crate::engine::LutModel;
use crate::lut::wire::{self, Reader};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"LTM1";
pub const VERSION: u32 = 1;

/// Largest artifact the loader will accept (matches the engine's
/// table materialisation cap with headroom for metadata).
const MAX_ARTIFACT_BYTES: u64 = 8 << 30;

/// FNV-1a 64 (vendored crate set has no hash crates; collision
/// resistance is not a goal — this is an integrity check, not MAC).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a compiled model to the `.ltm` byte format.
pub fn to_bytes(model: &LutModel) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    wire::put_u32(&mut out, VERSION);
    let plan_json = crate::config::plan_to_json(model.plan()).to_string();
    wire::put_u32(&mut out, plan_json.len() as u32);
    out.extend_from_slice(plan_json.as_bytes());
    wire::put_u32(&mut out, model.stages().len() as u32);
    let mut payload = Vec::new();
    for stage in model.stages() {
        payload.clear();
        stage.write_payload(&mut payload);
        wire::put_u16(&mut out, stage.kind().tag());
        wire::put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
    }
    let sum = fnv1a(&out);
    wire::put_u64(&mut out, sum);
    out
}

/// The parsed container header + stage table of a `.ltm` buffer:
/// checksum-verified, payloads still undecoded. This is the ONE
/// header-read path — [`from_bytes`] (registry / `serve` loads) and
/// [`inspect_bytes`] (`tablenet inspect`) both start here.
struct Container<'a> {
    plan_json: &'a str,
    plan: crate::engine::plan::EnginePlan,
    stages: Vec<(StageKind, &'a [u8])>,
}

fn parse_container(bytes: &[u8]) -> Result<Container<'_>> {
    if bytes.len() < MAGIC.len() + 4 + 4 + 4 + 8 {
        bail!("artifact too short ({} bytes) to be a .ltm file", bytes.len());
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    let computed = fnv1a(body);
    if stored != computed {
        bail!("artifact checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — file is corrupted or truncated");
    }
    let mut r = Reader::new(body);
    let magic = r.take(4).map_err(wire_err)?;
    if magic != MAGIC {
        bail!("bad artifact magic {magic:?}, expected {MAGIC:?}");
    }
    let version = r.u32().map_err(wire_err)?;
    if version != VERSION {
        bail!("unsupported .ltm version {version} (this build reads {VERSION})");
    }
    let plan_len = r
        .len_capped_u32(1 << 20, "plan JSON")
        .map_err(wire_err)?;
    let plan_bytes = r.take(plan_len).map_err(wire_err)?;
    let plan_json =
        std::str::from_utf8(plan_bytes).context("artifact plan JSON is not utf-8")?;
    let parsed = crate::config::json::Json::parse(plan_json)
        .map_err(|e| anyhow!("artifact plan JSON: {e}"))?;
    let plan = crate::config::plan_from_json(&parsed)?;
    let n_stages = r.u32().map_err(wire_err)? as usize;
    if n_stages > 4096 {
        bail!("artifact claims {n_stages} stages — refusing");
    }
    let mut stages = Vec::with_capacity(n_stages);
    for i in 0..n_stages {
        let tag = r.u16().map_err(wire_err)?;
        let kind = StageKind::from_tag(tag)
            .ok_or_else(|| anyhow!("stage {i}: unknown kind tag {tag}"))?;
        let len = r.u64().map_err(wire_err)? as usize;
        let payload = r
            .take(len)
            .map_err(wire_err)
            .with_context(|| format!("stage {i} ({}) payload", kind.name()))?;
        stages.push((kind, payload));
    }
    if !r.is_empty() {
        bail!("artifact has {} trailing bytes after the stage table", r.remaining());
    }
    Ok(Container { plan_json, plan, stages })
}

/// Decode every stage payload of a parsed container, enforcing the
/// per-stage trailing-bytes rule. Shared by [`from_bytes`] and
/// [`inspect_bytes`] so an artifact inspect accepts is exactly one a
/// serve load accepts.
fn decode_stages(records: &[(StageKind, &[u8])]) -> Result<Vec<Box<dyn Stage>>> {
    let mut stages: Vec<Box<dyn Stage>> = Vec::with_capacity(records.len());
    for (i, (kind, payload)) in records.iter().enumerate() {
        let mut pr = Reader::new(payload);
        let stage = read_stage(*kind, &mut pr)
            .map_err(wire_err)
            .with_context(|| format!("decoding stage {i} ({})", kind.name()))?;
        if !pr.is_empty() {
            bail!(
                "stage {i} ({}) payload has {} trailing bytes",
                kind.name(),
                pr.remaining()
            );
        }
        stages.push(stage);
    }
    Ok(stages)
}

/// Pipeline-level sanity: each payload validated its own shape during
/// decode, but a crafted (checksum-recomputed) artifact could still
/// describe an unservable pipeline. Reject the cheap-to-check global
/// invariants here; per-stage input contracts (representation tags,
/// code widths) are additionally hard-asserted by the stages on first
/// use, so an inconsistent pipeline fails loudly, never with
/// out-of-bounds indexing.
fn validate_pipeline(stages: &[Box<dyn Stage>]) -> Result<()> {
    if stages.is_empty() {
        bail!("artifact describes an empty pipeline");
    }
    // mirror the runtime contract (inference argmaxes integer
    // accumulators): walking back over the Acc-preserving stages
    // (ReLU, max-pool), the pipeline must reach an affine bank. This
    // accepts exactly the pipelines `infer` can finish.
    let tail_bank = stages
        .iter()
        .rev()
        .map(|s| s.kind())
        .find(|k| !matches!(k, StageKind::ReluInt | StageKind::MaxPool2Int));
    let ends_in_acc = matches!(
        tail_bank,
        Some(
            StageKind::DenseWhole
                | StageKind::DenseBitplane
                | StageKind::DenseFloat
                | StageKind::ConvFixed
                | StageKind::ConvFloat
        )
    );
    if !ends_in_acc {
        bail!(
            "artifact pipeline ends with {} — inference must end on integer accumulators",
            stages.last().unwrap().kind().name()
        );
    }
    Ok(())
}

/// Parse a `.ltm` byte buffer back into a compiled model.
pub fn from_bytes(bytes: &[u8]) -> Result<LutModel> {
    let c = parse_container(bytes)?;
    let stages = decode_stages(&c.stages)?;
    validate_pipeline(&stages)?;
    Ok(LutModel::from_parts(stages, c.plan))
}

fn wire_err(e: wire::WireError) -> anyhow::Error {
    anyhow!("{e}")
}

/// Write a compiled model to `path`.
pub fn save(model: &LutModel, path: &Path) -> Result<()> {
    let bytes = to_bytes(model);
    std::fs::write(path, bytes)
        .with_context(|| format!("writing artifact {}", path.display()))
}

/// Load a compiled model from `path`.
pub fn load(path: &Path) -> Result<LutModel> {
    let bytes = read_capped(path)?;
    from_bytes(&bytes).with_context(|| format!("parsing artifact {}", path.display()))
}

fn read_capped(path: &Path) -> Result<Vec<u8>> {
    let meta = std::fs::metadata(path)
        .with_context(|| format!("reading artifact {}", path.display()))?;
    if meta.len() > MAX_ARTIFACT_BYTES {
        bail!(
            "artifact {} is {} bytes — larger than the {} byte cap",
            path.display(),
            meta.len(),
            MAX_ARTIFACT_BYTES
        );
    }
    std::fs::read(path).with_context(|| format!("reading artifact {}", path.display()))
}

/// What `tablenet inspect` reports about one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Container format version.
    pub version: u32,
    /// The embedded engine plan, verbatim JSON.
    pub plan_json: String,
    /// Per-stage kind + payload/table accounting, in pipeline order.
    pub stages: Vec<StageInfo>,
    /// Input features of the pipeline (first bank's geometry).
    pub input_features: Option<usize>,
    /// Total file size in bytes.
    pub total_bytes: u64,
    /// Total LUT storage in bits at the plan's accounting width.
    pub size_bits: u64,
}

/// One stage row of an [`ArtifactInfo`].
#[derive(Debug, Clone)]
pub struct StageInfo {
    pub kind: StageKind,
    /// On-disk payload bytes (tables + metadata).
    pub payload_bytes: u64,
    /// Table storage in bits at the plan's accounting width.
    pub size_bits: u64,
}

/// Inspect a `.ltm` buffer: checksum, header, stage table and per-stage
/// table sizes — the same parse + decode + validate path the serving
/// registry loads through, so inspect-clean means serve-loadable
/// (trailing payload bytes and unservable pipelines fail inspect too).
pub fn inspect_bytes(bytes: &[u8]) -> Result<ArtifactInfo> {
    let c = parse_container(bytes)?;
    let decoded = decode_stages(&c.stages)?;
    validate_pipeline(&decoded)?;
    let r_o = c.plan.r_o;
    let mut stages = Vec::with_capacity(decoded.len());
    let mut size_bits = 0u64;
    let mut input_features = None;
    for (stage, (kind, payload)) in decoded.iter().zip(&c.stages) {
        let bits = stage.size_bits(r_o);
        size_bits += bits;
        if input_features.is_none() {
            input_features = stage.in_elems();
        }
        stages.push(StageInfo {
            kind: *kind,
            payload_bytes: payload.len() as u64,
            size_bits: bits,
        });
    }
    Ok(ArtifactInfo {
        version: VERSION,
        plan_json: c.plan_json.to_string(),
        stages,
        input_features,
        total_bytes: bytes.len() as u64,
        size_bits,
    })
}

/// [`inspect_bytes`] over a file.
pub fn inspect(path: &Path) -> Result<ArtifactInfo> {
    let bytes = read_capped(path)?;
    inspect_bytes(&bytes).with_context(|| format!("inspecting artifact {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vectors() {
        // reference vectors for FNV-1a 64
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn inspect_agrees_with_loaded_model() {
        use crate::engine::plan::EnginePlan;
        use crate::engine::Compiler;
        use crate::nn::Model;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(90);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        let lut = Compiler::new(&model)
            .plan(&EnginePlan::linear_default())
            .build()
            .unwrap();
        let bytes = to_bytes(&lut);
        let info = inspect_bytes(&bytes).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!(info.total_bytes, bytes.len() as u64);
        assert_eq!(info.stages.len(), lut.num_stages());
        assert_eq!(info.size_bits, lut.size_bits());
        assert_eq!(info.input_features, Some(784));
        assert_eq!(
            info.plan_json,
            crate::config::plan_to_json(lut.plan()).to_string()
        );
        // inspect goes through the same checksum gate as load
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(inspect_bytes(&bad).is_err());
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(from_bytes(b"not an artifact").is_err());
        assert!(from_bytes(b"").is_err());
        let mut fake = Vec::new();
        fake.extend_from_slice(b"LTM1");
        fake.extend_from_slice(&[0u8; 32]);
        assert!(from_bytes(&fake).is_err(), "checksumless bytes must fail");
    }
}
