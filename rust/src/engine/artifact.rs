//! The `.ltm` compiled-model artifact: a versioned binary container
//! holding everything a deployment serves — the engine plan plus every
//! stage's tables and metadata. `serve`/`eval` can start from an
//! artifact without weights or recompilation, and the round-trip is
//! bit-exact (same classes, same logits, same zero-multiply counters;
//! asserted by `rust/tests/artifact_roundtrip.rs`).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic   b"LTM1"
//! u32     container version (1)
//! u32     plan JSON length | plan JSON (the EnginePlan, via config)
//! u32     stage count
//! stage*  u16 kind tag | u64 payload length | payload bytes
//! u64     FNV-1a 64 checksum of every preceding byte
//! ```
//!
//! Stage payloads are owned by the stage modules (`Stage::write_payload`
//! / `read_stage`), so new stage kinds serialize without touching this
//! container. The trailing checksum rejects truncation and bit rot
//! before any payload is parsed.

use crate::engine::stages::{read_stage, Stage, StageKind};
use crate::engine::LutModel;
use crate::lut::wire::{self, Reader};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"LTM1";
pub const VERSION: u32 = 1;

/// Largest artifact the loader will accept (matches the engine's
/// table materialisation cap with headroom for metadata).
const MAX_ARTIFACT_BYTES: u64 = 8 << 30;

/// FNV-1a 64 (vendored crate set has no hash crates; collision
/// resistance is not a goal — this is an integrity check, not MAC).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a compiled model to the `.ltm` byte format.
pub fn to_bytes(model: &LutModel) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    wire::put_u32(&mut out, VERSION);
    let plan_json = crate::config::plan_to_json(model.plan()).to_string();
    wire::put_u32(&mut out, plan_json.len() as u32);
    out.extend_from_slice(plan_json.as_bytes());
    wire::put_u32(&mut out, model.stages().len() as u32);
    let mut payload = Vec::new();
    for stage in model.stages() {
        payload.clear();
        stage.write_payload(&mut payload);
        wire::put_u16(&mut out, stage.kind().tag());
        wire::put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
    }
    let sum = fnv1a(&out);
    wire::put_u64(&mut out, sum);
    out
}

/// Parse a `.ltm` byte buffer back into a compiled model.
pub fn from_bytes(bytes: &[u8]) -> Result<LutModel> {
    if bytes.len() < MAGIC.len() + 4 + 4 + 4 + 8 {
        bail!("artifact too short ({} bytes) to be a .ltm file", bytes.len());
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    let computed = fnv1a(body);
    if stored != computed {
        bail!("artifact checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — file is corrupted or truncated");
    }
    let mut r = Reader::new(body);
    let magic = r.take(4).map_err(wire_err)?;
    if magic != MAGIC {
        bail!("bad artifact magic {magic:?}, expected {MAGIC:?}");
    }
    let version = r.u32().map_err(wire_err)?;
    if version != VERSION {
        bail!("unsupported .ltm version {version} (this build reads {VERSION})");
    }
    let plan_len = r
        .len_capped_u32(1 << 20, "plan JSON")
        .map_err(wire_err)?;
    let plan_bytes = r.take(plan_len).map_err(wire_err)?;
    let plan_text =
        std::str::from_utf8(plan_bytes).context("artifact plan JSON is not utf-8")?;
    let plan_json = crate::config::json::Json::parse(plan_text)
        .map_err(|e| anyhow!("artifact plan JSON: {e}"))?;
    let plan = crate::config::plan_from_json(&plan_json)?;
    let n_stages = r.u32().map_err(wire_err)? as usize;
    if n_stages > 4096 {
        bail!("artifact claims {n_stages} stages — refusing");
    }
    let mut stages: Vec<Box<dyn Stage>> = Vec::with_capacity(n_stages);
    for i in 0..n_stages {
        let tag = r.u16().map_err(wire_err)?;
        let kind = StageKind::from_tag(tag)
            .ok_or_else(|| anyhow!("stage {i}: unknown kind tag {tag}"))?;
        let len = r.u64().map_err(wire_err)? as usize;
        let payload = r
            .take(len)
            .map_err(wire_err)
            .with_context(|| format!("stage {i} ({}) payload", kind.name()))?;
        let mut pr = Reader::new(payload);
        let stage = read_stage(kind, &mut pr)
            .map_err(wire_err)
            .with_context(|| format!("decoding stage {i} ({})", kind.name()))?;
        if !pr.is_empty() {
            bail!(
                "stage {i} ({}) payload has {} trailing bytes",
                kind.name(),
                pr.remaining()
            );
        }
        stages.push(stage);
    }
    if !r.is_empty() {
        bail!("artifact has {} trailing bytes after the stage table", r.remaining());
    }
    // pipeline-level sanity: each payload validated its own shape above,
    // but a crafted (checksum-recomputed) artifact could still describe
    // an unservable pipeline. Reject the cheap-to-check global
    // invariants here; per-stage input contracts (representation tags,
    // code widths) are additionally hard-asserted by the stages on
    // first use, so an inconsistent pipeline fails loudly, never with
    // out-of-bounds indexing.
    if stages.is_empty() {
        bail!("artifact describes an empty pipeline");
    }
    // mirror the runtime contract (inference argmaxes integer
    // accumulators): walking back over the Acc-preserving stages
    // (ReLU, max-pool), the pipeline must reach an affine bank. This
    // accepts exactly the pipelines `infer` can finish.
    let tail_bank = stages
        .iter()
        .rev()
        .map(|s| s.kind())
        .find(|k| !matches!(k, StageKind::ReluInt | StageKind::MaxPool2Int));
    let ends_in_acc = matches!(
        tail_bank,
        Some(
            StageKind::DenseWhole
                | StageKind::DenseBitplane
                | StageKind::DenseFloat
                | StageKind::ConvFixed
                | StageKind::ConvFloat
        )
    );
    if !ends_in_acc {
        bail!(
            "artifact pipeline ends with {} — inference must end on integer accumulators",
            stages.last().unwrap().kind().name()
        );
    }
    Ok(LutModel::from_parts(stages, plan))
}

fn wire_err(e: wire::WireError) -> anyhow::Error {
    anyhow!("{e}")
}

/// Write a compiled model to `path`.
pub fn save(model: &LutModel, path: &Path) -> Result<()> {
    let bytes = to_bytes(model);
    std::fs::write(path, bytes)
        .with_context(|| format!("writing artifact {}", path.display()))
}

/// Load a compiled model from `path`.
pub fn load(path: &Path) -> Result<LutModel> {
    let meta = std::fs::metadata(path)
        .with_context(|| format!("reading artifact {}", path.display()))?;
    if meta.len() > MAX_ARTIFACT_BYTES {
        bail!(
            "artifact {} is {} bytes — larger than the {} byte cap",
            path.display(),
            meta.len(),
            MAX_ARTIFACT_BYTES
        );
    }
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading artifact {}", path.display()))?;
    from_bytes(&bytes).with_context(|| format!("parsing artifact {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vectors() {
        // reference vectors for FNV-1a 64
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(from_bytes(b"not an artifact").is_err());
        assert!(from_bytes(b"").is_err());
        let mut fake = Vec::new();
        fake.extend_from_slice(b"LTM1");
        fake.extend_from_slice(&[0u8; 32]);
        assert!(from_bytes(&fake).is_err(), "checksumless bytes must fail");
    }
}
