//! The `.ltm` compiled-model artifact: a versioned binary container
//! holding everything a deployment serves — the engine plan plus every
//! stage's tables and metadata. `serve`/`eval` can start from an
//! artifact without weights or recompilation, and the round-trip is
//! bit-exact (same classes, same logits, same zero-multiply counters;
//! asserted by `rust/tests/artifact_roundtrip.rs`).
//!
//! Two container versions are readable; v2 is written (all integers
//! little-endian):
//!
//! **v2 — zero-copy layout** (written by [`to_bytes`]):
//!
//! ```text
//! magic   b"LTM1"
//! u32     container version (2)
//! u32     plan JSON length | plan JSON (the EnginePlan, via config)
//! u32     stage count
//! stage*  u16 kind tag | u64 payload offset | u64 payload length
//!         | u64 payload FNV-1a 64
//! u64     FNV-1a 64 of every preceding byte (the header checksum)
//! stage payloads, back to back (offsets above are file-absolute;
//! every table-arena entry block inside them is padded to a 64-byte
//! file offset)
//! ```
//!
//! The per-stage checksums localise corruption ("stage 3 checksum
//! mismatch at offset 0x…"), and the 64-byte alignment lets
//! [`load`] memory-map the file and hand each bank its entry block
//! *in place* — zero table-payload copies and zero table-sized heap
//! allocations; the load's cost is the one sequential checksum scan
//! over the mapping (see [`crate::bytes`] and [`crate::lut::arena`]).
//!
//! **v1 — legacy packed layout** (still loaded, via the copying path):
//!
//! ```text
//! magic   b"LTM1"
//! u32     container version (1)
//! u32     plan JSON length | plan JSON
//! u32     stage count
//! stage*  u16 kind tag | u64 payload length | payload bytes
//! u64     FNV-1a 64 checksum of every preceding byte
//! ```
//!
//! Stage payloads are owned by the stage modules (`Stage::write_payload`
//! / `read_stage`), so new stage kinds serialize without touching this
//! container.

use crate::bytes::ArtifactBytes;
use crate::engine::stages::{read_stage, Stage, StageKind};
use crate::engine::LutModel;
use crate::lut::arena::ArenaResidency;
use crate::lut::wire::{self, Reader, WireCtx};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

pub const MAGIC: &[u8; 4] = b"LTM1";
/// Container version written by [`to_bytes`] / [`save`].
pub const VERSION: u32 = 2;
/// Legacy packed container version (read-only compatibility).
pub const VERSION_V1: u32 = 1;

/// Largest artifact the loader will accept (matches the engine's
/// table materialisation cap with headroom for metadata).
const MAX_ARTIFACT_BYTES: u64 = 8 << 30;

/// Bytes of one v2 stage-index record: kind + offset + length + fnv.
const V2_INDEX_RECORD: usize = 2 + 8 + 8 + 8;

/// FNV-1a 64 (vendored crate set has no hash crates; collision
/// resistance is not a goal — this is an integrity check, not MAC).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a compiled model to the current (v2) `.ltm` byte format:
/// indexed, per-stage-checksummed, arena entry blocks 64-byte-aligned.
pub fn to_bytes(model: &LutModel) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    wire::put_u32(&mut out, VERSION);
    let plan_json = crate::config::plan_to_json(model.plan()).to_string();
    wire::put_u32(&mut out, plan_json.len() as u32);
    out.extend_from_slice(plan_json.as_bytes());
    let n = model.stages().len();
    wire::put_u32(&mut out, n as u32);
    // reserve the index + header checksum, backpatched once payload
    // offsets are known
    let idx_pos = out.len();
    out.resize(out.len() + n * V2_INDEX_RECORD + 8, 0);
    // payloads go straight into the container buffer: `out.len()` IS
    // the file offset, which is what lets the arenas place their entry
    // blocks on 64-byte file boundaries
    let mut index = Vec::with_capacity(n);
    for stage in model.stages() {
        let start = out.len();
        stage.write_payload(&mut out, true);
        let sum = fnv1a(&out[start..]);
        index.push((stage.kind().tag(), start as u64, (out.len() - start) as u64, sum));
    }
    let mut idx_bytes = Vec::with_capacity(n * V2_INDEX_RECORD);
    for (tag, off, len, sum) in index {
        wire::put_u16(&mut idx_bytes, tag);
        wire::put_u64(&mut idx_bytes, off);
        wire::put_u64(&mut idx_bytes, len);
        wire::put_u64(&mut idx_bytes, sum);
    }
    out[idx_pos..idx_pos + idx_bytes.len()].copy_from_slice(&idx_bytes);
    let fnv_pos = idx_pos + idx_bytes.len();
    let header_sum = fnv1a(&out[..fnv_pos]);
    out[fnv_pos..fnv_pos + 8].copy_from_slice(&header_sum.to_le_bytes());
    out
}

/// Serialize to the legacy v1 packed format. Kept for the
/// compatibility matrix (old readers, and tests proving v1 files still
/// load bit-exact); new artifacts should use [`to_bytes`].
pub fn to_bytes_v1(model: &LutModel) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    wire::put_u32(&mut out, VERSION_V1);
    let plan_json = crate::config::plan_to_json(model.plan()).to_string();
    wire::put_u32(&mut out, plan_json.len() as u32);
    out.extend_from_slice(plan_json.as_bytes());
    wire::put_u32(&mut out, model.stages().len() as u32);
    let mut payload = Vec::new();
    for stage in model.stages() {
        payload.clear();
        stage.write_payload(&mut payload, false);
        wire::put_u16(&mut out, stage.kind().tag());
        wire::put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
    }
    let sum = fnv1a(&out);
    wire::put_u64(&mut out, sum);
    out
}

/// One stage record of a parsed container: checksum-verified, payload
/// still undecoded.
struct StageRecord<'a> {
    kind: StageKind,
    payload: &'a [u8],
    /// File offset of the payload (v2; v1 records the in-body offset).
    offset: u64,
    /// Stored per-stage checksum (v2 only).
    checksum: Option<u64>,
}

/// The parsed container header + stage table of a `.ltm` buffer:
/// checksum-verified, payloads still undecoded. This is the ONE
/// header-read path — [`from_bytes`] / [`load`] (registry / `serve`
/// loads) and [`inspect_bytes`] (`tablenet inspect`) all start here.
struct Container<'a> {
    version: u32,
    plan_json: &'a str,
    plan: crate::engine::plan::EnginePlan,
    stages: Vec<StageRecord<'a>>,
}

fn parse_container(bytes: &[u8]) -> Result<Container<'_>> {
    if bytes.len() < MAGIC.len() + 4 + 4 + 4 + 8 {
        bail!("artifact too short ({} bytes) to be a .ltm file", bytes.len());
    }
    let mut r = Reader::new(bytes);
    let magic = r.take(4).map_err(wire_err)?;
    if magic != MAGIC {
        bail!("bad artifact magic {magic:?}, expected {MAGIC:?}");
    }
    let version = r.u32().map_err(wire_err)?;
    match version {
        VERSION_V1 => parse_container_v1(bytes),
        VERSION => parse_container_v2(bytes, r),
        other => {
            bail!("unsupported .ltm version {other} (this build reads {VERSION_V1} and {VERSION})")
        }
    }
}

/// Shared plan-JSON decode (both container versions embed it the same
/// way: u32 length + verbatim JSON).
fn parse_plan<'a>(
    r: &mut Reader<'a>,
) -> Result<(&'a str, crate::engine::plan::EnginePlan)> {
    let plan_len = r.len_capped_u32(1 << 20, "plan JSON").map_err(wire_err)?;
    let plan_bytes = r.take(plan_len).map_err(wire_err)?;
    let plan_json = std::str::from_utf8(plan_bytes).context("artifact plan JSON is not utf-8")?;
    let parsed = crate::config::json::Json::parse(plan_json)
        .map_err(|e| anyhow!("artifact plan JSON: {e}"))?;
    let plan = crate::config::plan_from_json(&parsed)?;
    Ok((plan_json, plan))
}

/// v1: whole-file trailing checksum, packed inline payloads.
fn parse_container_v1(bytes: &[u8]) -> Result<Container<'_>> {
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    let computed = fnv1a(body);
    if stored != computed {
        bail!("artifact checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — file is corrupted or truncated");
    }
    let mut r = Reader::new(body);
    r.take(4).map_err(wire_err)?; // magic, already validated
    r.u32().map_err(wire_err)?; // version, already validated
    let (plan_json, plan) = parse_plan(&mut r)?;
    let n_stages = r.u32().map_err(wire_err)? as usize;
    if n_stages > 4096 {
        bail!("artifact claims {n_stages} stages — refusing");
    }
    let mut stages = Vec::with_capacity(n_stages);
    for i in 0..n_stages {
        let tag = r.u16().map_err(wire_err)?;
        let kind = StageKind::from_tag(tag)
            .ok_or_else(|| anyhow!("stage {i}: unknown kind tag {tag}"))?;
        let len = r.u64().map_err(wire_err)? as usize;
        let offset = (body.len() - r.remaining()) as u64;
        let payload = r
            .take(len)
            .map_err(wire_err)
            .with_context(|| format!("stage {i} ({}) payload", kind.name()))?;
        stages.push(StageRecord { kind, payload, offset, checksum: None });
    }
    if !r.is_empty() {
        bail!("artifact has {} trailing bytes after the stage table", r.remaining());
    }
    Ok(Container { version: VERSION_V1, plan_json, plan, stages })
}

/// v2: checksummed header with an absolute-offset stage index, then
/// back-to-back payloads, each covered by its own checksum. Every byte
/// of the file is covered by exactly one checksum, so corruption is
/// always caught AND localised to a stage + offset.
///
/// Order matters: the header is walked with bounds-checked,
/// length-capped reads ONLY until its checksum verifies; the plan JSON
/// is not handed to the parser (and no payload is decoded) before
/// that — corrupted bytes fail as "checksum mismatch", never as a
/// confusing downstream parse error (the invariant v1's whole-file
/// checksum provided).
fn parse_container_v2<'a>(bytes: &'a [u8], mut r: Reader<'a>) -> Result<Container<'a>> {
    let plan_len = r.len_capped_u32(1 << 20, "plan JSON").map_err(wire_err)?;
    let plan_bytes = r.take(plan_len).map_err(wire_err)?;
    let n_stages = r.u32().map_err(wire_err)? as usize;
    if n_stages > 4096 {
        bail!("artifact claims {n_stages} stages — refusing");
    }
    let mut index = Vec::with_capacity(n_stages);
    for i in 0..n_stages {
        let tag = r.u16().map_err(wire_err)?;
        let off = r.u64().map_err(wire_err)?;
        let len = r.u64().map_err(wire_err)?;
        let sum = r.u64().map_err(wire_err)?;
        index.push((i, tag, off, len, sum));
    }
    let fnv_pos = bytes.len() - r.remaining();
    let stored = r.u64().map_err(wire_err)?;
    let computed = fnv1a(&bytes[..fnv_pos]);
    if stored != computed {
        bail!("artifact header checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) — file is corrupted or truncated");
    }
    // the header is now trusted: decode the plan and resolve kind tags
    let plan_json =
        std::str::from_utf8(plan_bytes).context("artifact plan JSON is not utf-8")?;
    let parsed = crate::config::json::Json::parse(plan_json)
        .map_err(|e| anyhow!("artifact plan JSON: {e}"))?;
    let plan = crate::config::plan_from_json(&parsed)?;
    let index: Vec<(StageKind, u64, u64, u64)> = index
        .into_iter()
        .map(|(i, tag, off, len, sum)| {
            StageKind::from_tag(tag)
                .map(|kind| (kind, off, len, sum))
                .ok_or_else(|| anyhow!("stage {i}: unknown kind tag {tag}"))
        })
        .collect::<Result<_>>()?;
    // the index is now trusted (header checksum): payloads must tile
    // the rest of the file exactly, so no byte escapes a checksum
    let payload_base = (fnv_pos + 8) as u64;
    let mut expect = payload_base;
    let mut stages = Vec::with_capacity(n_stages);
    for (i, &(kind, off, len, sum)) in index.iter().enumerate() {
        if off != expect {
            bail!(
                "stage {i} ({}) payload offset {off:#x} does not follow the previous stage (expected {expect:#x})",
                kind.name()
            );
        }
        let end = off
            .checked_add(len)
            .filter(|&e| e <= bytes.len() as u64)
            .ok_or_else(|| {
                anyhow!(
                    "stage {i} ({}) payload at offset {off:#x} (+{len} bytes) runs past the end of the {}-byte file — truncated?",
                    kind.name(),
                    bytes.len()
                )
            })?;
        let payload = &bytes[off as usize..end as usize];
        let computed = fnv1a(payload);
        if computed != sum {
            bail!(
                "stage {i} ({}) checksum mismatch at offset {off:#x} (stored {sum:#018x}, computed {computed:#018x}) — file is corrupted",
                kind.name()
            );
        }
        stages.push(StageRecord { kind, payload, offset: off, checksum: Some(sum) });
        expect = end;
    }
    if expect != bytes.len() as u64 {
        bail!(
            "artifact has {} trailing bytes after the last stage payload",
            bytes.len() as u64 - expect
        );
    }
    Ok(Container { version: VERSION, plan_json, plan, stages })
}

/// Decode every stage payload of a parsed container, enforcing the
/// per-stage trailing-bytes rule. Shared by [`from_bytes`] and
/// [`inspect_bytes`] so an artifact inspect accepts is exactly one a
/// serve load accepts.
fn decode_stages(records: &[StageRecord], ctx: &WireCtx) -> Result<Vec<Box<dyn Stage>>> {
    let mut stages: Vec<Box<dyn Stage>> = Vec::with_capacity(records.len());
    for (i, rec) in records.iter().enumerate() {
        let mut pr = Reader::new(rec.payload);
        let stage = read_stage(rec.kind, &mut pr, ctx)
            .map_err(wire_err)
            .with_context(|| format!("decoding stage {i} ({})", rec.kind.name()))?;
        if !pr.is_empty() {
            bail!(
                "stage {i} ({}) payload has {} trailing bytes",
                rec.kind.name(),
                pr.remaining()
            );
        }
        stages.push(stage);
    }
    Ok(stages)
}

/// Pipeline-level sanity: each payload validated its own shape during
/// decode, but a crafted (checksum-recomputed) artifact could still
/// describe an unservable pipeline. Reject the cheap-to-check global
/// invariants here; per-stage input contracts (representation tags,
/// code widths) are additionally hard-asserted by the stages on first
/// use, so an inconsistent pipeline fails loudly, never with
/// out-of-bounds indexing.
fn validate_pipeline(stages: &[Box<dyn Stage>]) -> Result<()> {
    if stages.is_empty() {
        bail!("artifact describes an empty pipeline");
    }
    // mirror the runtime contract (inference argmaxes integer
    // accumulators): walking back over the Acc-preserving stages
    // (ReLU, max-pool), the pipeline must reach an affine bank whose
    // fused epilogue (if any) still ends on accumulators. This accepts
    // exactly the pipelines `infer` can finish.
    let tail_bank = stages
        .iter()
        .rev()
        .find(|s| !matches!(s.kind(), StageKind::ReluInt | StageKind::MaxPool2Int));
    let ends_in_acc = tail_bank.is_some_and(|s| {
        s.kind().is_bank() && s.fused_chain().is_none_or(|c| c.ends_in_acc())
    });
    if !ends_in_acc {
        bail!(
            "artifact pipeline ends with {} — inference must end on integer accumulators",
            stages.last().unwrap().kind().name()
        );
    }
    Ok(())
}

/// Parse a `.ltm` byte buffer back into a compiled model. Transient
/// buffer: arenas are always copied onto the heap. The serving path
/// ([`load`]) maps the file instead and borrows v2 arenas zero-copy.
pub fn from_bytes(bytes: &[u8]) -> Result<LutModel> {
    let c = parse_container(bytes)?;
    let ctx = WireCtx { aligned: c.version >= VERSION, backing: None };
    let stages = decode_stages(&c.stages, &ctx)?;
    validate_pipeline(&stages)?;
    Ok(LutModel::from_parts(stages, c.plan))
}

/// Parse an [`ArtifactBytes`] buffer into a compiled model, borrowing
/// v2 table arenas from the buffer zero-copy (the `Arc` keeps the
/// mapping alive for the model's lifetime). v1 containers — and any
/// misaligned block — decode through the copying path, bit-exact.
pub fn from_artifact_bytes(owner: &Arc<ArtifactBytes>) -> Result<LutModel> {
    let c = parse_container(owner)?;
    let ctx = WireCtx { aligned: c.version >= VERSION, backing: Some(owner) };
    let stages = decode_stages(&c.stages, &ctx)?;
    validate_pipeline(&stages)?;
    Ok(LutModel::from_parts(stages, c.plan))
}

fn wire_err(e: wire::WireError) -> anyhow::Error {
    anyhow!("{e}")
}

/// Write a compiled model to `path` (v2 format).
pub fn save(model: &LutModel, path: &Path) -> Result<()> {
    let bytes = to_bytes(model);
    std::fs::write(path, bytes)
        .with_context(|| format!("writing artifact {}", path.display()))
}

/// Load a compiled model from `path`. The file is memory-mapped when
/// the platform allows; a v2 artifact is then served *in place* — zero
/// table-payload copies and no table-sized allocations. The load's
/// cost is one sequential checksum scan over the mapping (integrity
/// is always verified before serving).
pub fn load(path: &Path) -> Result<LutModel> {
    let owner = Arc::new(open_bytes(path)?);
    from_artifact_bytes(&owner).with_context(|| format!("parsing artifact {}", path.display()))
}

fn open_bytes(path: &Path) -> Result<ArtifactBytes> {
    ArtifactBytes::open(path, MAX_ARTIFACT_BYTES)
        .with_context(|| format!("reading artifact {}", path.display()))
}

/// Content fingerprint of an artifact file, read from its own
/// checksums in O(header) time: the v2 header checksum covers the
/// whole stage index *including every per-stage payload checksum*, so
/// it identifies the full contents; v1 stores a whole-file trailing
/// checksum. Used by the deploy watcher to distinguish a real content
/// change from a bare mtime touch without re-reading gigabyte banks.
pub fn content_fingerprint(path: &Path) -> Result<u64> {
    let bytes = open_bytes(path)?;
    if bytes.len() < MAGIC.len() + 4 + 4 + 4 + 8 {
        bail!("artifact too short ({} bytes) to be a .ltm file", bytes.len());
    }
    let mut r = Reader::new(&bytes);
    let magic = r.take(4).map_err(wire_err)?;
    if magic != MAGIC {
        bail!("bad artifact magic {magic:?}, expected {MAGIC:?}");
    }
    match r.u32().map_err(wire_err)? {
        VERSION_V1 => {
            let tail = &bytes[bytes.len() - 8..];
            Ok(u64::from_le_bytes(tail.try_into().unwrap()))
        }
        VERSION => {
            let plan_len = r.len_capped_u32(1 << 20, "plan JSON").map_err(wire_err)?;
            r.take(plan_len).map_err(wire_err)?;
            let n = r.u32().map_err(wire_err)? as usize;
            if n > 4096 {
                bail!("artifact claims {n} stages — refusing");
            }
            r.take(n * V2_INDEX_RECORD).map_err(wire_err)?;
            r.u64().map_err(wire_err)
        }
        other => bail!("unsupported .ltm version {other}"),
    }
}

/// What `tablenet inspect` reports about one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Container format version (1 = packed/copying, 2 = zero-copy).
    pub version: u32,
    /// The embedded engine plan, verbatim JSON.
    pub plan_json: String,
    /// Per-stage kind + payload/table accounting, in pipeline order.
    pub stages: Vec<StageInfo>,
    /// Input features of the pipeline (first bank's geometry).
    pub input_features: Option<usize>,
    /// Total file size in bytes.
    pub total_bytes: u64,
    /// Total LUT storage in bits at the plan's accounting width.
    pub size_bits: u64,
    /// True when the inspected bytes were memory-mapped (the borrowed
    /// residencies below then reflect exactly what a serve load does).
    pub mapped: bool,
}

/// One stage row of an [`ArtifactInfo`].
#[derive(Debug, Clone)]
pub struct StageInfo {
    pub kind: StageKind,
    /// On-disk payload bytes (tables + metadata).
    pub payload_bytes: u64,
    /// Table storage in bits at the plan's accounting width.
    pub size_bits: u64,
    /// File offset of the payload.
    pub offset: u64,
    /// Stored per-stage checksum (v2 containers only).
    pub checksum: Option<u64>,
    /// Decoded table residency: bytes / narrowing / borrowed-vs-owned
    /// (`None` for table-free stages).
    pub storage: Option<ArenaResidency>,
    /// Kinds of the elementwise chain fused into this bank by the
    /// stage-folding optimizer (empty for unfused stages). `inspect`
    /// renders it as a `+`-joined suffix, e.g.
    /// `dense-whole+relu-int+to-fixed`.
    pub fused: Vec<StageKind>,
}

impl StageInfo {
    /// Display name of the stage including its fused chain
    /// (`dense-float+relu-int+to-half`; bare kind name when unfused).
    pub fn display_name(&self) -> String {
        let mut s = self.kind.name().to_string();
        for k in &self.fused {
            s.push('+');
            s.push_str(k.name());
        }
        s
    }
}

fn inspect_container(bytes: &[u8], ctx_backing: Option<&Arc<ArtifactBytes>>) -> Result<ArtifactInfo> {
    let c = parse_container(bytes)?;
    let ctx = WireCtx { aligned: c.version >= VERSION, backing: ctx_backing };
    let decoded = decode_stages(&c.stages, &ctx)?;
    validate_pipeline(&decoded)?;
    let r_o = c.plan.r_o;
    let mut stages = Vec::with_capacity(decoded.len());
    let mut size_bits = 0u64;
    let mut input_features = None;
    for (stage, rec) in decoded.iter().zip(&c.stages) {
        let bits = stage.size_bits(r_o);
        size_bits += bits;
        if input_features.is_none() {
            input_features = stage.in_elems();
        }
        stages.push(StageInfo {
            kind: rec.kind,
            payload_bytes: rec.payload.len() as u64,
            size_bits: bits,
            offset: rec.offset,
            checksum: rec.checksum,
            storage: stage.storage(),
            fused: stage.fused_chain().map(|c| c.kinds()).unwrap_or_default(),
        });
    }
    Ok(ArtifactInfo {
        version: c.version,
        plan_json: c.plan_json.to_string(),
        stages,
        input_features,
        total_bytes: bytes.len() as u64,
        size_bits,
        mapped: ctx_backing.map(|o| o.is_mapped()).unwrap_or(false),
    })
}

/// Inspect a `.ltm` buffer: checksums, header, stage table and
/// per-stage table sizes — the same parse + decode + validate path the
/// serving registry loads through, so inspect-clean means
/// serve-loadable (trailing payload bytes and unservable pipelines
/// fail inspect too).
pub fn inspect_bytes(bytes: &[u8]) -> Result<ArtifactInfo> {
    inspect_container(bytes, None)
}

/// [`inspect_bytes`] over a file, memory-mapped like a serve load so
/// the reported borrowed-vs-owned residency is the serving truth.
pub fn inspect(path: &Path) -> Result<ArtifactInfo> {
    let owner = Arc::new(open_bytes(path)?);
    inspect_container(&owner[..], Some(&owner))
        .with_context(|| format!("inspecting artifact {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vectors() {
        // reference vectors for FNV-1a 64
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    fn small_model() -> LutModel {
        use crate::engine::plan::EnginePlan;
        use crate::engine::Compiler;
        use crate::nn::Model;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(90);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        Compiler::new(&model)
            .plan(&EnginePlan::linear_default())
            .build()
            .unwrap()
    }

    #[test]
    fn inspect_agrees_with_loaded_model() {
        let lut = small_model();
        let bytes = to_bytes(&lut);
        let info = inspect_bytes(&bytes).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!(info.total_bytes, bytes.len() as u64);
        assert_eq!(info.stages.len(), lut.num_stages());
        assert_eq!(info.size_bits, lut.size_bits());
        assert_eq!(info.input_features, Some(784));
        assert_eq!(
            info.plan_json,
            crate::config::plan_to_json(lut.plan()).to_string()
        );
        // v2 carries a checksum and an offset per stage
        for s in &info.stages {
            assert!(s.checksum.is_some());
            assert!(s.offset > 0);
        }
        // inspect goes through the same checksum gates as load
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(inspect_bytes(&bad).is_err());
    }

    #[test]
    fn v2_stage_corruption_is_localised() {
        let lut = small_model();
        let bytes = to_bytes(&lut);
        let info = inspect_bytes(&bytes).unwrap();
        // flip one byte inside the LAST stage's payload: the error must
        // name that stage and its offset, not just "bad file"
        let last = info.stages.last().unwrap();
        let i = info.stages.len() - 1;
        let mut bad = bytes.clone();
        bad[last.offset as usize + last.payload_bytes as usize / 2] ^= 0x01;
        let err = format!("{:#}", from_bytes(&bad).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains(&format!("stage {i}")), "{err}");
        assert!(err.contains(&format!("{:#x}", last.offset)), "{err}");
    }

    #[test]
    fn v1_writer_roundtrips_through_the_same_loader() {
        let lut = small_model();
        let v1 = to_bytes_v1(&lut);
        let back = from_bytes(&v1).unwrap();
        assert_eq!(back.num_stages(), lut.num_stages());
        assert_eq!(back.size_bits(), lut.size_bits());
        let info = inspect_bytes(&v1).unwrap();
        assert_eq!(info.version, VERSION_V1);
        assert!(info.stages.iter().all(|s| s.checksum.is_none()));
    }

    #[test]
    fn content_fingerprint_tracks_content_not_encoding_noise() {
        let lut = small_model();
        let dir = std::env::temp_dir().join("tablenet_fp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("a.ltm");
        let p2 = dir.join("b.ltm");
        std::fs::write(&p1, to_bytes(&lut)).unwrap();
        std::fs::write(&p2, to_bytes(&lut)).unwrap();
        // identical content, distinct files/mtimes -> same fingerprint
        assert_eq!(
            content_fingerprint(&p1).unwrap(),
            content_fingerprint(&p2).unwrap()
        );
        // v1 encoding of the same model is a different artifact
        std::fs::write(&p2, to_bytes_v1(&lut)).unwrap();
        assert_ne!(
            content_fingerprint(&p1).unwrap(),
            content_fingerprint(&p2).unwrap()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(from_bytes(b"not an artifact").is_err());
        assert!(from_bytes(b"").is_err());
        let mut fake = Vec::new();
        fake.extend_from_slice(b"LTM1");
        fake.extend_from_slice(&[0u8; 32]);
        assert!(from_bytes(&fake).is_err(), "checksumless bytes must fail");
        // future container version: clean error, not a misparse
        let mut vnext = Vec::new();
        vnext.extend_from_slice(b"LTM1");
        wire::put_u32(&mut vnext, 99);
        vnext.extend_from_slice(&[0u8; 32]);
        let err = format!("{:#}", from_bytes(&vnext).unwrap_err());
        assert!(err.contains("unsupported .ltm version 99"), "{err}");
    }
}
