//! Fused elementwise epilogues: the chain of `relu` / `tofixed` /
//! `tohalf` / `sigmoid` stages a LUT bank absorbs at compile time.
//!
//! The paper's core observation is that a table lookup computes *any*
//! function of its input chunk at zero extra cost, so running the
//! activation/boundary ops as separate full-width passes over the
//! activation buffer wastes memory sweeps. Rewriting the bank's table
//! entries literally, however, is only exact for banks with a single
//! lookup per output: every bank here computes `acc = Σ_chunks
//! T_c[idx_c]` (plus shifted bitplane / mantissa-plane sums), and a
//! nonlinear function of the *sum* does not distribute over the
//! summands — and the single-lookup configuration blows past the table
//! materialisation cap at real layer widths. So the optimizer fuses the
//! honest way: the absorbed chain's stage objects move *into* the bank
//! and run as an epilogue over the bank's just-written accumulator
//! rows, while still hot, inside one [`Stage::eval_batch`] call. The
//! executed op stream is identical to the unfused plan — bit-exactness
//! and exact per-sample counters hold by construction — but the plan
//! has strictly fewer stages, the artifact has fewer index records, and
//! `inspect` reports the fused pipeline honestly
//! (e.g. `dense-whole+relu-int+to-fixed`).
//!
//! Legality is a tiny representation state machine ([`elem_transition`])
//! starting at the bank's output representation (integer accumulators):
//! a chain element is fusible only when the standalone stage would have
//! accepted that representation. Chains never cross a bank or a
//! `maxpool` (it reshapes the activation; fusing across it is a ROADMAP
//! follow-up), and a chain on the *final* bank is trimmed to the
//! longest prefix that still ends on accumulators, because inference
//! argmaxes integers ([`crate::engine::LutModel`]).

use crate::engine::act::ActBuf;
use crate::engine::counters::Counters;
use crate::engine::scratch::Scratch;
use crate::engine::stages::{read_stage, Stage, StageKind};
use crate::lut::wire::{self, WireCtx};

/// Upper bound on fused-chain length accepted from an artifact (a real
/// compiled chain is ≤ 3 elements; this is a decode sanity cap).
pub const MAX_CHAIN: usize = 16;

/// Activation representation flowing through a fused chain (the subset
/// of [`crate::engine::act::Repr`] reachable after a bank).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChainState {
    /// Integer accumulators (every bank's output).
    Acc,
    /// Fixed-point codes (after `tofixed`).
    Codes,
    /// Binary16 codes (after `tohalf` / `sigmoid`).
    Half,
}

/// Representation transition of fusing `kind` onto a chain in `state`,
/// or `None` when the standalone stage would not accept that
/// representation (then the stage stays standalone and the chain ends).
pub fn elem_transition(state: ChainState, kind: StageKind) -> Option<ChainState> {
    use ChainState::*;
    match kind {
        // relu clamps accumulators; on codes/binary16 it is the same
        // pass-through it is standalone
        StageKind::ReluInt => Some(state),
        // boundary encodes consume accumulators
        StageKind::ToFixed => (state == Acc).then_some(Codes),
        StageKind::ToHalf => match state {
            Acc | Half => Some(Half),
            Codes => Some(Codes), // standalone pass-through
        },
        // the scalar LUT reads binary16 (or signed-encodes accumulators
        // itself); it panics on codes — not fusible there
        StageKind::SigmoidLut => match state {
            Acc | Half => Some(Half),
            Codes => None,
        },
        // banks / maxpool are never chain elements
        _ => None,
    }
}

/// An elementwise stage chain absorbed into a LUT bank. The chain owns
/// the very stage objects the compiler originally emitted; applying it
/// replays their `eval_batch` calls in order, so a fused plan executes
/// the exact op stream of the unfused plan.
pub struct FusedChain {
    stages: Vec<Box<dyn Stage>>,
    out_state: ChainState,
}

impl FusedChain {
    /// Build a chain from stages, validating the representation state
    /// machine from `Acc`. Returns the stages back unchanged when the
    /// chain is empty or not fusible.
    pub fn from_stages(stages: Vec<Box<dyn Stage>>) -> Result<FusedChain, Vec<Box<dyn Stage>>> {
        if stages.is_empty() || stages.len() > MAX_CHAIN {
            return Err(stages);
        }
        let mut state = ChainState::Acc;
        for s in &stages {
            match elem_transition(state, s.kind()) {
                Some(next) => state = next,
                None => return Err(stages),
            }
        }
        Ok(FusedChain { stages, out_state: state })
    }

    /// Give the stages back (un-fusing; used when a bank refuses a
    /// chain so the optimizer can re-emit them standalone).
    pub fn into_stages(self) -> Vec<Box<dyn Stage>> {
        self.stages
    }

    /// Chain length in stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// Never true for a constructed chain ([`FusedChain::from_stages`]
    /// rejects empty chains); here for the `len`/`is_empty` pairing.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Kinds of the absorbed stages, in execution order.
    pub fn kinds(&self) -> Vec<StageKind> {
        self.stages.iter().map(|s| s.kind()).collect()
    }

    /// Representation the chain leaves behind.
    pub fn out_state(&self) -> ChainState {
        self.out_state
    }

    /// Whether the chain output is still integer accumulators (required
    /// of the final pipeline stage).
    pub fn ends_in_acc(&self) -> bool {
        self.out_state == ChainState::Acc
    }

    /// Diagnostics suffix, e.g. `+relu-int+to-half` — `inspect` and the
    /// compile banner append it to the bank's kind name.
    pub fn display_suffix(&self) -> String {
        let mut s = String::new();
        for st in &self.stages {
            s.push('+');
            s.push_str(st.kind().name());
        }
        s
    }

    /// Run the absorbed chain over the bank's just-written output.
    /// Identical calls, identical order, identical buffers as the
    /// standalone stages — bit-exact by construction.
    pub fn apply(&self, act: &mut ActBuf, scratch: &mut Scratch, counters: &mut [Counters]) {
        for stage in &self.stages {
            stage.eval_batch(act, scratch, counters);
        }
    }

    /// Table storage the chain contributes (the 128 KiB scalar LUT when
    /// a sigmoid is fused; the boundary/relu stages are table-free).
    pub fn size_bits(&self, r_o: u32) -> u64 {
        self.stages.iter().map(|s| s.size_bits(r_o)).sum()
    }

    /// Serialize the chain at the end of the owning bank's payload:
    /// `u16 count`, then per element `u16 kind tag | u64 payload len |
    /// payload bytes`. Unfused banks write nothing, so their artifact
    /// bytes are identical to pre-fusion builds (back-compat is "the
    /// payload reader is empty").
    pub fn write_wire(&self, out: &mut Vec<u8>) {
        wire::put_u16(out, self.stages.len() as u16);
        let mut payload = Vec::new();
        for stage in &self.stages {
            payload.clear();
            // chain elements are table-free or heap-decoded (sigmoid) —
            // the v2 arena alignment machinery does not apply to them
            stage.write_payload(&mut payload, false);
            wire::put_u16(out, stage.kind().tag());
            wire::put_u64(out, payload.len() as u64);
            out.extend_from_slice(&payload);
        }
    }

    /// Decode an optional chain from the tail of a bank payload: `None`
    /// when the reader is already empty (an unfused / pre-fusion
    /// artifact). Enforces the same state machine as the optimizer, so
    /// a crafted artifact cannot smuggle an illegal or nested chain.
    pub fn read_wire_opt(r: &mut wire::Reader) -> wire::Result<Option<FusedChain>> {
        if r.is_empty() {
            return Ok(None);
        }
        let n = r.u16()? as usize;
        if n == 0 || n > MAX_CHAIN {
            return wire::err(format!("fused chain: bad element count {n}"));
        }
        let ctx = WireCtx::v1();
        let mut stages: Vec<Box<dyn Stage>> = Vec::with_capacity(n);
        let mut state = ChainState::Acc;
        for i in 0..n {
            let tag = r.u16()?;
            let kind = StageKind::from_tag(tag)
                .ok_or_else(|| wire::WireError(format!("fused chain: unknown kind tag {tag}")))?;
            state = elem_transition(state, kind).ok_or_else(|| {
                wire::WireError(format!(
                    "fused chain: {} is not fusible at element {i}",
                    kind.name()
                ))
            })?;
            let len = r.u64()? as usize;
            let payload = r.take(len)?;
            let mut pr = wire::Reader::new(payload);
            let stage = read_stage(kind, &mut pr, &ctx)?;
            if !pr.is_empty() {
                return wire::err(format!(
                    "fused chain element {i} ({}) has {} trailing bytes",
                    kind.name(),
                    pr.remaining()
                ));
            }
            stages.push(stage);
        }
        Ok(Some(FusedChain { stages, out_state: state }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::act::Repr;
    use crate::engine::stages::{ReluIntStage, SigmoidLutStage, ToFixedStage, ToHalfStage};
    use crate::lut::scalar::ScalarLut;

    fn chain(stages: Vec<Box<dyn Stage>>) -> FusedChain {
        FusedChain::from_stages(stages).unwrap_or_else(|_| panic!("chain rejected"))
    }

    #[test]
    fn transitions_follow_stage_contracts() {
        use ChainState::*;
        assert_eq!(elem_transition(Acc, StageKind::ReluInt), Some(Acc));
        assert_eq!(elem_transition(Acc, StageKind::ToFixed), Some(Codes));
        assert_eq!(elem_transition(Acc, StageKind::ToHalf), Some(Half));
        assert_eq!(elem_transition(Acc, StageKind::SigmoidLut), Some(Half));
        assert_eq!(elem_transition(Half, StageKind::SigmoidLut), Some(Half));
        assert_eq!(elem_transition(Codes, StageKind::ToFixed), None);
        assert_eq!(elem_transition(Codes, StageKind::SigmoidLut), None);
        assert_eq!(elem_transition(Acc, StageKind::DenseWhole), None);
        assert_eq!(elem_transition(Acc, StageKind::MaxPool2Int), None);
    }

    #[test]
    fn apply_matches_standalone_stages() {
        let fc = chain(vec![
            Box::new(ReluIntStage),
            Box::new(ToFixedStage { bits: 3, range_exp: 0 }),
        ]);
        assert!(!fc.ends_in_acc());
        assert_eq!(fc.kinds(), vec![StageKind::ReluInt, StageKind::ToFixed]);
        assert_eq!(fc.display_suffix(), "+relu-int+to-fixed");

        let run_fused = |accs: &[i64]| {
            let mut act = ActBuf::new();
            act.load_f32(&vec![0.0; accs.len()], 1);
            act.acc.clear();
            act.acc.extend_from_slice(accs);
            act.set_repr(Repr::Acc(32));
            let mut scratch = Scratch::new();
            let mut ctrs = vec![Counters::default()];
            fc.apply(&mut act, &mut scratch, &mut ctrs);
            (act.codes.clone(), ctrs[0])
        };
        let run_standalone = |accs: &[i64]| {
            let mut act = ActBuf::new();
            act.load_f32(&vec![0.0; accs.len()], 1);
            act.acc.clear();
            act.acc.extend_from_slice(accs);
            act.set_repr(Repr::Acc(32));
            let mut scratch = Scratch::new();
            let mut ctrs = vec![Counters::default()];
            ReluIntStage.eval_batch(&mut act, &mut scratch, &mut ctrs);
            ToFixedStage { bits: 3, range_exp: 0 }.eval_batch(&mut act, &mut scratch, &mut ctrs);
            (act.codes.clone(), ctrs[0])
        };
        let accs = [1i64 << 31, -5, 0, i64::MAX / 2];
        assert_eq!(run_fused(&accs), run_standalone(&accs));
    }

    #[test]
    fn wire_roundtrip_preserves_chain() {
        let fc = chain(vec![
            Box::new(ReluIntStage),
            Box::new(ToHalfStage),
            Box::new(SigmoidLutStage::new(ScalarLut::sigmoid())),
        ]);
        let mut buf = Vec::new();
        fc.write_wire(&mut buf);
        let back = FusedChain::read_wire_opt(&mut wire::Reader::new(&buf))
            .unwrap()
            .unwrap();
        assert_eq!(back.kinds(), fc.kinds());
        assert_eq!(back.out_state(), fc.out_state());
        // empty reader = no chain (pre-fusion artifacts)
        assert!(FusedChain::read_wire_opt(&mut wire::Reader::new(&[])).unwrap().is_none());
    }

    #[test]
    fn illegal_chains_are_rejected() {
        // tofixed after tohalf would panic standalone — not fusible
        let bad = FusedChain::from_stages(vec![
            Box::new(ToHalfStage) as Box<dyn Stage>,
            Box::new(ToFixedStage { bits: 3, range_exp: 0 }),
        ]);
        assert!(bad.is_err());
        assert!(FusedChain::from_stages(Vec::new()).is_err());
        // crafted wire bytes with an illegal transition must not decode
        let mut buf = Vec::new();
        wire::put_u16(&mut buf, 2);
        wire::put_u16(&mut buf, StageKind::ToFixed.tag());
        wire::put_u64(&mut buf, 8);
        wire::put_u32(&mut buf, 3); // bits
        wire::put_i32(&mut buf, 0); // range_exp
        wire::put_u16(&mut buf, StageKind::SigmoidLut.tag());
        wire::put_u64(&mut buf, 0);
        let err = FusedChain::read_wire_opt(&mut wire::Reader::new(&buf));
        assert!(err.is_err(), "sigmoid on codes must not decode");
    }

    #[test]
    fn size_bits_counts_fused_tables() {
        let fc = chain(vec![Box::new(SigmoidLutStage::new(ScalarLut::sigmoid()))]);
        assert_eq!(fc.size_bits(16), (1u64 << 16) * 16);
        let fc = chain(vec![Box::new(ReluIntStage)]);
        assert_eq!(fc.size_bits(16), 0);
        assert!(fc.ends_in_acc());
    }
}
