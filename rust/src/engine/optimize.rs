//! The compile-time plan optimizer: passes that rewrite the lowered
//! stage pipeline before it is sealed into a
//! [`LutModel`](crate::engine::LutModel).
//!
//! [`Compiler::build`](crate::engine::Compiler::build) used to be a
//! pure 1:1 lowering (one authored layer → one or two stages). This
//! module turns it into an **optimize-then-emit** pipeline: lowering
//! produces the naive stage list, then each optimizer pass rewrites it,
//! and only the result is sealed/serialized. The executed plan may
//! therefore differ from the authored plan — `tablenet inspect` always
//! shows the *optimized* plan (see `docs/ARCHITECTURE.md`, "compiled
//! plan vs authored plan"). Later passes (table dedup, chunk pruning —
//! ROADMAP) slot in after [`fold_elementwise`] as further
//! `Vec<Box<dyn Stage>> -> Vec<Box<dyn Stage>>` rewrites.
//!
//! The one pass implemented today is **stage folding**
//! ([`fold_elementwise`]): each LUT bank absorbs its trailing
//! elementwise chain (`relu`/`tofixed`/`tohalf`/`sigmoid`) as a fused
//! epilogue — see [`crate::engine::fuse`] for the legality rules and
//! why this is exact where table-entry rewriting would not be.

use crate::engine::fuse::{elem_transition, ChainState, FusedChain};
use crate::engine::stages::Stage;

/// What [`fold_elementwise`] did — surfaced by `tablenet compile`'s
/// summary banner and asserted by tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FoldStats {
    /// Banks that absorbed a chain.
    pub chains_fused: usize,
    /// Standalone stages removed from the plan (now running as fused
    /// epilogues).
    pub stages_folded: usize,
}

/// Stage-folding pass: walk the lowered pipeline and move every LUT
/// bank's trailing elementwise chain into the bank as a fused epilogue
/// ([`FusedChain`]), deleting the standalone stages from the plan.
///
/// Legality per element is [`elem_transition`] (exactly the
/// representations the standalone stage would accept); a chain on the
/// final bank is trimmed to the longest prefix still ending on integer
/// accumulators, because inference argmaxes integers. Anything not
/// fusible — `maxpool`, a chain a bank refuses, an illegal transition —
/// stays standalone, bit-identical to the unfused plan.
pub fn fold_elementwise(stages: Vec<Box<dyn Stage>>) -> (Vec<Box<dyn Stage>>, FoldStats) {
    let mut out: Vec<Box<dyn Stage>> = Vec::with_capacity(stages.len());
    let mut stats = FoldStats::default();
    let mut it = stages.into_iter().peekable();
    while let Some(mut stage) = it.next() {
        if !stage.kind().is_bank() {
            out.push(stage);
            continue;
        }
        // collect the longest legal elementwise chain after the bank
        let mut chain: Vec<Box<dyn Stage>> = Vec::new();
        let mut state = ChainState::Acc;
        while let Some(next) = it.peek() {
            match elem_transition(state, next.kind()) {
                Some(ns) => {
                    state = ns;
                    chain.push(it.next().expect("peeked"));
                }
                None => break,
            }
        }
        // terminal bank: keep only the longest prefix that still ends
        // on accumulators; the rest stays standalone (and will fail
        // pipeline validation exactly like the unfused plan would)
        let mut spill: Vec<Box<dyn Stage>> = Vec::new();
        if it.peek().is_none() {
            let mut st = ChainState::Acc;
            let states: Vec<ChainState> = chain
                .iter()
                .map(|s| {
                    st = elem_transition(st, s.kind()).expect("validated above");
                    st
                })
                .collect();
            let keep = states
                .iter()
                .rposition(|&s| s == ChainState::Acc)
                .map_or(0, |i| i + 1);
            spill = chain.split_off(keep);
        }
        if !chain.is_empty() {
            let n = chain.len();
            match FusedChain::from_stages(chain) {
                Ok(fc) => match stage.absorb_chain(fc) {
                    Ok(()) => {
                        stats.chains_fused += 1;
                        stats.stages_folded += n;
                    }
                    Err(fc) => {
                        let mut back = fc.into_stages();
                        back.append(&mut spill);
                        spill = back;
                    }
                },
                Err(orig) => {
                    let mut back = orig;
                    back.append(&mut spill);
                    spill = back;
                }
            }
        }
        out.push(stage);
        out.append(&mut spill);
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::stages::{
        MaxPool2IntStage, ReluIntStage, StageKind, ToFixedStage, ToHalfStage,
    };
    use crate::lut::dense::DenseWholeLut;
    use crate::lut::Partition;
    use crate::quant::FixedFormat;
    use crate::util::Rng;

    fn bank(seed: u64) -> Box<dyn Stage> {
        let (p, q) = (3, 4);
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let lut = DenseWholeLut::build(
            &w,
            &b,
            p,
            q,
            Partition::contiguous(q, 2),
            FixedFormat::new(3),
        )
        .unwrap();
        Box::new(crate::engine::stages::DenseWholeStage::new(lut))
    }

    fn kinds(stages: &[Box<dyn Stage>]) -> Vec<StageKind> {
        stages.iter().map(|s| s.kind()).collect()
    }

    #[test]
    fn folds_interior_chain_into_bank() {
        let stages: Vec<Box<dyn Stage>> = vec![
            bank(1),
            Box::new(ReluIntStage),
            Box::new(ToFixedStage { bits: 3, range_exp: 0 }),
            bank(2),
        ];
        let (out, stats) = fold_elementwise(stages);
        assert_eq!(kinds(&out), vec![StageKind::DenseWhole, StageKind::DenseWhole]);
        assert_eq!(stats, FoldStats { chains_fused: 1, stages_folded: 2 });
        let chain = out[0].fused_chain().expect("bank 0 fused");
        assert_eq!(chain.kinds(), vec![StageKind::ReluInt, StageKind::ToFixed]);
        assert!(out[1].fused_chain().is_none());
    }

    #[test]
    fn terminal_chain_trims_to_acc() {
        // trailing relu keeps accumulators -> fused; trailing tohalf
        // would break the argmax contract -> stays standalone
        let (out, stats) =
            fold_elementwise(vec![bank(3), Box::new(ReluIntStage)]);
        assert_eq!(kinds(&out), vec![StageKind::DenseWhole]);
        assert_eq!(stats.stages_folded, 1);
        assert!(out[0].fused_chain().unwrap().ends_in_acc());

        let (out, stats) = fold_elementwise(vec![
            bank(4),
            Box::new(ReluIntStage),
            Box::new(ToHalfStage),
        ]);
        // relu prefix ends in Acc -> fused; tohalf spills back
        assert_eq!(kinds(&out), vec![StageKind::DenseWhole, StageKind::ToHalf]);
        assert_eq!(stats, FoldStats { chains_fused: 1, stages_folded: 1 });
    }

    #[test]
    fn maxpool_stops_the_chain() {
        let (out, stats) = fold_elementwise(vec![
            bank(5),
            Box::new(ReluIntStage),
            Box::new(MaxPool2IntStage { h: 4, w: 4, c: 1 }),
            bank(6),
        ]);
        assert_eq!(
            kinds(&out),
            vec![StageKind::DenseWhole, StageKind::MaxPool2Int, StageKind::DenseWhole]
        );
        // the relu before the pool is still fusible (Acc -> Acc)
        assert_eq!(stats, FoldStats { chains_fused: 1, stages_folded: 1 });
        assert_eq!(out[0].fused_chain().unwrap().kinds(), vec![StageKind::ReluInt]);
    }

    #[test]
    fn bankless_pipeline_is_untouched() {
        let (out, stats) = fold_elementwise(vec![
            Box::new(ReluIntStage) as Box<dyn Stage>,
            Box::new(ToHalfStage),
        ]);
        assert_eq!(kinds(&out), vec![StageKind::ReluInt, StageKind::ToHalf]);
        assert_eq!(stats, FoldStats::default());
    }
}
