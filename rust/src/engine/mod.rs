//! The multiplier-less inference engine: a [`Compiler`] lowers a
//! reference [`Model`](crate::nn::Model) plus an [`EnginePlan`] into a
//! pipeline of [`Stage`] trait objects (LUT banks and integer stages),
//! and [`LutModel`] executes inferences using only table reads, shifts,
//! adds and compares. [`counters::Counters::mults`] stays zero across
//! every stage — asserted in debug builds and by the test suite.
//!
//! The stage pipeline is **open**: stage kinds live in
//! [`stages`] as independent modules behind the [`Stage`] trait, so a
//! new bank kind is an additive change (new module + compiler emission
//! + artifact tag), not an engine edit. A compiled model serializes to
//! a versioned `.ltm` artifact ([`LutModel::save`] /
//! [`LutModel::load`]) that deploys without weights or recompilation.
//!
//! There is exactly one evaluation path: [`LutModel::infer`] is
//! batch-of-one through the same batched stages, so per-sample and
//! batched results are bit-exact by construction, and op counters are
//! attributed exactly per sample (`BatchInference::per_sample`).
//!
//! Compilation is optimize-then-emit: after the 1:1 lowering, the
//! passes in [`optimize`] rewrite the stage list — today, stage
//! folding fuses each bank's trailing elementwise chain into the bank
//! as an epilogue ([`fuse`]), so the compiled plan usually has fewer
//! stages than the authored plan (see `docs/ARCHITECTURE.md`).
//!
//! ```
//! use tablenet::engine::{plan::EnginePlan, Compiler};
//! use tablenet::nn::Model;
//! use tablenet::tensor::Tensor;
//! use tablenet::util::Rng;
//!
//! let mut rng = Rng::new(11);
//! let model = Model::mlp(vec![
//!     (Tensor::randn(&[12, 16], 0.3, &mut rng), Tensor::zeros(&[12])),
//!     (Tensor::randn(&[8, 12], 0.3, &mut rng), Tensor::zeros(&[8])),
//!     (Tensor::randn(&[4, 8], 0.3, &mut rng), Tensor::zeros(&[4])),
//! ]);
//! let lut = Compiler::new(&model)
//!     .plan(&EnginePlan::mlp_default())
//!     .build()
//!     .unwrap();
//! // relu/encode chains folded into the banks: 3 stages, not 7
//! assert_eq!(lut.num_stages(), 3);
//! let out = lut.infer(&vec![0.5; 16]);
//! assert!(out.class < 4);
//! out.counters.assert_multiplier_less();   // zero multiplies, proven
//! ```

pub mod act;
pub mod artifact;
pub mod compiler;
pub mod counters;
pub mod f16enc;
pub mod fuse;
pub mod optimize;
pub mod plan;
pub mod scratch;
pub mod stages;

pub use act::{ActBuf, Repr};
pub use compiler::Compiler;
pub use stages::{Stage, StageKind};

use counters::Counters;
use plan::EnginePlan;
use scratch::Scratch;
use std::path::Path;

/// A compiled multiplier-less model: an executable stage pipeline plus
/// the plan it was compiled from. Construct with [`Compiler`] (from
/// weights) or [`LutModel::load`] (from a `.ltm` artifact).
pub struct LutModel {
    stages: Vec<Box<dyn Stage>>,
    plan: EnginePlan,
}

/// Table-storage rollup of a compiled model (see
/// [`LutModel::storage_summary`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageSummary {
    /// Stages that own table storage.
    pub banks: usize,
    /// Of those, stages whose arena is borrowed zero-copy from a
    /// mapped artifact.
    pub borrowed: usize,
    /// Total table bytes across all banks (mapped or heap-resident).
    pub bytes: usize,
}

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct Inference {
    /// Logits decoded to f32 (display/serving only — argmax happens on
    /// the integer accumulators).
    pub logits: Vec<f32>,
    /// Predicted class.
    pub class: usize,
    /// Op mix for this inference.
    pub counters: Counters,
}

/// Result of one batched inference. Output vectors are reused across
/// calls by [`LutModel::infer_batch_into`] — steady-state serving
/// allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct BatchInference {
    /// Predicted class per sample.
    pub classes: Vec<usize>,
    /// Logits, row-major `batch x classes` (decoded for display only).
    pub logits: Vec<f32>,
    /// Op mix aggregated over the whole batch (equals the sum of
    /// [`BatchInference::per_sample`]).
    pub counters: Counters,
    /// Exact per-sample op attribution — every primitive lands on the
    /// row of the sample that incurred it, so `per_sample[s]` equals
    /// the counters of a standalone [`LutModel::infer`] on sample `s`
    /// (asserted by the property tests).
    pub per_sample: Vec<Counters>,
}

impl BatchInference {
    /// Logits of sample `s`.
    pub fn logits_row(&self, s: usize) -> &[f32] {
        let n = self.logits.len() / self.classes.len().max(1);
        &self.logits[s * n..(s + 1) * n]
    }
}

impl LutModel {
    /// Assemble from parts (used by [`Compiler::build`] and the
    /// artifact loader).
    pub(crate) fn from_parts(stages: Vec<Box<dyn Stage>>, plan: EnginePlan) -> LutModel {
        LutModel { stages, plan }
    }

    /// The stage pipeline, in execution order.
    pub fn stages(&self) -> &[Box<dyn Stage>] {
        &self.stages
    }

    /// Number of pipeline stages.
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total LUT storage in bits at the plan's accounting width.
    pub fn size_bits(&self) -> u64 {
        self.stages.iter().map(|s| s.size_bits(self.plan.r_o)).sum()
    }

    /// The plan this model was compiled from.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// Serialize the compiled pipeline to a `.ltm` artifact file.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        artifact::save(self, path)
    }

    /// Load a compiled pipeline from a `.ltm` artifact file — no
    /// weights, no recompilation; bit-exact with the saved model.
    pub fn load(path: &Path) -> anyhow::Result<LutModel> {
        artifact::load(path)
    }

    /// Run one inference on a raw f32 input (flattened, values in
    /// [0,1]). This is batch-of-one through the batched stage pipeline;
    /// convenience only — hot paths should hold a [`Scratch`] and call
    /// [`LutModel::infer_batch_into`].
    pub fn infer(&self, input: &[f32]) -> Inference {
        let mut scratch = Scratch::new();
        let mut out = BatchInference::default();
        self.infer_batch_into(input, 1, &mut scratch, &mut out);
        Inference {
            logits: std::mem::take(&mut out.logits),
            class: out.classes[0],
            counters: out.counters,
        }
    }

    /// Run a batch of inferences over `images` (row-major
    /// `batch x features`, values in [0,1]) reusing `scratch`.
    /// Convenience wrapper over [`LutModel::infer_batch_into`] that
    /// allocates the output struct.
    pub fn infer_batch(
        &self,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
    ) -> BatchInference {
        let mut out = BatchInference::default();
        self.infer_batch_into(images, batch, scratch, &mut out);
        out
    }

    /// Number of input features (flattened elements per sample) the
    /// pipeline consumes, read off the first stage whose geometry pins
    /// one (a LUT bank). `None` only for pipelines made entirely of
    /// width-agnostic stages, which the artifact loader rejects.
    pub fn input_features(&self) -> Option<usize> {
        self.stages.iter().find_map(|s| s.in_elems())
    }

    /// Rollup of every arena-backed stage's storage residency: how
    /// many such banks there are, how many borrow their arena
    /// zero-copy from a mapped artifact, and total arena bytes.
    /// `borrowed == banks` (with `banks > 0`) means every table arena
    /// is served in place out of the `.ltm` mapping — the v2 fast path
    /// the serve banner and `tablenet inspect` report. (The scalar
    /// sigmoid LUT is heap-only by design and not counted here; its
    /// size shows through [`LutModel::size_bits`].)
    pub fn storage_summary(&self) -> StorageSummary {
        let mut s = StorageSummary::default();
        for r in self.stages.iter().filter_map(|st| st.storage()) {
            s.banks += 1;
            s.bytes += r.bytes;
            if r.borrowed {
                s.borrowed += 1;
            }
        }
        s
    }

    /// Batched inference into a reusable output struct. This is the
    /// serving hot path: stages execute *batch-at-a-time* over the
    /// contiguous table arenas (chunk-outer, sample-inner inside each
    /// bank), all intermediates live in `scratch`, and counters land on
    /// exact per-sample rows. After one warm-up call with the same
    /// batch geometry, the whole path performs zero heap allocations.
    pub fn infer_batch_into(
        &self,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut BatchInference,
    ) {
        assert!(batch > 0, "batch must be >= 1");
        assert_eq!(images.len() % batch, 0, "images not divisible into batch rows");
        scratch.act.load_f32(images, batch);
        self.run_loaded(batch, scratch, out);
    }

    /// Rows-direct batched inference: per-request rows (the
    /// coordinator's `Vec<f32>` payloads) land in the activation buffer
    /// with exactly one copy — no intermediate flattened staging. Same
    /// hot-path guarantees as [`LutModel::infer_batch_into`].
    pub fn infer_batch_rows_into(
        &self,
        rows: &[Vec<f32>],
        scratch: &mut Scratch,
        out: &mut BatchInference,
    ) {
        scratch.act.load_rows(rows);
        self.run_loaded(rows.len(), scratch, out);
    }

    /// Run the stage pipeline over the batch already staged in
    /// `scratch.act` (the shared tail of both batched entry points).
    fn run_loaded(&self, batch: usize, scratch: &mut Scratch, out: &mut BatchInference) {
        // split the activation and counter rows out of the scratch so
        // stages can borrow the remaining buffers (pad, acc2) mutably
        let mut act = std::mem::take(&mut scratch.act);
        let mut ctrs = std::mem::take(&mut scratch.sample_counters);
        ctrs.clear();
        ctrs.resize(batch, Counters::default());
        for stage in &self.stages {
            stage.eval_batch(&mut act, scratch, &mut ctrs);
        }
        let frac = match act.repr() {
            Repr::Acc(frac) => frac,
            other => panic!("model must end with an affine stage, got {other:?}"),
        };
        let nclass = act.acc.len() / batch;
        out.classes.clear();
        out.logits.clear();
        let scale = (-(frac as f64)).exp2();
        for s in 0..batch {
            let row = &act.acc[s * nclass..(s + 1) * nclass];
            // argmax over integers; decode for display
            let mut best = 0usize;
            for i in 1..row.len() {
                ctrs[s].compares += 1;
                if row[i] > row[best] {
                    best = i;
                }
            }
            out.classes.push(best);
            out.logits.extend(row.iter().map(|&a| (a as f64 * scale) as f32));
        }
        let mut total = Counters::default();
        for c in &ctrs {
            total += *c;
        }
        debug_assert_eq!(total.mults, 0);
        out.counters = total;
        out.per_sample.clear();
        out.per_sample.extend_from_slice(&ctrs);
        scratch.act = act;
        scratch.sample_counters = ctrs;
    }

    /// Accuracy over a flat dataset (`images` row-major, one row per
    /// sample), executed on the batched path over an internal
    /// [`Scratch`]. Also returns the op counters of the *first*
    /// inference (exact — per-sample attribution), which are identical
    /// per sample for a fixed plan/architecture modulo zero-row skips.
    pub fn accuracy(&self, images: &[f32], row: usize, labels: &[usize]) -> (f64, Counters) {
        let mut scratch = Scratch::new();
        self.accuracy_scratch(images, row, labels, &mut scratch)
    }

    /// [`LutModel::accuracy`] over a caller-owned [`Scratch`] — the
    /// harness sweeps thread one scratch through every plan they
    /// measure, so the fig benches run allocation-free on the batched
    /// path.
    pub fn accuracy_scratch(
        &self,
        images: &[f32],
        row: usize,
        labels: &[usize],
        scratch: &mut Scratch,
    ) -> (f64, Counters) {
        assert_eq!(images.len(), row * labels.len());
        const EVAL_BATCH: usize = 32;
        let mut out = BatchInference::default();
        let mut correct = 0usize;
        let mut first = Counters::default();
        let mut i = 0usize;
        while i < labels.len() {
            let b = EVAL_BATCH.min(labels.len() - i);
            self.infer_batch_into(&images[i * row..(i + b) * row], b, scratch, &mut out);
            if i == 0 {
                first = out.per_sample[0];
            }
            for (s, &label) in labels[i..i + b].iter().enumerate() {
                if out.classes[s] == label {
                    correct += 1;
                }
            }
            i += b;
        }
        (correct as f64 / labels.len() as f64, first)
    }
}

#[cfg(test)]
mod tests {
    use super::plan::AffineMode;
    use super::*;
    use crate::nn::Model;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn linear_model(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        )
    }

    fn mlp_model(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        Model::mlp(vec![
            (Tensor::randn(&[32, 784], 0.05, &mut rng), Tensor::zeros(&[32])),
            (Tensor::randn(&[16, 32], 0.2, &mut rng), Tensor::zeros(&[16])),
            (Tensor::randn(&[10, 16], 0.3, &mut rng), Tensor::zeros(&[10])),
        ])
    }

    fn compile(model: &Model, plan: &EnginePlan) -> LutModel {
        Compiler::new(model).plan(plan).build().unwrap()
    }

    #[test]
    fn linear_lut_agrees_with_reference() {
        let model = linear_model(5);
        let plan = EnginePlan::linear_default();
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(6);
        let mut agree = 0;
        for _ in 0..20 {
            let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
            // reference on quantized input
            let fmt = crate::quant::FixedFormat::new(3);
            let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
            let ref_out = model.forward(&Tensor::new(&[1, 784], xq));
            let inf = lut.infer(&x);
            inf.counters.assert_multiplier_less();
            if ref_out.argmax_rows()[0] == inf.class {
                agree += 1;
            }
        }
        assert!(agree >= 19, "LUT and reference disagree too often: {agree}/20");
    }

    #[test]
    fn linear_logits_close_to_reference() {
        let model = linear_model(7);
        let plan = EnginePlan::linear_default();
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let fmt = crate::quant::FixedFormat::new(3);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
        let ref_out = model.forward(&Tensor::new(&[1, 784], xq));
        let inf = lut.infer(&x);
        for (g, e) in inf.logits.iter().zip(ref_out.data()) {
            assert!((g - e).abs() < 1e-3, "{g} vs {e}");
        }
    }

    #[test]
    fn engine_size_matches_cost_model() {
        let model = linear_model(9);
        let plan = EnginePlan::linear_default(); // bitplane, 3 bits, m=14
        let lut = compile(&model, &plan);
        let c = crate::lut::cost::dense_cost(
            784,
            10,
            14,
            crate::lut::cost::IndexMode::BitplaneFixed { r_i: 3 },
            16,
        );
        assert_eq!(lut.size_bits(), c.size_bits);
    }

    #[test]
    fn counters_zero_mults_all_archs_small() {
        let model = mlp_model(10);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let inf = lut.infer(&x);
        inf.counters.assert_multiplier_less();
        assert!(inf.counters.lut_evals > 0);
    }

    #[test]
    fn mlp_float_pipeline_tracks_reference() {
        let model = mlp_model(12);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(13);
        let mut agree = 0;
        for _ in 0..10 {
            let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
            let ref_out = model
                .with_quantization(8, true, 8)
                .forward(&Tensor::new(&[1, 784], x.clone()));
            let inf = lut.infer(&x);
            if ref_out.argmax_rows()[0] == inf.class {
                agree += 1;
            }
        }
        assert!(agree >= 9, "MLP pipeline diverges: {agree}/10");
    }

    fn sigmoid_model(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        Model {
            arch: crate::nn::Arch::Mlp,
            layers: vec![
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[24, 784], 0.05, &mut rng),
                    b: Tensor::zeros(&[24]),
                },
                crate::nn::Layer::Sigmoid,
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[10, 24], 0.3, &mut rng),
                    b: Tensor::zeros(&[10]),
                },
            ],
            input_shape: vec![784],
        }
    }

    #[test]
    fn sigmoid_pipeline_tracks_reference() {
        // MLP with sigmoid activations: engine path = float banks + the
        // paper's 128 KiB scalar LUT; must match the float reference
        let model = sigmoid_model(77);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = compile(&model, &plan);
        // size includes the 128 KiB scalar table
        assert!(lut.size_bits() >= (1 << 16) * 16);
        let mut agree = 0;
        for s in 0..10 {
            let mut r2 = Rng::new(100 + s);
            let x: Vec<f32> = (0..784).map(|_| r2.f32()).collect();
            let inf = lut.infer(&x);
            inf.counters.assert_multiplier_less();
            let ref_out = model.forward(&Tensor::new(&[1, 784], x));
            if ref_out.argmax_rows()[0] == inf.class {
                agree += 1;
            }
        }
        assert!(agree >= 9, "sigmoid pipeline diverged: {agree}/10");
    }

    /// infer_batch must agree bit-exactly with per-sample infer: same
    /// classes, same logits, and EXACT per-sample counters — across
    /// every stage kind the compiler can emit.
    fn assert_batch_matches_single(model: &Model, plan: &EnginePlan, seed: u64) {
        let lut = compile(model, plan);
        let features: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(seed);
        let batch = 4;
        let images: Vec<f32> = (0..batch * features).map(|_| rng.f32()).collect();
        let mut scratch = scratch::Scratch::new();
        let got = lut.infer_batch(&images, batch, &mut scratch);
        got.counters.assert_multiplier_less();
        let mut total = Counters::default();
        for s in 0..batch {
            let single = lut.infer(&images[s * features..(s + 1) * features]);
            assert_eq!(got.classes[s], single.class, "class diverges at sample {s}");
            assert_eq!(
                got.logits_row(s),
                single.logits.as_slice(),
                "logits diverge at sample {s}"
            );
            assert_eq!(
                got.per_sample[s], single.counters,
                "per-sample counters diverge at sample {s}"
            );
            total += single.counters;
        }
        assert_eq!(got.counters, total, "batched counter totals diverge");
    }

    #[test]
    fn infer_batch_matches_single_linear_bitplane() {
        let model = linear_model(31);
        assert_batch_matches_single(&model, &EnginePlan::linear_default(), 131);
    }

    #[test]
    fn infer_batch_matches_single_mlp_float() {
        let model = mlp_model(32);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 132);
    }

    #[test]
    fn infer_batch_matches_single_fixed_inner() {
        let model = mlp_model(33);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 133);
    }

    #[test]
    fn infer_batch_matches_single_sigmoid() {
        let model = sigmoid_model(78);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 134);
    }

    #[test]
    fn infer_batch_matches_single_cnn() {
        // exercises the batched conv wiring end-to-end: ConvFixed,
        // ReluInt, MaxPool2Int (acc/acc2 swap), ToHalf, ConvFloat (pad
        // scratch), Flatten, DenseFloat
        let mut rng = Rng::new(79);
        let model = Model {
            arch: crate::nn::Arch::Cnn,
            layers: vec![
                crate::nn::Layer::Conv2d {
                    filter: Tensor::randn(&[3, 3, 1, 2], 0.3, &mut rng),
                    b: Tensor::randn(&[2], 0.05, &mut rng),
                },
                crate::nn::Layer::Relu,
                crate::nn::Layer::MaxPool2,
                crate::nn::Layer::Conv2d {
                    filter: Tensor::randn(&[3, 3, 2, 3], 0.2, &mut rng),
                    b: Tensor::randn(&[3], 0.05, &mut rng),
                },
                crate::nn::Layer::Relu,
                crate::nn::Layer::Flatten,
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[10, 4 * 4 * 3], 0.2, &mut rng),
                    b: Tensor::zeros(&[10]),
                },
            ],
            input_shape: vec![8, 8, 1],
        };
        let plan = EnginePlan {
            affine: vec![
                AffineMode::BitplaneFixed { bits: 3, m: 2, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 135);
    }

    #[test]
    fn infer_batch_rows_matches_flat_entry() {
        // the rows-direct serving entry must be bit-exact with the
        // flat-slice entry: same classes, logits and per-sample counters
        let model = mlp_model(60);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(61);
        let batch = 5;
        let rows: Vec<Vec<f32>> =
            (0..batch).map(|_| (0..784).map(|_| rng.f32()).collect()).collect();
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let mut scratch = scratch::Scratch::new();
        let mut flat_out = BatchInference::default();
        lut.infer_batch_into(&flat, batch, &mut scratch, &mut flat_out);
        let mut rows_out = BatchInference::default();
        lut.infer_batch_rows_into(&rows, &mut scratch, &mut rows_out);
        assert_eq!(rows_out.classes, flat_out.classes);
        assert_eq!(rows_out.logits, flat_out.logits);
        assert_eq!(rows_out.per_sample, flat_out.per_sample);
        assert_eq!(rows_out.counters, flat_out.counters);
    }

    #[test]
    fn input_features_reads_first_bank_geometry() {
        let model = linear_model(62);
        let lut = compile(&model, &EnginePlan::linear_default());
        assert_eq!(lut.input_features(), Some(784));
        let mlp = mlp_model(63);
        let lut = compile(&mlp, &EnginePlan::mlp_fixed_input());
        assert_eq!(lut.input_features(), Some(784));
    }

    #[test]
    fn scratch_buffers_stabilize_after_warmup() {
        // after one warm-up batch, further batches of the same geometry
        // must not grow any scratch buffer (the zero-allocation
        // precondition; the allocator-level assert lives in
        // rust/tests/alloc_discipline.rs)
        let model = linear_model(36);
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(37);
        let batch = 8;
        let images: Vec<f32> = (0..batch * 784).map(|_| rng.f32()).collect();
        let mut scratch = scratch::Scratch::new();
        let mut out = BatchInference::default();
        lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
        let bytes = scratch.resident_bytes();
        let (cap_c, cap_l) = (out.classes.capacity(), out.logits.capacity());
        for _ in 0..5 {
            lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
        }
        assert_eq!(scratch.resident_bytes(), bytes, "scratch grew after warm-up");
        assert_eq!(out.classes.capacity(), cap_c);
        assert_eq!(out.logits.capacity(), cap_l);
    }

    #[test]
    fn fixed_inner_pipeline_runs() {
        // ablation path: fixed-point inner layers with power-of-2 range
        let model = mlp_model(14);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = compile(&model, &plan);
        let mut rng = Rng::new(15);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let inf = lut.infer(&x);
        inf.counters.assert_multiplier_less();
        assert_eq!(inf.logits.len(), 10);
    }

    #[test]
    fn accuracy_scratch_matches_per_sample_path() {
        // batched accuracy (the harness path) must agree with a manual
        // per-sample loop, and the returned counters must be the first
        // sample's exact counters
        let model = linear_model(40);
        let lut = compile(&model, &EnginePlan::linear_default());
        let mut rng = Rng::new(41);
        let n = 70; // not a multiple of the internal eval batch
        let images: Vec<f32> = (0..n * 784).map(|_| rng.f32()).collect();
        let labels: Vec<usize> = (0..n).map(|_| rng.below(10)).collect();
        let mut scratch = scratch::Scratch::new();
        let (acc, first) = lut.accuracy_scratch(&images, 784, &labels, &mut scratch);
        let mut correct = 0usize;
        for (i, &label) in labels.iter().enumerate() {
            let inf = lut.infer(&images[i * 784..(i + 1) * 784]);
            if i == 0 {
                assert_eq!(first, inf.counters, "first-sample counters diverge");
            }
            if inf.class == label {
                correct += 1;
            }
        }
        assert_eq!(acc, correct as f64 / n as f64);
    }

    #[test]
    fn artifact_roundtrip_smoke() {
        // full save -> load -> bit-exact infer loop (the exhaustive
        // version lives in rust/tests/artifact_roundtrip.rs)
        let model = mlp_model(50);
        let lut = compile(&model, &EnginePlan::mlp_fixed_input());
        let bytes = artifact::to_bytes(&lut);
        let back = artifact::from_bytes(&bytes).unwrap();
        assert_eq!(back.plan(), lut.plan());
        assert_eq!(back.size_bits(), lut.size_bits());
        assert_eq!(back.num_stages(), lut.num_stages());
        let mut rng = Rng::new(51);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let a = lut.infer(&x);
        let b = back.infer(&x);
        assert_eq!(a.class, b.class);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.counters, b.counters);
    }
}
