//! The multiplier-less inference engine: compiles a reference
//! [`Model`](crate::nn::Model) plus an [`EnginePlan`] into a pipeline of
//! LUT banks and integer stages, then executes inferences using only
//! table reads, shifts, adds and compares. [`counters::Counters::mults`]
//! stays zero across every stage — asserted in debug builds and by the
//! test suite.

pub mod counters;
pub mod f16enc;
pub mod plan;
pub mod scratch;

use crate::lut::bitplane::DenseBitplaneLut;
use crate::lut::conv::ConvLut;
use crate::lut::convfloat::ConvFloatLut;
use crate::lut::dense::DenseWholeLut;
use crate::lut::floatplane::{DenseFloatLut, FloatLutConfig, FACC};
use crate::lut::{LutError, Partition, ACC_FRAC};
use crate::nn::{Layer, Model};
use crate::quant::f16::F16;
use crate::quant::FixedFormat;
use counters::Counters;
use plan::{AffineMode, EnginePlan};
use scratch::{reset_len_i64, Scratch};

/// One executable stage of the compiled pipeline.
enum Stage {
    DenseWhole(DenseWholeLut),
    DenseBitplane(DenseBitplaneLut),
    DenseFloat(DenseFloatLut),
    ConvFixed(ConvLut),
    ConvFloat(ConvFloatLut),
    /// ReLU on integer accumulators (compare + select).
    ReluInt,
    /// Sigmoid via the paper's 128 KiB f16->f16 scalar LUT (one memory
    /// read per element, zero arithmetic).
    SigmoidLut(crate::lut::scalar::ScalarLut),
    /// 2x2 max pool on an integer accumulator image.
    MaxPool2Int { h: usize, w: usize, c: usize },
    /// Convert accumulators to binary16 codes (priority-encode + shift).
    ToHalf,
    /// Convert accumulators to fixed codes via right-shift + clamp.
    ToFixed { bits: u32, range_exp: i32 },
}

/// Runtime activation value.
enum Act {
    F32(Vec<f32>),
    Acc { v: Vec<i64>, frac: u32 },
    Half(Vec<F16>),
    Codes { v: Vec<u32>, bits: u32 },
}

/// A compiled multiplier-less model.
pub struct LutModel {
    stages: Vec<Stage>,
    plan: EnginePlan,
    /// Total LUT bits at the plan's accounting width r_o.
    size_bits: u64,
}

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct Inference {
    /// Logits decoded to f32 (display/serving only — argmax happens on
    /// the integer accumulators).
    pub logits: Vec<f32>,
    /// Predicted class.
    pub class: usize,
    /// Op mix for this inference.
    pub counters: Counters,
}

/// Result of one batched inference. Output vectors are reused across
/// calls by [`LutModel::infer_batch_into`] — steady-state serving
/// allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct BatchInference {
    /// Predicted class per sample.
    pub classes: Vec<usize>,
    /// Logits, row-major `batch x classes` (decoded for display only).
    pub logits: Vec<f32>,
    /// Op mix aggregated over the whole batch (totals equal the sum of
    /// the per-sample counters of [`LutModel::infer`] — asserted by the
    /// property tests).
    pub counters: Counters,
}

impl BatchInference {
    /// Logits of sample `s`.
    pub fn logits_row(&self, s: usize) -> &[f32] {
        let n = self.logits.len() / self.classes.len().max(1);
        &self.logits[s * n..(s + 1) * n]
    }
}

/// Tag of the activation representation flowing between batched stages.
/// The data itself lives in the [`Scratch`] buffers (`acc`, `half`,
/// `codes`) or, for `F32`, in the caller's input slice.
#[derive(Debug, Clone, Copy)]
enum Repr {
    F32,
    Acc(u32),
    Half,
    Codes(u32),
}

impl LutModel {
    /// Compile `model` under `plan`. Fails if a requested table exceeds
    /// the materialisation cap (those configs are planner-only).
    pub fn compile(model: &Model, plan: &EnginePlan) -> Result<LutModel, LutError> {
        let mut stages = Vec::new();
        let mut size_bits = 0u64;
        let mut affine_idx = 0usize;
        // spatial dims tracked through conv stages
        let mut dims: Option<(usize, usize, usize)> = match model.input_shape.as_slice() {
            [h, w, c] => Some((*h, *w, *c)),
            _ => None,
        };
        // scale of values flowing *into* the next affine stage relative
        // to the raw f32 model (used for fixed inner layers)
        let mut pending_fixed: Option<(u32, i32)> = None;

        for layer in &model.layers {
            match layer {
                Layer::QuantFixed { .. } | Layer::QuantF16 => {
                    // the engine performs its own quantization at stage
                    // boundaries; fake-quant markers are training-time
                }
                Layer::Relu => stages.push(Stage::ReluInt),
                Layer::Sigmoid => {
                    // one table read per element; the stage performs its
                    // own SIGNED acc->f16 encode (pre-activations can be
                    // negative; sigmoid output is nonneg, so downstream
                    // float banks keep their sign-free assumption)
                    let lut = crate::lut::scalar::ScalarLut::sigmoid();
                    size_bits += lut.size_bits();
                    stages.push(Stage::SigmoidLut(lut));
                }
                Layer::MaxPool2 => {
                    let (h, w, c) = dims.expect("maxpool needs spatial dims");
                    stages.push(Stage::MaxPool2Int { h, w, c });
                    dims = Some((h / 2, w / 2, c));
                }
                Layer::Flatten => {
                    dims = None; // flat from here on
                }
                Layer::Dense { w, b } => {
                    let mode = plan.affine.get(affine_idx).unwrap_or(&plan.fallback);
                    affine_idx += 1;
                    let p = w.shape()[0];
                    let q = w.shape()[1];
                    // weight scaling for fixed inner layers
                    let (wdata, conv_needed): (Vec<f32>, Option<Stage>) = match mode {
                        AffineMode::WholeFixed { bits, m: _, range_exp }
                        | AffineMode::BitplaneFixed { bits, m: _, range_exp } => {
                            if affine_idx == 1 {
                                (w.data().to_vec(), None)
                            } else {
                                let s = (*range_exp as f32).exp2();
                                (
                                    w.data().iter().map(|&x| x * s).collect(),
                                    Some(Stage::ToFixed { bits: *bits, range_exp: *range_exp }),
                                )
                            }
                        }
                        AffineMode::Float { .. } => {
                            if affine_idx == 1 {
                                (w.data().to_vec(), None)
                            } else {
                                (w.data().to_vec(), Some(Stage::ToHalf))
                            }
                        }
                    };
                    if let Some(cstage) = conv_needed {
                        stages.push(cstage);
                    }
                    let bank = match mode {
                        AffineMode::WholeFixed { bits, m, .. } => {
                            let lut = DenseWholeLut::build(
                                &wdata,
                                b.data(),
                                p,
                                q,
                                Partition::contiguous(q, *m),
                                FixedFormat::new(*bits),
                            )?;
                            size_bits += lut.size_bits(plan.r_o);
                            Stage::DenseWhole(lut)
                        }
                        AffineMode::BitplaneFixed { bits, m, .. } => {
                            let lut = DenseBitplaneLut::build(
                                &wdata,
                                b.data(),
                                p,
                                q,
                                Partition::contiguous(q, *m),
                                FixedFormat::new(*bits),
                            )?;
                            size_bits += lut.size_bits(plan.r_o);
                            Stage::DenseBitplane(lut)
                        }
                        AffineMode::Float { planes, m } => {
                            let lut = DenseFloatLut::build(
                                &wdata,
                                b.data(),
                                p,
                                q,
                                Partition::contiguous(q, *m),
                                FloatLutConfig { planes: *planes },
                            )?;
                            size_bits += lut.size_bits(plan.r_o);
                            Stage::DenseFloat(lut)
                        }
                    };
                    let _ = pending_fixed.take();
                    stages.push(bank);
                }
                Layer::Conv2d { filter, b } => {
                    let mode = plan.affine.get(affine_idx).unwrap_or(&plan.fallback);
                    affine_idx += 1;
                    let (h, w2, cin) = dims.expect("conv needs spatial dims");
                    let fs = filter.shape()[0];
                    let r = fs / 2;
                    let cout = filter.shape()[3];
                    match mode {
                        AffineMode::BitplaneFixed { bits, m, range_exp }
                        | AffineMode::WholeFixed { bits, m, range_exp } => {
                            let fdata: Vec<f32> = if affine_idx == 1 {
                                filter.data().to_vec()
                            } else {
                                stages.push(Stage::ToFixed {
                                    bits: *bits,
                                    range_exp: *range_exp,
                                });
                                let s = (*range_exp as f32).exp2();
                                filter.data().iter().map(|&x| x * s).collect()
                            };
                            let lut = ConvLut::build(
                                &fdata,
                                b.data(),
                                h,
                                w2,
                                cin,
                                cout,
                                r,
                                *m,
                                FixedFormat::new(*bits),
                            )?;
                            size_bits += lut.size_bits(plan.r_o);
                            stages.push(Stage::ConvFixed(lut));
                        }
                        AffineMode::Float { planes, .. } => {
                            if affine_idx > 1 {
                                stages.push(Stage::ToHalf);
                            }
                            let lut = ConvFloatLut::build(
                                filter.data(),
                                b.data(),
                                h,
                                w2,
                                cin,
                                cout,
                                r,
                                *planes,
                            )?;
                            size_bits += lut.size_bits(plan.r_o);
                            stages.push(Stage::ConvFloat(lut));
                        }
                    }
                    dims = Some((h, w2, cout));
                }
            }
        }
        Ok(LutModel { stages, plan: plan.clone(), size_bits })
    }

    /// Total LUT storage in bits at the plan's accounting width.
    pub fn size_bits(&self) -> u64 {
        self.size_bits
    }

    /// The plan this model was compiled from.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// Run one inference on a raw f32 input (flattened, values in [0,1]).
    pub fn infer(&self, input: &[f32]) -> Inference {
        let mut ctr = Counters::default();
        let mut act = Act::F32(input.to_vec());
        for stage in &self.stages {
            act = self.run_stage(stage, act, &mut ctr);
        }
        debug_assert_eq!(ctr.mults, 0);
        let (logits, class) = match act {
            Act::Acc { v, frac } => {
                // argmax over integers; decode for display
                let mut best = 0usize;
                for i in 1..v.len() {
                    ctr.compares += 1;
                    if v[i] > v[best] {
                        best = i;
                    }
                }
                let scale = (-(frac as f64)).exp2();
                (v.iter().map(|&a| (a as f64 * scale) as f32).collect(), best)
            }
            _ => panic!("model must end with an affine stage"),
        };
        Inference { logits, class, counters: ctr }
    }

    /// Run a batch of inferences over `images` (row-major
    /// `batch x features`, values in [0,1]) reusing `scratch`. Convenience
    /// wrapper over [`LutModel::infer_batch_into`] that allocates the
    /// output struct.
    pub fn infer_batch(
        &self,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
    ) -> BatchInference {
        let mut out = BatchInference::default();
        self.infer_batch_into(images, batch, scratch, &mut out);
        out
    }

    /// Batched inference into a reusable output struct. This is the
    /// serving hot path: stages execute *batch-at-a-time* over the
    /// contiguous table arenas (chunk-outer, sample-inner inside each
    /// bank), all intermediates live in `scratch`, and counters
    /// accumulate per batch. After one warm-up call with the same batch
    /// geometry, the whole path performs zero heap allocations.
    ///
    /// Results are bit-exact with per-sample [`LutModel::infer`]: same
    /// classes, same logits, and counter totals equal to the sum of the
    /// per-sample counters.
    pub fn infer_batch_into(
        &self,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
        out: &mut BatchInference,
    ) {
        assert!(batch > 0, "batch must be >= 1");
        assert_eq!(images.len() % batch, 0, "images not divisible into batch rows");
        let mut ctr = Counters::default();
        let mut repr = Repr::F32;
        for stage in &self.stages {
            repr = self.run_stage_batch(stage, repr, images, batch, scratch, &mut ctr);
        }
        let frac = match repr {
            Repr::Acc(frac) => frac,
            _ => panic!("model must end with an affine stage"),
        };
        let nclass = scratch.acc.len() / batch;
        out.classes.clear();
        out.logits.clear();
        let scale = (-(frac as f64)).exp2();
        for s in 0..batch {
            let row = &scratch.acc[s * nclass..(s + 1) * nclass];
            // argmax over integers; decode for display
            let mut best = 0usize;
            for i in 1..row.len() {
                ctr.compares += 1;
                if row[i] > row[best] {
                    best = i;
                }
            }
            out.classes.push(best);
            out.logits.extend(row.iter().map(|&a| (a as f64 * scale) as f32));
        }
        debug_assert_eq!(ctr.mults, 0);
        out.counters = ctr;
    }

    /// One batched stage. The activation tag moves between the scratch
    /// buffers; `images` is only read while the tag is still `F32`
    /// (i.e. before the first quantizing stage).
    fn run_stage_batch(
        &self,
        stage: &Stage,
        repr: Repr,
        images: &[f32],
        batch: usize,
        scratch: &mut Scratch,
        ctr: &mut Counters,
    ) -> Repr {
        let Scratch { codes, half, acc, acc2, pad, .. } = scratch;
        match stage {
            Stage::DenseWhole(lut) => {
                match repr {
                    Repr::F32 => {
                        assert_eq!(images.len(), batch * lut.partition.q);
                        codes.clear();
                        codes.extend(images.iter().map(|&v| lut.fmt.quantize(v)));
                    }
                    Repr::Codes(bits) => debug_assert_eq!(bits, lut.fmt.bits),
                    _ => panic!("whole-fixed dense expects f32 or codes"),
                }
                reset_len_i64(acc, batch * lut.p);
                lut.eval_batch(codes, batch, acc, ctr);
                Repr::Acc(ACC_FRAC)
            }
            Stage::DenseBitplane(lut) => {
                match repr {
                    Repr::F32 => {
                        assert_eq!(images.len(), batch * lut.partition.q);
                        codes.clear();
                        codes.extend(images.iter().map(|&v| lut.fmt.quantize(v)));
                    }
                    Repr::Codes(bits) => debug_assert_eq!(bits, lut.fmt.bits),
                    _ => panic!("bitplane dense expects f32 or codes"),
                }
                reset_len_i64(acc, batch * lut.p);
                lut.eval_batch(codes, batch, acc, ctr);
                Repr::Acc(ACC_FRAC)
            }
            Stage::DenseFloat(lut) => {
                match repr {
                    Repr::F32 => {
                        assert_eq!(images.len(), batch * lut.partition.q);
                        half.clear();
                        half.extend(images.iter().map(|&v| F16::from_f32(v.max(0.0))));
                    }
                    Repr::Half => {}
                    _ => panic!("float dense expects f32 or half"),
                }
                reset_len_i64(acc, batch * lut.p);
                lut.eval_batch_f16(half, batch, acc, ctr);
                Repr::Acc(FACC as u32)
            }
            Stage::ConvFixed(lut) => {
                match repr {
                    Repr::F32 => {
                        assert_eq!(images.len(), batch * lut.h * lut.w * lut.cin);
                        codes.clear();
                        codes.extend(images.iter().map(|&v| lut.fmt.quantize(v)));
                    }
                    Repr::Codes(bits) => debug_assert_eq!(bits, lut.fmt.bits),
                    _ => panic!("fixed conv expects f32 or codes"),
                }
                reset_len_i64(acc, batch * lut.h * lut.w * lut.cout);
                lut.eval_batch(codes, batch, acc, pad, ctr);
                Repr::Acc(ACC_FRAC)
            }
            Stage::ConvFloat(lut) => {
                match repr {
                    Repr::F32 => {
                        assert_eq!(images.len(), batch * lut.h * lut.w * lut.cin);
                        half.clear();
                        half.extend(images.iter().map(|&v| F16::from_f32(v.max(0.0))));
                    }
                    Repr::Half => {}
                    _ => panic!("float conv expects f32 or half"),
                }
                reset_len_i64(acc, batch * lut.h * lut.w * lut.cout);
                lut.eval_batch_f16(half, batch, acc, pad, ctr);
                Repr::Acc(FACC as u32)
            }
            Stage::SigmoidLut(lut) => {
                match repr {
                    Repr::Half => {}
                    Repr::Acc(frac) => {
                        f16enc::acc_slice_to_f16_signed_into(acc, frac, half, ctr);
                    }
                    Repr::F32 => {
                        half.clear();
                        half.extend(images.iter().map(|&v| F16::from_f32(v)));
                    }
                    Repr::Codes(_) => {
                        panic!("sigmoid LUT expects accumulators or binary16")
                    }
                }
                lut.eval_vec(half, ctr);
                Repr::Half
            }
            Stage::ReluInt => match repr {
                Repr::Acc(frac) => {
                    for a in acc.iter_mut() {
                        if *a < 0 {
                            *a = 0;
                        }
                    }
                    ctr.compares += acc.len() as u64;
                    Repr::Acc(frac)
                }
                other => other, // ReLU on codes/half handled at encode
            },
            Stage::MaxPool2Int { h, w, c } => match repr {
                Repr::Acc(frac) => {
                    let (h, w, c) = (*h, *w, *c);
                    let (oh, ow) = (h / 2, w / 2);
                    assert_eq!(acc.len(), batch * h * w * c);
                    reset_len_i64(acc2, batch * oh * ow * c);
                    acc2.fill(i64::MIN);
                    for s in 0..batch {
                        let src = &acc[s * h * w * c..(s + 1) * h * w * c];
                        let dst = &mut acc2[s * oh * ow * c..(s + 1) * oh * ow * c];
                        for y in 0..h {
                            for x in 0..w {
                                for ci in 0..c {
                                    let val = src[(y * w + x) * c + ci];
                                    let o = &mut dst[((y / 2) * ow + x / 2) * c + ci];
                                    if val > *o {
                                        *o = val;
                                    }
                                }
                            }
                        }
                    }
                    ctr.compares += (batch * h * w * c) as u64;
                    std::mem::swap(acc, acc2);
                    Repr::Acc(frac)
                }
                _ => panic!("maxpool expects accumulators"),
            },
            Stage::ToHalf => match repr {
                Repr::Acc(frac) => {
                    f16enc::acc_slice_to_f16_into(acc, frac, half, ctr);
                    Repr::Half
                }
                Repr::F32 => {
                    half.clear();
                    half.extend(images.iter().map(|&v| F16::from_f32(v.max(0.0))));
                    Repr::Half
                }
                other => other,
            },
            Stage::ToFixed { bits, range_exp } => match repr {
                Repr::Acc(frac) => {
                    // code = clamp(acc >> (frac - bits + range_exp));
                    // value represented = code * 2^(range_exp - bits)
                    let shift = frac as i32 - *bits as i32 + range_exp;
                    let maxc = (1u32 << bits) - 1;
                    ctr.compares += 2 * acc.len() as u64;
                    codes.clear();
                    codes.extend(acc.iter().map(|&a| {
                        if a <= 0 {
                            return 0;
                        }
                        let c = if shift >= 0 {
                            (a >> shift as u32) as u64
                        } else {
                            (a as u64) << (-shift) as u32
                        };
                        (c as u32).min(maxc)
                    }));
                    Repr::Codes(*bits)
                }
                _ => panic!("tofixed expects accumulators"),
            },
        }
    }

    fn run_stage(&self, stage: &Stage, act: Act, ctr: &mut Counters) -> Act {
        match stage {
            Stage::DenseWhole(lut) => {
                let v = match act {
                    Act::F32(x) => lut.eval_f32(&x, ctr),
                    Act::Codes { v, bits } => {
                        debug_assert_eq!(bits, lut.fmt.bits);
                        lut.eval_codes(&v, ctr)
                    }
                    _ => panic!("whole-fixed dense expects f32 or codes"),
                };
                Act::Acc { v, frac: ACC_FRAC }
            }
            Stage::DenseBitplane(lut) => {
                let v = match act {
                    Act::F32(x) => lut.eval_f32(&x, ctr),
                    Act::Codes { v, bits } => {
                        debug_assert_eq!(bits, lut.fmt.bits);
                        lut.eval_codes(&v, ctr)
                    }
                    _ => panic!("bitplane dense expects f32 or codes"),
                };
                Act::Acc { v, frac: ACC_FRAC }
            }
            Stage::DenseFloat(lut) => {
                let v = match act {
                    Act::F32(x) => lut.eval_f32(&x, ctr),
                    Act::Half(h) => lut.eval_f16(&h, ctr),
                    _ => panic!("float dense expects f32 or half"),
                };
                Act::Acc { v, frac: FACC as u32 }
            }
            Stage::ConvFixed(lut) => {
                let v = match act {
                    Act::F32(x) => lut.eval_f32(&x, ctr),
                    Act::Codes { v, bits } => {
                        debug_assert_eq!(bits, lut.fmt.bits);
                        lut.eval_codes(&v, ctr)
                    }
                    _ => panic!("fixed conv expects f32 or codes"),
                };
                Act::Acc { v, frac: ACC_FRAC }
            }
            Stage::ConvFloat(lut) => {
                let v = match act {
                    Act::F32(x) => {
                        let h: Vec<F16> =
                            x.iter().map(|&v| F16::from_f32(v.max(0.0))).collect();
                        lut.eval_f16(&h, ctr)
                    }
                    Act::Half(h) => lut.eval_f16(&h, ctr),
                    _ => panic!("float conv expects f32 or half"),
                };
                Act::Acc { v, frac: FACC as u32 }
            }
            Stage::SigmoidLut(lut) => {
                let mut h = match act {
                    Act::Half(h) => h,
                    Act::Acc { v, frac } => {
                        f16enc::acc_vec_to_f16_signed(&v, frac, ctr)
                    }
                    Act::F32(x) => x.iter().map(|&v| F16::from_f32(v)).collect(),
                    _ => panic!("sigmoid LUT expects accumulators or binary16"),
                };
                lut.eval_vec(&mut h, ctr);
                Act::Half(h)
            }
            Stage::ReluInt => match act {
                Act::Acc { mut v, frac } => {
                    for a in &mut v {
                        ctr.compares += 1;
                        if *a < 0 {
                            *a = 0;
                        }
                    }
                    Act::Acc { v, frac }
                }
                other => other, // ReLU on codes/half handled at encode
            },
            Stage::MaxPool2Int { h, w, c } => match act {
                Act::Acc { v, frac } => {
                    let (oh, ow) = (h / 2, w / 2);
                    let mut out = vec![i64::MIN; oh * ow * c];
                    for y in 0..*h {
                        for x in 0..*w {
                            for ci in 0..*c {
                                let val = v[(y * w + x) * c + ci];
                                let o = &mut out[((y / 2) * ow + x / 2) * c + ci];
                                ctr.compares += 1;
                                if val > *o {
                                    *o = val;
                                }
                            }
                        }
                    }
                    Act::Acc { v: out, frac }
                }
                _ => panic!("maxpool expects accumulators"),
            },
            Stage::ToHalf => match act {
                Act::Acc { v, frac } => {
                    Act::Half(f16enc::acc_vec_to_f16(&v, frac, ctr))
                }
                Act::F32(x) => Act::Half(
                    x.iter().map(|&v| F16::from_f32(v.max(0.0))).collect(),
                ),
                other => other,
            },
            Stage::ToFixed { bits, range_exp } => match act {
                Act::Acc { v, frac } => {
                    // code = clamp(acc >> (frac - bits + range_exp));
                    // value represented = code * 2^(range_exp - bits)
                    let shift = frac as i32 - *bits as i32 + range_exp;
                    let maxc = (1u32 << bits) - 1;
                    let codes = v
                        .iter()
                        .map(|&a| {
                            ctr.compares += 2;
                            if a <= 0 {
                                return 0;
                            }
                            let c = if shift >= 0 {
                                (a >> shift as u32) as u64
                            } else {
                                (a as u64) << (-shift) as u32
                            };
                            (c as u32).min(maxc)
                        })
                        .collect();
                    Act::Codes { v: codes, bits: *bits }
                }
                _ => panic!("tofixed expects accumulators"),
            },
        }
    }

    /// Accuracy over a flat dataset (`images` row-major, one row per
    /// sample). Also returns the op counters of the *first* inference
    /// (they are identical per sample for a fixed plan/architecture,
    /// modulo zero-row skips).
    pub fn accuracy(&self, images: &[f32], row: usize, labels: &[usize]) -> (f64, Counters) {
        assert_eq!(images.len(), row * labels.len());
        let mut correct = 0usize;
        let mut first = Counters::default();
        for (i, &label) in labels.iter().enumerate() {
            let inf = self.infer(&images[i * row..(i + 1) * row]);
            if i == 0 {
                first = inf.counters;
            }
            if inf.class == label {
                correct += 1;
            }
        }
        (correct as f64 / labels.len() as f64, first)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Model;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn linear_model(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        )
    }

    fn mlp_model(seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        Model::mlp(vec![
            (Tensor::randn(&[32, 784], 0.05, &mut rng), Tensor::zeros(&[32])),
            (Tensor::randn(&[16, 32], 0.2, &mut rng), Tensor::zeros(&[16])),
            (Tensor::randn(&[10, 16], 0.3, &mut rng), Tensor::zeros(&[10])),
        ])
    }

    #[test]
    fn linear_lut_agrees_with_reference() {
        let model = linear_model(5);
        let plan = EnginePlan::linear_default();
        let lut = LutModel::compile(&model, &plan).unwrap();
        let mut rng = Rng::new(6);
        let mut agree = 0;
        for _ in 0..20 {
            let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
            // reference on quantized input
            let fmt = FixedFormat::new(3);
            let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
            let ref_out = model.forward(&Tensor::new(&[1, 784], xq));
            let inf = lut.infer(&x);
            inf.counters.assert_multiplier_less();
            if ref_out.argmax_rows()[0] == inf.class {
                agree += 1;
            }
        }
        assert!(agree >= 19, "LUT and reference disagree too often: {agree}/20");
    }

    #[test]
    fn linear_logits_close_to_reference() {
        let model = linear_model(7);
        let plan = EnginePlan::linear_default();
        let lut = LutModel::compile(&model, &plan).unwrap();
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let fmt = FixedFormat::new(3);
        let xq: Vec<f32> = x.iter().map(|&v| fmt.fake_quant(v)).collect();
        let ref_out = model.forward(&Tensor::new(&[1, 784], xq));
        let inf = lut.infer(&x);
        for (g, e) in inf.logits.iter().zip(ref_out.data()) {
            assert!((g - e).abs() < 1e-3, "{g} vs {e}");
        }
    }

    #[test]
    fn engine_size_matches_cost_model() {
        let model = linear_model(9);
        let plan = EnginePlan::linear_default(); // bitplane, 3 bits, m=14
        let lut = LutModel::compile(&model, &plan).unwrap();
        let c = crate::lut::cost::dense_cost(
            784,
            10,
            14,
            crate::lut::cost::IndexMode::BitplaneFixed { r_i: 3 },
            16,
        );
        assert_eq!(lut.size_bits(), c.size_bits);
    }

    #[test]
    fn counters_zero_mults_all_archs_small() {
        let model = mlp_model(10);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = LutModel::compile(&model, &plan).unwrap();
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let inf = lut.infer(&x);
        inf.counters.assert_multiplier_less();
        assert!(inf.counters.lut_evals > 0);
    }

    #[test]
    fn mlp_float_pipeline_tracks_reference() {
        let model = mlp_model(12);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = LutModel::compile(&model, &plan).unwrap();
        let mut rng = Rng::new(13);
        let mut agree = 0;
        for _ in 0..10 {
            let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
            let ref_out = model
                .with_quantization(8, true, 8)
                .forward(&Tensor::new(&[1, 784], x.clone()));
            let inf = lut.infer(&x);
            if ref_out.argmax_rows()[0] == inf.class {
                agree += 1;
            }
        }
        assert!(agree >= 9, "MLP pipeline diverges: {agree}/10");
    }

    #[test]
    fn sigmoid_pipeline_tracks_reference() {
        // MLP with sigmoid activations: engine path = float banks + the
        // paper's 128 KiB scalar LUT; must match the float reference
        let mut rng = Rng::new(77);
        let model = Model {
            arch: crate::nn::Arch::Mlp,
            layers: vec![
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[24, 784], 0.05, &mut rng),
                    b: Tensor::zeros(&[24]),
                },
                crate::nn::Layer::Sigmoid,
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[10, 24], 0.3, &mut rng),
                    b: Tensor::zeros(&[10]),
                },
            ],
            input_shape: vec![784],
        };
        let plan = EnginePlan {
            affine: vec![
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = LutModel::compile(&model, &plan).unwrap();
        // size includes the 128 KiB scalar table
        assert!(lut.size_bits() >= (1 << 16) * 16);
        let mut agree = 0;
        for s in 0..10 {
            let mut r2 = Rng::new(100 + s);
            let x: Vec<f32> = (0..784).map(|_| r2.f32()).collect();
            let inf = lut.infer(&x);
            inf.counters.assert_multiplier_less();
            let ref_out = model.forward(&Tensor::new(&[1, 784], x));
            if ref_out.argmax_rows()[0] == inf.class {
                agree += 1;
            }
        }
        assert!(agree >= 9, "sigmoid pipeline diverged: {agree}/10");
    }

    /// infer_batch must agree bit-exactly with per-sample infer: same
    /// classes, same logits, and counter totals equal to the per-sample
    /// sum — across every stage kind the compiler can emit.
    fn assert_batch_matches_single(model: &Model, plan: &EnginePlan, seed: u64) {
        let lut = LutModel::compile(model, plan).unwrap();
        let features: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(seed);
        let batch = 4;
        let images: Vec<f32> = (0..batch * features).map(|_| rng.f32()).collect();
        let mut scratch = scratch::Scratch::new();
        let got = lut.infer_batch(&images, batch, &mut scratch);
        got.counters.assert_multiplier_less();
        let mut total = Counters::default();
        for s in 0..batch {
            let single = lut.infer(&images[s * features..(s + 1) * features]);
            assert_eq!(got.classes[s], single.class, "class diverges at sample {s}");
            assert_eq!(
                got.logits_row(s),
                single.logits.as_slice(),
                "logits diverge at sample {s}"
            );
            total += single.counters;
        }
        assert_eq!(got.counters, total, "batched counter totals diverge");
    }

    #[test]
    fn infer_batch_matches_single_linear_bitplane() {
        let model = linear_model(31);
        assert_batch_matches_single(&model, &EnginePlan::linear_default(), 131);
    }

    #[test]
    fn infer_batch_matches_single_mlp_float() {
        let model = mlp_model(32);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 132);
    }

    #[test]
    fn infer_batch_matches_single_fixed_inner() {
        let model = mlp_model(33);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 133);
    }

    #[test]
    fn infer_batch_matches_single_sigmoid() {
        let mut rng = Rng::new(78);
        let model = Model {
            arch: crate::nn::Arch::Mlp,
            layers: vec![
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[24, 784], 0.05, &mut rng),
                    b: Tensor::zeros(&[24]),
                },
                crate::nn::Layer::Sigmoid,
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[10, 24], 0.3, &mut rng),
                    b: Tensor::zeros(&[10]),
                },
            ],
            input_shape: vec![784],
        };
        let plan = EnginePlan {
            affine: vec![
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 134);
    }

    #[test]
    fn infer_batch_matches_single_cnn() {
        // exercises the batched conv wiring end-to-end: ConvFixed,
        // ReluInt, MaxPool2Int (acc/acc2 swap), ToHalf, ConvFloat (pad
        // scratch), Flatten, DenseFloat
        let mut rng = Rng::new(79);
        let model = Model {
            arch: crate::nn::Arch::Cnn,
            layers: vec![
                crate::nn::Layer::Conv2d {
                    filter: Tensor::randn(&[3, 3, 1, 2], 0.3, &mut rng),
                    b: Tensor::randn(&[2], 0.05, &mut rng),
                },
                crate::nn::Layer::Relu,
                crate::nn::Layer::MaxPool2,
                crate::nn::Layer::Conv2d {
                    filter: Tensor::randn(&[3, 3, 2, 3], 0.2, &mut rng),
                    b: Tensor::randn(&[3], 0.05, &mut rng),
                },
                crate::nn::Layer::Relu,
                crate::nn::Layer::Flatten,
                crate::nn::Layer::Dense {
                    w: Tensor::randn(&[10, 4 * 4 * 3], 0.2, &mut rng),
                    b: Tensor::zeros(&[10]),
                },
            ],
            input_shape: vec![8, 8, 1],
        };
        let plan = EnginePlan {
            affine: vec![
                AffineMode::BitplaneFixed { bits: 3, m: 2, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        assert_batch_matches_single(&model, &plan, 135);
    }

    #[test]
    fn scratch_buffers_stabilize_after_warmup() {
        // after one warm-up batch, further batches of the same geometry
        // must not grow any scratch buffer (the zero-allocation
        // precondition; the allocator-level assert lives in
        // rust/tests/alloc_discipline.rs)
        let model = linear_model(36);
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = LutModel::compile(&model, &plan).unwrap();
        let mut rng = Rng::new(37);
        let batch = 8;
        let images: Vec<f32> = (0..batch * 784).map(|_| rng.f32()).collect();
        let mut scratch = scratch::Scratch::new();
        let mut out = BatchInference::default();
        lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
        let bytes = scratch.resident_bytes();
        let (cap_c, cap_l) = (out.classes.capacity(), out.logits.capacity());
        for _ in 0..5 {
            lut.infer_batch_into(&images, batch, &mut scratch, &mut out);
        }
        assert_eq!(scratch.resident_bytes(), bytes, "scratch grew after warm-up");
        assert_eq!(out.classes.capacity(), cap_c);
        assert_eq!(out.logits.capacity(), cap_l);
    }

    #[test]
    fn fixed_inner_pipeline_runs() {
        // ablation path: fixed-point inner layers with power-of-2 range
        let model = mlp_model(14);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
                AffineMode::BitplaneFixed { bits: 8, m: 4, range_exp: 3 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = LutModel::compile(&model, &plan).unwrap();
        let mut rng = Rng::new(15);
        let x: Vec<f32> = (0..784).map(|_| rng.f32()).collect();
        let inf = lut.infer(&x);
        inf.counters.assert_multiplier_less();
        assert_eq!(inf.logits.len(), 10);
    }
}
