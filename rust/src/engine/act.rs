//! First-class activation buffer for the open stage pipeline.
//!
//! [`ActBuf`] owns the batched activation flowing between stages: one
//! representation tag plus one reusable buffer per representation
//! (f32 staging, fixed-point codes, binary16 codes, integer
//! accumulators). A [`crate::engine::stages::Stage`] reads the buffer
//! matching the current [`Repr`], writes its output into the buffer of
//! its output representation, and retags. Buffers are `clear()` +
//! `extend()`d so, after one warm-up batch, the whole pipeline runs
//! without heap allocations (see `rust/tests/alloc_discipline.rs`).
//!
//! The buffers are public on purpose: stage implementations live in
//! separate modules and need disjoint `&`/`&mut` borrows of individual
//! buffers (e.g. gather from `codes` while accumulating into `acc`).
//! The `repr`/`batch` tags stay private so retagging goes through
//! [`ActBuf::set_repr`] / [`ActBuf::load_f32`].
//!
//! ```
//! use tablenet::engine::act::{ActBuf, Repr};
//!
//! let mut act = ActBuf::new();
//! act.load_f32(&[0.5, -1.0, 2.0, 0.0], 2);   // 2 samples × 2 features
//! assert_eq!(act.batch(), 2);
//! assert_eq!(act.repr(), Repr::F32);
//! assert_eq!(act.f32s.len(), 4);
//! // a quantizing stage would now write `codes` and retag:
//! act.codes.clear();
//! act.codes.extend([3u32, 0, 7, 1]);
//! act.set_repr(Repr::Codes(3));
//! assert_eq!(act.repr(), Repr::Codes(3));
//! ```

use crate::quant::f16::F16;
use crate::quant::FixedFormat;

/// Representation of the activation currently held by an [`ActBuf`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Repr {
    /// Raw f32 rows in `f32s` (model input before the first
    /// quantizing stage).
    #[default]
    F32,
    /// Fixed-point codes of the given bit width in `codes`.
    Codes(u32),
    /// Binary16 codes in `half`.
    Half,
    /// Integer accumulators in `acc` with the given fractional scale.
    Acc(u32),
}

/// Batched activation: a representation tag plus the reusable buffers
/// the representations live in. Row-major `batch x elems` everywhere.
#[derive(Debug, Default)]
pub struct ActBuf {
    batch: usize,
    repr: Repr,
    /// f32 staging rows (valid while `repr` is [`Repr::F32`]).
    pub f32s: Vec<f32>,
    /// Quantized fixed-point codes (valid under [`Repr::Codes`]).
    pub codes: Vec<u32>,
    /// Binary16 codes (valid under [`Repr::Half`]).
    pub half: Vec<F16>,
    /// Integer accumulators (valid under [`Repr::Acc`]).
    pub acc: Vec<i64>,
}

impl ActBuf {
    pub fn new() -> ActBuf {
        ActBuf::default()
    }

    /// Stage a batch of raw f32 rows as the pipeline input.
    ///
    /// This copies the rows (one memcpy per batch, reusing capacity).
    /// Deliberate trade-off: it keeps `ActBuf` (and the whole `Stage`
    /// trait) free of borrowed lifetimes, which is what lets stages be
    /// boxed, serialized and added without touching the engine. The
    /// copy is a few µs next to streaming megabytes of tables; a
    /// borrowed-staging variant is a ROADMAP follow-up if profiles
    /// ever show it.
    pub fn load_f32(&mut self, images: &[f32], batch: usize) {
        assert!(batch > 0, "batch must be >= 1");
        assert_eq!(images.len() % batch, 0, "rows not divisible into batch");
        self.f32s.clear();
        self.f32s.extend_from_slice(images);
        self.batch = batch;
        self.repr = Repr::F32;
    }

    /// Stage a batch of per-request rows (one `Vec<f32>` per sample) as
    /// the pipeline input. This is the rows-direct serving entry: the
    /// coordinator's request payloads land here with exactly one copy,
    /// instead of being flattened into an intermediate staging buffer
    /// first (the former `scratch.input` double copy).
    pub fn load_rows(&mut self, rows: &[Vec<f32>]) {
        assert!(!rows.is_empty(), "batch must be >= 1");
        let features = rows[0].len();
        self.f32s.clear();
        for row in rows {
            assert_eq!(row.len(), features, "rows must share one feature width");
            self.f32s.extend_from_slice(row);
        }
        self.batch = rows.len();
        self.repr = Repr::F32;
    }

    /// Samples in the buffer.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The current representation tag.
    pub fn repr(&self) -> Repr {
        self.repr
    }

    /// Retag after a stage wrote its output buffer.
    pub fn set_repr(&mut self, repr: Repr) {
        self.repr = repr;
    }

    /// Fractional scale of the accumulators; panics unless `repr` is
    /// [`Repr::Acc`].
    pub fn acc_frac(&self) -> u32 {
        match self.repr {
            Repr::Acc(frac) => frac,
            other => panic!("expected accumulators, activation is {other:?}"),
        }
    }

    /// Make `codes` hold `fmt`-quantized activations: quantizes f32
    /// input in place, accepts matching codes, rejects anything else.
    /// The width check is a hard assert (once per batch, not per
    /// element): a mismatched upstream `ToFixed` — possible only via a
    /// hand-crafted artifact — must fail with a clear message, not
    /// with out-of-range table indexing.
    pub fn ensure_codes(&mut self, fmt: FixedFormat) {
        match self.repr {
            Repr::F32 => {
                self.codes.clear();
                self.codes.extend(self.f32s.iter().map(|&v| fmt.quantize(v)));
                self.repr = Repr::Codes(fmt.bits);
            }
            Repr::Codes(bits) => assert_eq!(
                bits, fmt.bits,
                "upstream stage produced {bits}-bit codes, bank expects {}",
                fmt.bits
            ),
            other => panic!(
                "stage expects f32 or {}-bit codes, activation is {other:?}",
                fmt.bits
            ),
        }
    }

    /// Make `half` hold nonnegative binary16 activations: encodes f32
    /// input (clamped at 0, the float banks' ReLU-nonneg contract),
    /// accepts binary16, rejects anything else. Acc-to-half conversion
    /// is the `ToHalf` stage's job, not an implicit coercion.
    pub fn ensure_half_nonneg(&mut self) {
        match self.repr {
            Repr::F32 => {
                self.half.clear();
                self.half
                    .extend(self.f32s.iter().map(|&v| F16::from_f32(v.max(0.0))));
                self.repr = Repr::Half;
            }
            Repr::Half => {}
            other => panic!("stage expects f32 or binary16, activation is {other:?}"),
        }
    }

    /// Sum of buffer capacities in bytes (diagnostics).
    pub fn resident_bytes(&self) -> usize {
        self.f32s.capacity() * 4
            + self.codes.capacity() * 4
            + self.half.capacity() * 2
            + self.acc.capacity() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_sets_tag_and_batch() {
        let mut a = ActBuf::new();
        a.load_f32(&[0.1, 0.2, 0.3, 0.4], 2);
        assert_eq!(a.batch(), 2);
        assert_eq!(a.repr(), Repr::F32);
        assert_eq!(a.f32s.len(), 4);
    }

    #[test]
    fn ensure_codes_quantizes_once() {
        let mut a = ActBuf::new();
        a.load_f32(&[0.0, 0.5, 0.99], 1);
        let fmt = FixedFormat::new(2);
        a.ensure_codes(fmt);
        assert_eq!(a.repr(), Repr::Codes(2));
        assert_eq!(a.codes, vec![0, 2, 3]);
        // idempotent on matching codes
        a.ensure_codes(fmt);
        assert_eq!(a.codes, vec![0, 2, 3]);
    }

    #[test]
    fn ensure_half_clamps_negatives() {
        let mut a = ActBuf::new();
        a.load_f32(&[-1.0, 2.0], 1);
        a.ensure_half_nonneg();
        assert_eq!(a.repr(), Repr::Half);
        assert_eq!(a.half[0].to_f32(), 0.0);
        assert_eq!(a.half[1].to_f32(), 2.0);
    }

    #[test]
    #[should_panic(expected = "expected accumulators")]
    fn acc_frac_rejects_wrong_repr() {
        let a = ActBuf::new();
        let _ = a.acc_frac();
    }

    #[test]
    fn load_rows_matches_flat_load() {
        let rows = vec![vec![0.1f32, 0.2], vec![0.3, 0.4], vec![0.5, 0.6]];
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let mut a = ActBuf::new();
        a.load_rows(&rows);
        assert_eq!(a.batch(), 3);
        assert_eq!(a.repr(), Repr::F32);
        let mut b = ActBuf::new();
        b.load_f32(&flat, 3);
        assert_eq!(a.f32s, b.f32s);
    }

    #[test]
    #[should_panic(expected = "share one feature width")]
    fn load_rows_rejects_ragged_rows() {
        let mut a = ActBuf::new();
        a.load_rows(&[vec![0.0f32, 1.0], vec![0.5]]);
    }

    #[test]
    fn buffers_keep_capacity_across_reloads() {
        let mut a = ActBuf::new();
        a.load_f32(&vec![0.5; 64], 8);
        a.ensure_codes(FixedFormat::new(3));
        let (cf, cc) = (a.f32s.capacity(), a.codes.capacity());
        for _ in 0..5 {
            a.load_f32(&vec![0.25; 64], 8);
            a.ensure_codes(FixedFormat::new(3));
        }
        assert_eq!(a.f32s.capacity(), cf);
        assert_eq!(a.codes.capacity(), cc);
    }
}
