//! Engine plans: which LUT construction each affine layer uses. The
//! planner (`crate::planner`) sweeps these; the engine compiles them.



/// LUT construction for one affine (dense or conv) layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AffineMode {
    /// Whole-code fixed-point indexing: chunk of `m` elements at `bits`
    /// bits each indexes a `2^(m·bits)`-row table.
    WholeFixed {
        bits: u32,
        m: usize,
        /// Power-of-two input range exponent for *inner* layers: input
        /// values are assumed in [0, 2^range_exp); the dequant scale is
        /// baked into the next table at build time (build-time multiply,
        /// zero data-path multiplies).
        range_exp: i32,
    },
    /// Bitplane fixed-point indexing: one table of `2^m` rows reused
    /// across all `bits` planes (for conv layers, `m` is the spatial
    /// block edge and the chunk is the m×m block).
    BitplaneFixed { bits: u32, m: usize, range_exp: i32 },
    /// Binary16 mantissa-plane + full-exponent indexing (`planes` ≤ 11;
    /// `m` elements per chunk, conv uses m = 1).
    Float { planes: u32, m: usize },
}

impl AffineMode {
    /// The cost-model index mode for this affine mode.
    pub fn index_mode(&self) -> crate::lut::cost::IndexMode {
        use crate::lut::cost::IndexMode;
        match *self {
            AffineMode::WholeFixed { bits, .. } => IndexMode::WholeFixed { r_i: bits },
            AffineMode::BitplaneFixed { bits, .. } => {
                IndexMode::BitplaneFixed { r_i: bits }
            }
            AffineMode::Float { planes, .. } => {
                IndexMode::FloatPlanes { planes, exp_bits: 5 }
            }
        }
    }

    pub fn m(&self) -> usize {
        match *self {
            AffineMode::WholeFixed { m, .. }
            | AffineMode::BitplaneFixed { m, .. }
            | AffineMode::Float { m, .. } => m,
        }
    }
}

/// A full engine plan: one mode per affine layer, in model order.
#[derive(Debug, Clone, PartialEq)]
pub struct EnginePlan {
    pub affine: Vec<AffineMode>,
    /// Used if the model has more affine layers than `affine` entries.
    pub fallback: AffineMode,
    /// Accounting width of table entries in bits (the paper uses 16-bit
    /// half-precision outputs).
    pub r_o: u32,
}

impl EnginePlan {
    /// Paper's headline linear config: 3-bit input, bitplane chunks of
    /// 14 pixels — "56 LUTs with a total combined size of 17.5 MiB".
    pub fn linear_default() -> EnginePlan {
        EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 14, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        }
    }

    /// Paper's linear memory-parity config: "784 LUTs totaling about
    /// 30.6 KiB ... the same memory footprint as the reference model".
    pub fn linear_parity() -> EnginePlan {
        EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 1, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        }
    }

    /// Paper's MLP bitplaned config ("2320 LUTs with a combined size of
    /// 162.6 MiB and 14652918 shift-and-add operations"): all three
    /// layers use binary16 mantissa-plane + exponent indexing with
    /// single-element chunks. (The 162.6 MiB and 14.65 M numbers only
    /// reproduce with the *first* layer float-indexed as well; the
    /// engine encodes the [0,1] image through binary16 exactly.)
    pub fn mlp_default() -> EnginePlan {
        EnginePlan {
            affine: vec![
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        }
    }

    /// MLP variant with the paper's "8-bit fixed point format to encode
    /// the input image pixels for the first dense layer" (ablation).
    pub fn mlp_fixed_input() -> EnginePlan {
        EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        }
    }

    /// Paper's CNN config: 8-bit fixed input conv (2×2 spatial blocks),
    /// binary16 single-element partitions for layers 2-4 ("the total
    /// LUT size is 400 MiB").
    pub fn cnn_default() -> EnginePlan {
        EnginePlan {
            affine: vec![
                AffineMode::BitplaneFixed { bits: 8, m: 2, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::Float { planes: 11, m: 1 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        }
    }

    /// Default plan for an architecture by name.
    pub fn default_for(arch: crate::nn::Arch) -> EnginePlan {
        match arch {
            crate::nn::Arch::Linear => EnginePlan::linear_default(),
            crate::nn::Arch::Mlp => EnginePlan::mlp_default(),
            crate::nn::Arch::Cnn => EnginePlan::cnn_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plans_have_right_layer_counts() {
        assert_eq!(EnginePlan::linear_default().affine.len(), 1);
        assert_eq!(EnginePlan::mlp_default().affine.len(), 3);
        assert_eq!(EnginePlan::cnn_default().affine.len(), 4);
    }

    #[test]
    fn index_mode_mapping() {
        use crate::lut::cost::IndexMode;
        let a = AffineMode::BitplaneFixed { bits: 3, m: 14, range_exp: 0 };
        assert_eq!(a.index_mode(), IndexMode::BitplaneFixed { r_i: 3 });
        let f = AffineMode::Float { planes: 11, m: 1 };
        assert_eq!(
            f.index_mode(),
            IndexMode::FloatPlanes { planes: 11, exp_bits: 5 }
        );
    }

    #[test]
    fn plans_serialize() {
        // JSON round-trip via the in-repo codec
        let p = EnginePlan::cnn_default();
        let j = crate::config::plan_to_json(&p);
        let back = crate::config::plan_from_json(
            &crate::config::json::Json::parse(&j.to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(p, back);
    }
}
