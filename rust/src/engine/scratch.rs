//! Reusable scratch arena for batched inference.
//!
//! [`Scratch`] owns everything the stage pipeline needs besides the
//! model itself: the [`ActBuf`] activation flowing between stages, the
//! max-pool ping-pong accumulator, the conv banks' padded accumulator
//! images, and the per-sample counter rows. Request rows enter the
//! pipeline through [`ActBuf::load_rows`] directly (one copy — the
//! former flattened `input` staging area is gone). Buffers are
//! `clear()` + `resize()`d per
//! stage: after one warm-up batch every buffer has reached its
//! high-water capacity and steady-state inference performs **zero heap
//! allocations** (asserted by `rust/tests/alloc_discipline.rs` with a
//! counting global allocator).
//!
//! A `Scratch` is owned by exactly one executor (a coordinator worker
//! thread, a bench loop, a caller of `LutModel::infer_batch`) and
//! threaded `&mut` through every stage — it is deliberately not shared.

use crate::engine::act::ActBuf;
use crate::engine::counters::Counters;

/// Per-executor scratch buffers. All fields are public: stages and
/// benches borrow individual buffers directly.
#[derive(Default)]
pub struct Scratch {
    /// The activation buffer threaded through the stage pipeline.
    pub act: ActBuf,
    /// Secondary accumulators (max-pool ping-pong).
    pub acc2: Vec<i64>,
    /// Conv banks' padded accumulator images, `batch x ph x pw x cout`.
    pub pad: Vec<i64>,
    /// Exact per-sample counter rows for the batch in flight.
    pub sample_counters: Vec<Counters>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Sum of buffer capacities in bytes (diagnostics).
    pub fn resident_bytes(&self) -> usize {
        self.act.resident_bytes()
            + self.acc2.capacity() * 8
            + self.pad.capacity() * 8
            + self.sample_counters.capacity() * std::mem::size_of::<Counters>()
    }
}

/// Set `v`'s length to `n` without shrinking capacity; contents are
/// overwritten by the caller. Allocation-free once capacity ≥ n.
#[inline]
pub fn reset_len_i64(v: &mut Vec<i64>, n: usize) {
    v.clear();
    v.resize(n, 0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_keep_capacity_across_reuse() {
        let mut s = Scratch::new();
        reset_len_i64(&mut s.act.acc, 1024);
        let cap = s.act.acc.capacity();
        let ptr = s.act.acc.as_ptr();
        for _ in 0..10 {
            reset_len_i64(&mut s.act.acc, 1024);
            assert_eq!(s.act.acc.capacity(), cap);
            assert_eq!(s.act.acc.as_ptr(), ptr, "buffer must not reallocate");
        }
        reset_len_i64(&mut s.act.acc, 100);
        assert_eq!(s.act.acc.capacity(), cap, "shrinking length keeps capacity");
    }

    #[test]
    fn resident_bytes_counts_capacity() {
        let mut s = Scratch::new();
        assert_eq!(s.resident_bytes(), 0);
        s.act.acc.reserve_exact(10);
        assert!(s.resident_bytes() >= 80);
    }
}
