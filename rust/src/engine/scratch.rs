//! Reusable scratch arena for batched inference.
//!
//! [`Scratch`] owns every intermediate buffer the batched stage runner
//! needs — quantized codes, binary16 codes, integer accumulators (two,
//! for stages that cannot run in place, e.g. max-pool), the conv banks'
//! padded accumulator images, and a flattened input staging area for
//! the coordinator. Buffers are `clear()` + `resize()`d per stage:
//! after one warm-up batch every buffer has reached its high-water
//! capacity and steady-state inference performs **zero heap
//! allocations** (asserted by `rust/tests/alloc_discipline.rs` with a
//! counting global allocator).
//!
//! A `Scratch` is owned by exactly one executor (a coordinator worker
//! thread, a bench loop, a caller of `LutModel::infer_batch`) and
//! threaded `&mut` through every stage — it is deliberately not shared.

use crate::quant::f16::F16;

/// Per-executor scratch buffers. All fields are public: LUT banks and
/// benches borrow individual buffers directly.
#[derive(Default)]
pub struct Scratch {
    /// Flattened f32 input staging (coordinator: rows copied from the
    /// per-request `Vec<f32>` payloads).
    pub input: Vec<f32>,
    /// Quantized fixed-point codes, `batch x q`.
    pub codes: Vec<u32>,
    /// Binary16 codes, `batch x q`.
    pub half: Vec<F16>,
    /// Primary integer accumulators, `batch x p`.
    pub acc: Vec<i64>,
    /// Secondary accumulators (max-pool ping-pong).
    pub acc2: Vec<i64>,
    /// Conv banks' padded accumulator images, `batch x ph x pw x cout`.
    pub pad: Vec<i64>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Sum of buffer capacities in bytes (diagnostics).
    pub fn resident_bytes(&self) -> usize {
        self.input.capacity() * 4
            + self.codes.capacity() * 4
            + self.half.capacity() * 2
            + self.acc.capacity() * 8
            + self.acc2.capacity() * 8
            + self.pad.capacity() * 8
    }
}

/// Set `v`'s length to `n` without shrinking capacity; contents are
/// overwritten by the caller. Allocation-free once capacity ≥ n.
#[inline]
pub fn reset_len_i64(v: &mut Vec<i64>, n: usize) {
    v.clear();
    v.resize(n, 0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_keep_capacity_across_reuse() {
        let mut s = Scratch::new();
        reset_len_i64(&mut s.acc, 1024);
        let cap = s.acc.capacity();
        let ptr = s.acc.as_ptr();
        for _ in 0..10 {
            reset_len_i64(&mut s.acc, 1024);
            assert_eq!(s.acc.capacity(), cap);
            assert_eq!(s.acc.as_ptr(), ptr, "buffer must not reallocate");
        }
        reset_len_i64(&mut s.acc, 100);
        assert_eq!(s.acc.capacity(), cap, "shrinking length keeps capacity");
    }

    #[test]
    fn resident_bytes_counts_capacity() {
        let mut s = Scratch::new();
        assert_eq!(s.resident_bytes(), 0);
        s.acc.reserve_exact(10);
        assert!(s.resident_bytes() >= 80);
    }
}
