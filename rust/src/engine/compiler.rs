//! The compiler: lowers a reference [`Model`] plus an [`EnginePlan`]
//! into the stage pipeline of a [`LutModel`]. This is the **one** way
//! to construct a `LutModel` from weights; the other constructor is
//! [`LutModel::load`](crate::engine::LutModel::load), which revives a
//! previously compiled `.ltm` artifact without touching weights.
//!
//! Compilation is **optimize-then-emit**: lowering first produces the
//! naive 1:1 stage list (one authored layer → one or two stages), then
//! the optimizer passes in [`crate::engine::optimize`] rewrite it —
//! today, stage folding moves each bank's trailing elementwise chain
//! (`relu`/`tofixed`/`tohalf`/`sigmoid`) into the bank as a fused
//! epilogue, deleting whole stages from the plan. Fusion is on by
//! default and bit-exact with the unfused plan; disable it per build
//! with [`Compiler::fuse`] (the CLI's `compile --no-fuse`).
//!
//! ```
//! use tablenet::engine::{plan::EnginePlan, Compiler};
//! use tablenet::nn::Model;
//! use tablenet::tensor::Tensor;
//! use tablenet::util::Rng;
//!
//! let mut rng = Rng::new(7);
//! let model = Model::mlp(vec![
//!     (Tensor::randn(&[12, 16], 0.3, &mut rng), Tensor::zeros(&[12])),
//!     (Tensor::randn(&[8, 12], 0.3, &mut rng), Tensor::zeros(&[8])),
//!     (Tensor::randn(&[4, 8], 0.3, &mut rng), Tensor::zeros(&[4])),
//! ]);
//! let plan = EnginePlan::mlp_default();
//! let fused = Compiler::new(&model).plan(&plan).build().unwrap();
//! let naive = Compiler::new(&model).plan(&plan).fuse(false).build().unwrap();
//! // same op stream, strictly fewer stages
//! assert!(fused.num_stages() < naive.num_stages());
//! let x = vec![0.5; 16];
//! assert_eq!(fused.infer(&x).logits, naive.infer(&x).logits);
//! ```

use crate::engine::plan::{AffineMode, EnginePlan};
use crate::engine::stages::{
    ConvFixedStage, ConvFloatStage, DenseBitplaneStage, DenseFloatStage, DenseWholeStage,
    MaxPool2IntStage, ReluIntStage, SigmoidLutStage, Stage, ToFixedStage, ToHalfStage,
};
use crate::engine::LutModel;
use crate::lut::bitplane::DenseBitplaneLut;
use crate::lut::conv::ConvLut;
use crate::lut::convfloat::ConvFloatLut;
use crate::lut::dense::DenseWholeLut;
use crate::lut::floatplane::{DenseFloatLut, FloatLutConfig};
use crate::lut::{LutError, Partition};
use crate::nn::{Layer, Model};
use crate::quant::FixedFormat;

/// Builder for compiling a model into a [`LutModel`].
pub struct Compiler<'m> {
    model: &'m Model,
    plan: Option<EnginePlan>,
    fuse: bool,
}

impl<'m> Compiler<'m> {
    /// Start compiling `model`. Without an explicit [`Compiler::plan`],
    /// the architecture's default plan is used. Stage folding is on.
    pub fn new(model: &'m Model) -> Compiler<'m> {
        Compiler { model, plan: None, fuse: true }
    }

    /// Use `plan` for the affine layers.
    pub fn plan(mut self, plan: &EnginePlan) -> Compiler<'m> {
        self.plan = Some(plan.clone());
        self
    }

    /// Enable/disable the stage-folding optimizer pass
    /// ([`crate::engine::optimize::fold_elementwise`]). Default on;
    /// `false` emits the naive 1:1 lowering (the CLI escape hatch
    /// `compile --no-fuse`, and the reference side of the
    /// fused-vs-unfused bit-exactness tests).
    pub fn fuse(mut self, fuse: bool) -> Compiler<'m> {
        self.fuse = fuse;
        self
    }

    /// Build the stage pipeline. Fails if a requested table exceeds the
    /// materialisation cap (those configs are planner-only).
    pub fn build(self) -> Result<LutModel, LutError> {
        let plan = self
            .plan
            .unwrap_or_else(|| EnginePlan::default_for(self.model.arch));
        let model = self.model;
        let mut stages: Vec<Box<dyn Stage>> = Vec::new();
        let mut affine_idx = 0usize;
        // spatial dims tracked through conv stages
        let mut dims: Option<(usize, usize, usize)> = match model.input_shape.as_slice() {
            [h, w, c] => Some((*h, *w, *c)),
            _ => None,
        };

        for layer in &model.layers {
            match layer {
                Layer::QuantFixed { .. } | Layer::QuantF16 => {
                    // the engine performs its own quantization at stage
                    // boundaries; fake-quant markers are training-time
                }
                Layer::Relu => stages.push(Box::new(ReluIntStage)),
                Layer::Sigmoid => {
                    // one table read per element; the stage performs its
                    // own SIGNED acc->f16 encode (pre-activations can be
                    // negative; sigmoid output is nonneg, so downstream
                    // float banks keep their sign-free assumption)
                    let lut = crate::lut::scalar::ScalarLut::sigmoid();
                    stages.push(Box::new(SigmoidLutStage::new(lut)));
                }
                Layer::MaxPool2 => {
                    let (h, w, c) = dims.expect("maxpool needs spatial dims");
                    stages.push(Box::new(MaxPool2IntStage { h, w, c }));
                    dims = Some((h / 2, w / 2, c));
                }
                Layer::Flatten => {
                    dims = None; // flat from here on
                }
                Layer::Dense { w, b } => {
                    let mode = plan.affine.get(affine_idx).unwrap_or(&plan.fallback);
                    affine_idx += 1;
                    let p = w.shape()[0];
                    let q = w.shape()[1];
                    // weight scaling for fixed inner layers
                    let (wdata, boundary): (Vec<f32>, Option<Box<dyn Stage>>) = match mode
                    {
                        AffineMode::WholeFixed { bits, m: _, range_exp }
                        | AffineMode::BitplaneFixed { bits, m: _, range_exp } => {
                            if affine_idx == 1 {
                                (w.data().to_vec(), None)
                            } else {
                                let s = (*range_exp as f32).exp2();
                                (
                                    w.data().iter().map(|&x| x * s).collect(),
                                    Some(Box::new(ToFixedStage {
                                        bits: *bits,
                                        range_exp: *range_exp,
                                    })),
                                )
                            }
                        }
                        AffineMode::Float { .. } => {
                            if affine_idx == 1 {
                                (w.data().to_vec(), None)
                            } else {
                                (w.data().to_vec(), Some(Box::new(ToHalfStage)))
                            }
                        }
                    };
                    if let Some(bstage) = boundary {
                        stages.push(bstage);
                    }
                    let bank: Box<dyn Stage> = match mode {
                        AffineMode::WholeFixed { bits, m, .. } => {
                            let lut = DenseWholeLut::build(
                                &wdata,
                                b.data(),
                                p,
                                q,
                                Partition::contiguous(q, *m),
                                FixedFormat::new(*bits),
                            )?;
                            Box::new(DenseWholeStage::new(lut))
                        }
                        AffineMode::BitplaneFixed { bits, m, .. } => {
                            let lut = DenseBitplaneLut::build(
                                &wdata,
                                b.data(),
                                p,
                                q,
                                Partition::contiguous(q, *m),
                                FixedFormat::new(*bits),
                            )?;
                            Box::new(DenseBitplaneStage::new(lut))
                        }
                        AffineMode::Float { planes, m } => {
                            let lut = DenseFloatLut::build(
                                &wdata,
                                b.data(),
                                p,
                                q,
                                Partition::contiguous(q, *m),
                                FloatLutConfig { planes: *planes },
                            )?;
                            Box::new(DenseFloatStage::new(lut))
                        }
                    };
                    stages.push(bank);
                }
                Layer::Conv2d { filter, b } => {
                    let mode = plan.affine.get(affine_idx).unwrap_or(&plan.fallback);
                    affine_idx += 1;
                    let (h, w2, cin) = dims.expect("conv needs spatial dims");
                    let fs = filter.shape()[0];
                    let r = fs / 2;
                    let cout = filter.shape()[3];
                    match mode {
                        AffineMode::BitplaneFixed { bits, m, range_exp }
                        | AffineMode::WholeFixed { bits, m, range_exp } => {
                            let fdata: Vec<f32> = if affine_idx == 1 {
                                filter.data().to_vec()
                            } else {
                                stages.push(Box::new(ToFixedStage {
                                    bits: *bits,
                                    range_exp: *range_exp,
                                }));
                                let s = (*range_exp as f32).exp2();
                                filter.data().iter().map(|&x| x * s).collect()
                            };
                            let lut = ConvLut::build(
                                &fdata,
                                b.data(),
                                h,
                                w2,
                                cin,
                                cout,
                                r,
                                *m,
                                FixedFormat::new(*bits),
                            )?;
                            stages.push(Box::new(ConvFixedStage::new(lut)));
                        }
                        AffineMode::Float { planes, .. } => {
                            if affine_idx > 1 {
                                stages.push(Box::new(ToHalfStage));
                            }
                            let lut = ConvFloatLut::build(
                                filter.data(),
                                b.data(),
                                h,
                                w2,
                                cin,
                                cout,
                                r,
                                *planes,
                            )?;
                            stages.push(Box::new(ConvFloatStage::new(lut)));
                        }
                    }
                    dims = Some((h, w2, cout));
                }
            }
        }
        // optimize-then-emit: rewrite the lowered pipeline before
        // sealing it (stage folding today; dedup/pruning passes later)
        if self.fuse {
            stages = crate::engine::optimize::fold_elementwise(stages).0;
        }
        Ok(LutModel::from_parts(stages, plan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::stages::StageKind;
    use crate::nn::Arch;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn default_plan_is_used_when_none_given() {
        let mut rng = Rng::new(3);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        assert_eq!(model.arch, Arch::Linear);
        let lut = Compiler::new(&model).build().unwrap();
        assert_eq!(lut.plan(), &EnginePlan::linear_default());
        assert_eq!(lut.num_stages(), 1);
        assert_eq!(lut.stages()[0].kind(), StageKind::DenseBitplane);
    }

    fn three_layer_mlp() -> Model {
        let mut rng = Rng::new(4);
        Model::mlp(vec![
            (Tensor::randn(&[32, 784], 0.05, &mut rng), Tensor::zeros(&[32])),
            (Tensor::randn(&[16, 32], 0.2, &mut rng), Tensor::zeros(&[16])),
            (Tensor::randn(&[10, 16], 0.3, &mut rng), Tensor::zeros(&[10])),
        ])
    }

    #[test]
    fn unfused_mlp_pipeline_emits_boundary_stages() {
        let model = three_layer_mlp();
        let lut = Compiler::new(&model)
            .plan(&EnginePlan::mlp_default())
            .fuse(false)
            .build()
            .unwrap();
        let kinds: Vec<StageKind> = lut.stages().iter().map(|s| s.kind()).collect();
        assert_eq!(
            kinds,
            vec![
                StageKind::DenseFloat,
                StageKind::ReluInt,
                StageKind::ToHalf,
                StageKind::DenseFloat,
                StageKind::ReluInt,
                StageKind::ToHalf,
                StageKind::DenseFloat,
            ]
        );
        assert!(lut.stages().iter().all(|s| s.fused_chain().is_none()));
    }

    #[test]
    fn default_build_folds_elementwise_chains_into_banks() {
        let model = three_layer_mlp();
        let lut = Compiler::new(&model)
            .plan(&EnginePlan::mlp_default())
            .build()
            .unwrap();
        // [dense+relu+tohalf, dense+relu+tohalf, dense] — strictly
        // fewer stages than the 7-stage naive lowering
        let kinds: Vec<StageKind> = lut.stages().iter().map(|s| s.kind()).collect();
        assert_eq!(
            kinds,
            vec![StageKind::DenseFloat, StageKind::DenseFloat, StageKind::DenseFloat]
        );
        for bank in &lut.stages()[..2] {
            let chain = bank.fused_chain().expect("interior banks fused");
            assert_eq!(chain.kinds(), vec![StageKind::ReluInt, StageKind::ToHalf]);
        }
        assert!(lut.stages()[2].fused_chain().is_none());
        // the fused plan accounts the same table storage
        let unfused = Compiler::new(&model)
            .plan(&EnginePlan::mlp_default())
            .fuse(false)
            .build()
            .unwrap();
        assert_eq!(lut.size_bits(), unfused.size_bits());
        assert!(lut.num_stages() < unfused.num_stages());
    }
}
