//! Integer-domain binary16 encoder: converts a fixed-point accumulator
//! (value = acc · 2^-frac_scale) to an [`F16`] using only shifts,
//! compares and adds — no multiplies, no float arithmetic.
//!
//! This is the layer-boundary operation of the engine's float pipeline:
//! the paper stores full-precision results in the tables and quantizes
//! layer *inputs*; in hardware this encode is a priority encoder plus a
//! barrel shifter, which is exactly the bit-rerouting circuitry the
//! paper's concluding remarks describe.

use crate::engine::counters::Counters;
use crate::quant::f16::F16;

/// Encode a nonnegative accumulator to binary16 with round-to-nearest-
/// even. `frac_scale` is the accumulator's fractional bit count.
pub fn acc_to_f16(acc: i64, frac_scale: u32, ctr: &mut Counters) -> F16 {
    ctr.compares += 1;
    if acc <= 0 {
        return F16(0); // ReLU already clamped; encode exact zero
    }
    let acc = acc as u64;
    // position of the leading 1 (priority encoder)
    let msb = 63 - acc.leading_zeros(); // value exponent = msb - frac_scale
    let e2 = msb as i32 - frac_scale as i32;
    ctr.compares += 1;
    if e2 >= 16 {
        // overflow -> f16 max (saturating, like the engine's tables)
        return F16(0x7BFF);
    }
    ctr.compares += 1;
    if e2 >= -14 {
        // normal: take 10 fraction bits below the msb, RNE
        let (mut frac, round) = shift_frac(acc, msb, 10);
        let mut exp = (e2 + 15) as u32;
        if round {
            frac += 1;
            if frac == 0x400 {
                frac = 0;
                exp += 1;
                if exp >= 0x1F {
                    return F16(0x7BFF);
                }
            }
        }
        F16(((exp as u16) << 10) | frac as u16)
    } else {
        // subnormal: value = f * 2^(-24); f = acc >> (frac_scale - 24)
        let shift = frac_scale as i32 - 24;
        let f = if shift >= 0 {
            let s = shift as u32;
            if s >= 64 {
                0
            } else {
                let base = acc >> s;
                let round_bit = if s == 0 {
                    false
                } else {
                    rne_round_bit(acc, s)
                };
                base + round_bit as u64
            }
        } else {
            acc << (-shift) as u32
        };
        if f >= 0x400 {
            // rounded up into the normal range
            F16(1 << 10)
        } else {
            F16(f as u16)
        }
    }
}

/// Extract `bits` fraction bits below position `msb` (exclusive) from
/// `acc`, returning (fraction, round_up) under round-to-nearest-even.
fn shift_frac(acc: u64, msb: u32, bits: u32) -> (u64, bool) {
    if msb >= bits {
        let s = msb - bits;
        let frac = (acc >> s) & ((1 << bits) - 1);
        let round = if s == 0 { false } else { rne_round_bit(acc, s) };
        (frac, round)
    } else {
        ((acc << (bits - msb)) & ((1 << bits) - 1), false)
    }
}

/// RNE decision for dropping the low `s` bits of `acc`.
fn rne_round_bit(acc: u64, s: u32) -> bool {
    let dropped = acc & ((1u64 << s) - 1);
    let half = 1u64 << (s - 1);
    dropped > half || (dropped == half && ((acc >> s) & 1) == 1)
}

/// Encode a whole accumulator vector (ReLU applied: negatives -> 0).
pub fn acc_vec_to_f16(acc: &[i64], frac_scale: u32, ctr: &mut Counters) -> Vec<F16> {
    acc.iter().map(|&a| acc_to_f16(a, frac_scale, ctr)).collect()
}

/// Signed encode: magnitude through [`acc_to_f16`], sign bit restored.
/// Used where the consumer handles signs (e.g. the sigmoid scalar LUT,
/// which is indexed by the full 16-bit pattern).
pub fn acc_to_f16_signed(acc: i64, frac_scale: u32, ctr: &mut Counters) -> F16 {
    if acc >= 0 {
        acc_to_f16(acc, frac_scale, ctr)
    } else {
        // saturating_neg: i64::MIN has no positive counterpart; its
        // magnitude saturates (to f16 max anyway) instead of
        // overflowing the negation
        let mag = acc_to_f16(acc.saturating_neg(), frac_scale, ctr);
        F16(mag.0 | 0x8000)
    }
}

/// Signed vector encode.
pub fn acc_vec_to_f16_signed(acc: &[i64], frac_scale: u32, ctr: &mut Counters) -> Vec<F16> {
    acc.iter().map(|&a| acc_to_f16_signed(a, frac_scale, ctr)).collect()
}

/// Allocation-free batched encode into a reusable buffer (the stage
/// pipeline's layer-boundary path): `acc` is row-major
/// `batch x elems`, `out` is cleared and refilled (so it never
/// reallocates once its capacity has reached the batch size), and the
/// encode's compare ops land on each sample's own counter row.
pub fn acc_rows_to_f16_into(
    acc: &[i64],
    batch: usize,
    frac_scale: u32,
    out: &mut Vec<F16>,
    ctrs: &mut [Counters],
) {
    assert_eq!(ctrs.len(), batch);
    assert_eq!(acc.len() % batch.max(1), 0);
    let n = acc.len() / batch.max(1);
    out.clear();
    for (s, ctr) in ctrs.iter_mut().enumerate() {
        out.extend(acc[s * n..(s + 1) * n].iter().map(|&a| acc_to_f16(a, frac_scale, ctr)));
    }
}

/// Allocation-free batched signed encode (see [`acc_rows_to_f16_into`]).
pub fn acc_rows_to_f16_signed_into(
    acc: &[i64],
    batch: usize,
    frac_scale: u32,
    out: &mut Vec<F16>,
    ctrs: &mut [Counters],
) {
    assert_eq!(ctrs.len(), batch);
    assert_eq!(acc.len() % batch.max(1), 0);
    let n = acc.len() / batch.max(1);
    out.clear();
    for (s, ctr) in ctrs.iter_mut().enumerate() {
        out.extend(
            acc[s * n..(s + 1) * n]
                .iter()
                .map(|&a| acc_to_f16_signed(a, frac_scale, ctr)),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oracle: go through f64 and the float-domain encoder.
    fn oracle(acc: i64, frac_scale: u32) -> F16 {
        if acc <= 0 {
            return F16(0);
        }
        let v = acc as f64 * (-(frac_scale as f64)).exp2();
        let f = F16::from_f32(v as f32);
        if f.0 == 0x7C00 {
            F16(0x7BFF)
        } else {
            f
        }
    }

    #[test]
    fn matches_oracle_exhaustively_small() {
        let mut ctr = Counters::default();
        for frac in [8u32, 16, 24, 32, 44] {
            for acc in 0..=4096i64 {
                let got = acc_to_f16(acc, frac, &mut ctr);
                let want = oracle(acc, frac);
                assert_eq!(got.0, want.0, "acc={acc} frac={frac}");
            }
        }
    }

    #[test]
    fn matches_oracle_random_large() {
        let mut rng = crate::util::Rng::new(99);
        let mut ctr = Counters::default();
        for _ in 0..20_000 {
            let acc = (rng.next_u64() >> (rng.below(40) as u32 + 2)) as i64;
            for frac in [16u32, 32, 44] {
                let got = acc_to_f16(acc, frac, &mut ctr);
                let want = oracle(acc, frac);
                assert_eq!(got.0, want.0, "acc={acc} frac={frac}");
            }
        }
    }

    #[test]
    fn negative_clamps_to_zero() {
        let mut ctr = Counters::default();
        assert_eq!(acc_to_f16(-1234, 16, &mut ctr).0, 0);
    }

    #[test]
    fn saturates_instead_of_inf() {
        let mut ctr = Counters::default();
        let huge = i64::MAX / 2;
        assert_eq!(acc_to_f16(huge, 8, &mut ctr).0, 0x7BFF);
    }

    #[test]
    fn exact_powers_of_two() {
        let mut ctr = Counters::default();
        // acc = 2^20 at frac 16 -> value 16.0 -> f16 0x4C00
        assert_eq!(acc_to_f16(1 << 20, 16, &mut ctr).to_f32(), 16.0);
        assert_eq!(acc_to_f16(1 << 16, 16, &mut ctr).to_f32(), 1.0);
    }

    #[test]
    fn subnormal_range() {
        let mut ctr = Counters::default();
        // value 2^-24 (smallest f16 subnormal) at frac 32: acc = 2^8
        let f = acc_to_f16(1 << 8, 32, &mut ctr);
        assert_eq!(f.0, 0x0001);
    }

    #[test]
    fn rows_encode_attributes_counters_per_sample() {
        // two samples with different op mixes: positive accs cost more
        // compares than negatives, and each lands on its own row
        let acc = vec![-5i64, -7, 1 << 16, 1 << 18];
        let mut out = Vec::new();
        let mut ctrs = vec![Counters::default(); 2];
        acc_rows_to_f16_into(&acc, 2, 16, &mut out, &mut ctrs);
        assert_eq!(out.len(), 4);
        assert_eq!(out[2].to_f32(), 1.0);
        let mut c0 = Counters::default();
        let mut c1 = Counters::default();
        let _ = acc_vec_to_f16(&acc[..2], 16, &mut c0);
        let _ = acc_vec_to_f16(&acc[2..], 16, &mut c1);
        assert_eq!(ctrs[0], c0);
        assert_eq!(ctrs[1], c1);
        assert!(c1.compares > c0.compares);
    }

    #[test]
    fn vector_encode_applies_relu() {
        let mut ctr = Counters::default();
        let v = acc_vec_to_f16(&[-5, 0, 1 << 16], 16, &mut ctr);
        assert_eq!(v[0].0, 0);
        assert_eq!(v[1].0, 0);
        assert_eq!(v[2].to_f32(), 1.0);
    }
}
