//! Operation accounting for the multiplier-less engine.
//!
//! Every data-path primitive the engine executes increments one of these
//! counters; `mults` exists precisely so tests can assert it stays at
//! zero end-to-end — the engine does not merely *claim* to be
//! multiplier-less, it proves it per inference.


use std::ops::AddAssign;

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Counters {
    /// Table reads (the paper's "LUT evaluations").
    pub lut_evals: u64,
    /// Scalar shift-and-add operations (bitplane/spatial shifts).
    pub shift_adds: u64,
    /// Plain scalar adds (bias folds, chunk accumulation).
    pub adds: u64,
    /// Scalar multiplies — MUST remain 0 on every LUT data path.
    pub mults: u64,
    /// Compare/branch ops (ReLU, max-pool, argmax — free of multiplies,
    /// and excluded from the paper's comparisons; tracked for
    /// completeness).
    pub compares: u64,
}

impl Counters {
    pub fn total_arith(&self) -> u64 {
        self.shift_adds + self.adds
    }

    /// Panic if any multiply was recorded (used by debug assertions in
    /// the engine and by tests).
    pub fn assert_multiplier_less(&self) {
        assert_eq!(self.mults, 0, "multiplier-less invariant violated");
    }
}

impl AddAssign for Counters {
    fn add_assign(&mut self, o: Counters) {
        self.lut_evals += o.lut_evals;
        self.shift_adds += o.shift_adds;
        self.adds += o.adds;
        self.mults += o.mults;
        self.compares += o.compares;
    }
}

impl std::fmt::Display for Counters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "lut_evals={} shift_adds={} adds={} mults={} compares={}",
            self.lut_evals, self.shift_adds, self.adds, self.mults, self.compares
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_accumulates() {
        let mut a = Counters { lut_evals: 1, shift_adds: 2, adds: 3, mults: 0, compares: 4 };
        let b = Counters { lut_evals: 10, shift_adds: 20, adds: 30, mults: 0, compares: 40 };
        a += b;
        assert_eq!(a.lut_evals, 11);
        assert_eq!(a.total_arith(), 55);
    }

    #[test]
    #[should_panic(expected = "multiplier-less")]
    fn assert_catches_multiplies() {
        let c = Counters { mults: 1, ..Default::default() };
        c.assert_multiplier_less();
    }

    #[test]
    fn display_is_stable() {
        let c = Counters::default();
        assert!(format!("{c}").contains("mults=0"));
    }
}
