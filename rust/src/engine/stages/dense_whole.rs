//! Whole-code fixed-point dense stage: quantizes f32 input (or accepts
//! matching codes from an upstream `ToFixed`) and runs the
//! [`DenseWholeLut`] bank over the batch.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::fuse::FusedChain;
use crate::engine::scratch::{reset_len_i64, Scratch};
use crate::lut::dense::DenseWholeLut;
use crate::lut::{wire, ACC_FRAC};

pub struct DenseWholeStage {
    pub lut: DenseWholeLut,
    /// Elementwise chain absorbed by the stage-folding optimizer
    /// pass, run as an epilogue over the just-written accumulators
    /// (`None` = unfused; artifact bytes then match pre-fusion builds).
    epilogue: Option<FusedChain>,
}

impl DenseWholeStage {
    pub fn new(lut: DenseWholeLut) -> DenseWholeStage {
        DenseWholeStage { lut, epilogue: None }
    }

    pub fn read_payload(
        r: &mut wire::Reader,
        ctx: &wire::WireCtx,
    ) -> wire::Result<DenseWholeStage> {
        let lut = DenseWholeLut::read_wire(r, ctx)?;
        let epilogue = FusedChain::read_wire_opt(r)?;
        Ok(DenseWholeStage { lut, epilogue })
    }
}

impl Stage for DenseWholeStage {
    fn kind(&self) -> StageKind {
        StageKind::DenseWhole
    }

    fn eval_batch(&self, act: &mut ActBuf, scratch: &mut Scratch, counters: &mut [Counters]) {
        act.ensure_codes(self.lut.fmt);
        let batch = act.batch();
        reset_len_i64(&mut act.acc, batch * self.lut.p);
        self.lut.eval_batch(&act.codes, batch, &mut act.acc, counters);
        act.set_repr(Repr::Acc(ACC_FRAC));
        if let Some(chain) = &self.epilogue {
            chain.apply(act, scratch, counters);
        }
    }

    fn size_bits(&self, r_o: u32) -> u64 {
        self.lut.size_bits(r_o)
            + self.epilogue.as_ref().map_or(0, |c| c.size_bits(r_o))
    }

    fn in_elems(&self) -> Option<usize> {
        Some(self.lut.partition.q)
    }

    fn write_payload(&self, out: &mut Vec<u8>, aligned: bool) {
        self.lut.write_wire(out, aligned);
        if let Some(chain) = &self.epilogue {
            chain.write_wire(out);
        }
    }

    fn absorb_chain(&mut self, chain: FusedChain) -> Result<(), FusedChain> {
        self.epilogue = Some(chain);
        Ok(())
    }

    fn fused_chain(&self) -> Option<&FusedChain> {
        self.epilogue.as_ref()
    }

    fn storage(&self) -> Option<crate::lut::arena::ArenaResidency> {
        Some(self.lut.arena().residency())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::Partition;
    use crate::quant::FixedFormat;
    use crate::util::Rng;

    #[test]
    fn stage_matches_bank_eval() {
        let (p, q) = (3, 8);
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(3);
        let lut =
            DenseWholeLut::build(&w, &b, p, q, Partition::contiguous(q, 2), fmt).unwrap();
        let x: Vec<f32> = (0..q).map(|_| rng.f32()).collect();
        let mut want_ctr = Counters::default();
        let want = lut.eval_f32(&x, &mut want_ctr);

        let stage = DenseWholeStage::new(lut);
        let mut act = ActBuf::new();
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default()];
        act.load_f32(&x, 1);
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.repr(), Repr::Acc(ACC_FRAC));
        assert_eq!(act.acc, want);
        assert_eq!(ctrs[0], want_ctr);
    }
}
