//! 2×2 max pool on integer accumulator images (compare + select only).
//! Ping-pongs through `scratch.acc2` because pooling cannot run in
//! place.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::scratch::{reset_len_i64, Scratch};
use crate::lut::wire;

pub struct MaxPool2IntStage {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl MaxPool2IntStage {
    pub fn read_payload(r: &mut wire::Reader) -> wire::Result<MaxPool2IntStage> {
        const DIM_CAP: usize = 1 << 20;
        let h = r.len_capped(DIM_CAP, "maxpool h")?;
        let w = r.len_capped(DIM_CAP, "maxpool w")?;
        let c = r.len_capped(DIM_CAP, "maxpool c")?;
        Ok(MaxPool2IntStage { h, w, c })
    }
}

impl Stage for MaxPool2IntStage {
    fn kind(&self) -> StageKind {
        StageKind::MaxPool2Int
    }

    fn eval_batch(&self, act: &mut ActBuf, scratch: &mut Scratch, counters: &mut [Counters]) {
        match act.repr() {
            Repr::Acc(_) => {
                let batch = act.batch();
                let (h, w, c) = (self.h, self.w, self.c);
                let (oh, ow) = (h / 2, w / 2);
                assert_eq!(act.acc.len(), batch * h * w * c);
                reset_len_i64(&mut scratch.acc2, batch * oh * ow * c);
                scratch.acc2.fill(i64::MIN);
                for s in 0..batch {
                    let src = &act.acc[s * h * w * c..(s + 1) * h * w * c];
                    let dst = &mut scratch.acc2[s * oh * ow * c..(s + 1) * oh * ow * c];
                    for y in 0..h {
                        for x in 0..w {
                            for ci in 0..c {
                                let val = src[(y * w + x) * c + ci];
                                let o = &mut dst[((y / 2) * ow + x / 2) * c + ci];
                                if val > *o {
                                    *o = val;
                                }
                            }
                        }
                    }
                    counters[s].compares += (h * w * c) as u64;
                }
                std::mem::swap(&mut act.acc, &mut scratch.acc2);
            }
            _ => panic!("maxpool expects accumulators"),
        }
    }

    fn size_bits(&self, _r_o: u32) -> u64 {
        0
    }

    fn write_payload(&self, out: &mut Vec<u8>, _aligned: bool) {
        wire::put_u64(out, self.h as u64);
        wire::put_u64(out, self.w as u64);
        wire::put_u64(out, self.c as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_and_swaps_buffers() {
        let stage = MaxPool2IntStage { h: 2, w: 2, c: 1 };
        let mut act = ActBuf::new();
        act.load_f32(&[0.0; 4], 1);
        act.acc.extend_from_slice(&[1, 7, -2, 4]);
        act.set_repr(Repr::Acc(32));
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default()];
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.acc, vec![7]);
        assert_eq!(act.repr(), Repr::Acc(32));
        assert_eq!(ctrs[0].compares, 4);
    }

    #[test]
    fn payload_roundtrip() {
        let stage = MaxPool2IntStage { h: 8, w: 6, c: 3 };
        let mut buf = Vec::new();
        stage.write_payload(&mut buf, false);
        let back = MaxPool2IntStage::read_payload(&mut wire::Reader::new(&buf)).unwrap();
        assert_eq!((back.h, back.w, back.c), (8, 6, 3));
    }
}
