//! Integer ReLU: compare + select on the accumulators. The paper
//! implements ReLU without a table, and so does the engine. On
//! code/binary16 activations the stage is a no-op — the clamp is folded
//! into the boundary encode.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::scratch::Scratch;
use crate::lut::wire;

pub struct ReluIntStage;

impl ReluIntStage {
    pub fn read_payload(_r: &mut wire::Reader) -> wire::Result<ReluIntStage> {
        Ok(ReluIntStage)
    }
}

impl Stage for ReluIntStage {
    fn kind(&self) -> StageKind {
        StageKind::ReluInt
    }

    fn eval_batch(&self, act: &mut ActBuf, _scratch: &mut Scratch, counters: &mut [Counters]) {
        if let Repr::Acc(_) = act.repr() {
            for a in act.acc.iter_mut() {
                if *a < 0 {
                    *a = 0;
                }
            }
            let batch = act.batch();
            let n = (act.acc.len() / batch) as u64;
            for ctr in counters.iter_mut() {
                ctr.compares += n;
            }
        }
        // codes/binary16: clamp already handled at encode — pass through
    }

    fn size_bits(&self, _r_o: u32) -> u64 {
        0
    }

    fn write_payload(&self, _out: &mut Vec<u8>, _aligned: bool) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_negatives_and_charges_compares() {
        let stage = ReluIntStage;
        let mut act = ActBuf::new();
        act.load_f32(&[0.0; 4], 2);
        act.acc.extend_from_slice(&[-3, 5, 0, -1]);
        act.set_repr(Repr::Acc(32));
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default(); 2];
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.acc, vec![0, 5, 0, 0]);
        assert_eq!(ctrs[0].compares, 2);
        assert_eq!(ctrs[1].compares, 2);
    }
}
