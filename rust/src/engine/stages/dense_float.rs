//! Binary16 mantissa-plane dense stage over the [`DenseFloatLut`] bank.
//! Accepts f32 input (first layer — encoded through binary16 with the
//! ReLU-nonneg clamp) or binary16 from an upstream `ToHalf`/sigmoid.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::fuse::FusedChain;
use crate::engine::scratch::{reset_len_i64, Scratch};
use crate::lut::floatplane::{DenseFloatLut, FACC};
use crate::lut::wire;

pub struct DenseFloatStage {
    pub lut: DenseFloatLut,
    /// Elementwise chain absorbed by the stage-folding optimizer
    /// pass, run as an epilogue over the just-written accumulators
    /// (`None` = unfused; artifact bytes then match pre-fusion builds).
    epilogue: Option<FusedChain>,
}

impl DenseFloatStage {
    pub fn new(lut: DenseFloatLut) -> DenseFloatStage {
        DenseFloatStage { lut, epilogue: None }
    }

    pub fn read_payload(
        r: &mut wire::Reader,
        ctx: &wire::WireCtx,
    ) -> wire::Result<DenseFloatStage> {
        let lut = DenseFloatLut::read_wire(r, ctx)?;
        let epilogue = FusedChain::read_wire_opt(r)?;
        Ok(DenseFloatStage { lut, epilogue })
    }
}

impl Stage for DenseFloatStage {
    fn kind(&self) -> StageKind {
        StageKind::DenseFloat
    }

    fn eval_batch(&self, act: &mut ActBuf, scratch: &mut Scratch, counters: &mut [Counters]) {
        act.ensure_half_nonneg();
        let batch = act.batch();
        reset_len_i64(&mut act.acc, batch * self.lut.p);
        self.lut.eval_batch_f16(&act.half, batch, &mut act.acc, counters);
        act.set_repr(Repr::Acc(FACC as u32));
        if let Some(chain) = &self.epilogue {
            chain.apply(act, scratch, counters);
        }
    }

    fn size_bits(&self, r_o: u32) -> u64 {
        self.lut.size_bits(r_o)
            + self.epilogue.as_ref().map_or(0, |c| c.size_bits(r_o))
    }

    fn in_elems(&self) -> Option<usize> {
        Some(self.lut.partition.q)
    }

    fn write_payload(&self, out: &mut Vec<u8>, aligned: bool) {
        self.lut.write_wire(out, aligned);
        if let Some(chain) = &self.epilogue {
            chain.write_wire(out);
        }
    }

    fn absorb_chain(&mut self, chain: FusedChain) -> Result<(), FusedChain> {
        self.epilogue = Some(chain);
        Ok(())
    }

    fn fused_chain(&self) -> Option<&FusedChain> {
        self.epilogue.as_ref()
    }

    fn storage(&self) -> Option<crate::lut::arena::ArenaResidency> {
        Some(self.lut.arena().residency())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::floatplane::FloatLutConfig;
    use crate::lut::Partition;
    use crate::util::Rng;

    #[test]
    fn stage_matches_bank_eval() {
        let (p, q) = (3, 6);
        let mut rng = Rng::new(11);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::singletons(q), FloatLutConfig::default(),
        )
        .unwrap();
        let x: Vec<f32> = (0..q).map(|_| rng.f32() * 4.0).collect();
        let mut want_ctr = Counters::default();
        let want = lut.eval_f32(&x, &mut want_ctr);

        let stage = DenseFloatStage::new(lut);
        let mut act = ActBuf::new();
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default()];
        act.load_f32(&x, 1);
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.repr(), Repr::Acc(FACC as u32));
        assert_eq!(act.acc, want);
        assert_eq!(ctrs[0], want_ctr);
    }

    #[test]
    fn stage_output_is_kernel_independent() {
        use crate::lut::kernel;
        let (p, q, batch) = (3, 6, 5);
        let mut rng = Rng::new(19);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let lut = DenseFloatLut::build(
            &w, &b, p, q, Partition::contiguous(q, 2), FloatLutConfig::default(),
        )
        .unwrap();
        let stage = DenseFloatStage::new(lut);
        let xs: Vec<f32> = (0..batch * q).map(|_| rng.f32() * 4.0).collect();
        let run = |k: kernel::Kernel| {
            let _g = kernel::force(k);
            let mut act = ActBuf::new();
            let mut scratch = Scratch::new();
            let mut ctrs = vec![Counters::default(); batch];
            act.load_f32(&xs, batch);
            stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
            (act.acc.clone(), ctrs)
        };
        let (o_s, c_s) = run(kernel::Kernel::Scalar);
        let (o_v, c_v) = run(kernel::Kernel::Avx2);
        assert_eq!(o_s, o_v);
        assert_eq!(c_s, c_v);
    }
}
