//! Bitplane fixed-point dense stage over the [`DenseBitplaneLut`] bank.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::fuse::FusedChain;
use crate::engine::scratch::{reset_len_i64, Scratch};
use crate::lut::bitplane::DenseBitplaneLut;
use crate::lut::{wire, ACC_FRAC};

pub struct DenseBitplaneStage {
    pub lut: DenseBitplaneLut,
    /// Elementwise chain absorbed by the stage-folding optimizer
    /// pass, run as an epilogue over the just-written accumulators
    /// (`None` = unfused; artifact bytes then match pre-fusion builds).
    epilogue: Option<FusedChain>,
}

impl DenseBitplaneStage {
    pub fn new(lut: DenseBitplaneLut) -> DenseBitplaneStage {
        DenseBitplaneStage { lut, epilogue: None }
    }

    pub fn read_payload(
        r: &mut wire::Reader,
        ctx: &wire::WireCtx,
    ) -> wire::Result<DenseBitplaneStage> {
        let lut = DenseBitplaneLut::read_wire(r, ctx)?;
        let epilogue = FusedChain::read_wire_opt(r)?;
        Ok(DenseBitplaneStage { lut, epilogue })
    }
}

impl Stage for DenseBitplaneStage {
    fn kind(&self) -> StageKind {
        StageKind::DenseBitplane
    }

    fn eval_batch(&self, act: &mut ActBuf, scratch: &mut Scratch, counters: &mut [Counters]) {
        act.ensure_codes(self.lut.fmt);
        let batch = act.batch();
        reset_len_i64(&mut act.acc, batch * self.lut.p);
        self.lut.eval_batch(&act.codes, batch, &mut act.acc, counters);
        act.set_repr(Repr::Acc(ACC_FRAC));
        if let Some(chain) = &self.epilogue {
            chain.apply(act, scratch, counters);
        }
    }

    fn size_bits(&self, r_o: u32) -> u64 {
        self.lut.size_bits(r_o)
            + self.epilogue.as_ref().map_or(0, |c| c.size_bits(r_o))
    }

    fn in_elems(&self) -> Option<usize> {
        Some(self.lut.partition.q)
    }

    fn write_payload(&self, out: &mut Vec<u8>, aligned: bool) {
        self.lut.write_wire(out, aligned);
        if let Some(chain) = &self.epilogue {
            chain.write_wire(out);
        }
    }

    fn absorb_chain(&mut self, chain: FusedChain) -> Result<(), FusedChain> {
        self.epilogue = Some(chain);
        Ok(())
    }

    fn fused_chain(&self) -> Option<&FusedChain> {
        self.epilogue.as_ref()
    }

    fn storage(&self) -> Option<crate::lut::arena::ArenaResidency> {
        Some(self.lut.arena().residency())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::Partition;
    use crate::quant::FixedFormat;
    use crate::util::Rng;

    #[test]
    fn stage_matches_bank_eval_batched() {
        let (p, q, batch) = (4, 12, 3);
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(3);
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 4), fmt)
                .unwrap();
        let xs: Vec<f32> = (0..batch * q).map(|_| rng.f32()).collect();
        let codes: Vec<u32> = xs.iter().map(|&v| fmt.quantize(v)).collect();
        let mut want = vec![0i64; batch * p];
        let mut want_ctrs = vec![Counters::default(); batch];
        lut.eval_batch(&codes, batch, &mut want, &mut want_ctrs);

        let stage = DenseBitplaneStage::new(lut);
        let mut act = ActBuf::new();
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default(); batch];
        act.load_f32(&xs, batch);
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.repr(), Repr::Acc(ACC_FRAC));
        assert_eq!(act.acc, want);
        assert_eq!(ctrs, want_ctrs);
    }

    #[test]
    fn stage_output_is_kernel_independent() {
        use crate::lut::kernel;
        let (p, q, batch) = (4, 12, 5);
        let mut rng = Rng::new(17);
        let w: Vec<f32> = (0..p * q).map(|_| rng.normal() * 0.4).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.normal() * 0.1).collect();
        let fmt = FixedFormat::new(3);
        let lut =
            DenseBitplaneLut::build(&w, &b, p, q, Partition::contiguous(q, 4), fmt)
                .unwrap();
        let stage = DenseBitplaneStage::new(lut);
        let xs: Vec<f32> = (0..batch * q).map(|_| rng.f32()).collect();
        let run = |k: kernel::Kernel| {
            let _g = kernel::force(k);
            let mut act = ActBuf::new();
            let mut scratch = Scratch::new();
            let mut ctrs = vec![Counters::default(); batch];
            act.load_f32(&xs, batch);
            stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
            (act.acc.clone(), ctrs)
        };
        let (o_s, c_s) = run(kernel::Kernel::Scalar);
        let (o_v, c_v) = run(kernel::Kernel::Avx2);
        assert_eq!(o_s, o_v);
        assert_eq!(c_s, c_v);
    }
}
