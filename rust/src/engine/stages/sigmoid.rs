//! Scalar-nonlinearity stage: the paper's 128 KiB binary16→binary16
//! table, one memory read per element. Performs its own SIGNED
//! acc→binary16 encode (pre-activations can be negative; the table is
//! indexed by the full 16-bit pattern).

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::f16enc;
use crate::engine::scratch::Scratch;
use crate::lut::scalar::ScalarLut;
use crate::lut::wire;
use crate::quant::f16::F16;

pub struct SigmoidLutStage {
    pub lut: ScalarLut,
}

impl SigmoidLutStage {
    pub fn new(lut: ScalarLut) -> SigmoidLutStage {
        SigmoidLutStage { lut }
    }

    pub fn read_payload(r: &mut wire::Reader) -> wire::Result<SigmoidLutStage> {
        Ok(SigmoidLutStage { lut: ScalarLut::read_wire(r)? })
    }
}

impl Stage for SigmoidLutStage {
    fn kind(&self) -> StageKind {
        StageKind::SigmoidLut
    }

    fn eval_batch(&self, act: &mut ActBuf, _scratch: &mut Scratch, counters: &mut [Counters]) {
        let batch = act.batch();
        match act.repr() {
            Repr::Half => {}
            Repr::Acc(frac) => {
                f16enc::acc_rows_to_f16_signed_into(
                    &act.acc, batch, frac, &mut act.half, counters,
                );
                act.set_repr(Repr::Half);
            }
            Repr::F32 => {
                act.half.clear();
                act.half.extend(act.f32s.iter().map(|&v| F16::from_f32(v)));
                act.set_repr(Repr::Half);
            }
            Repr::Codes(_) => panic!("sigmoid LUT expects accumulators or binary16"),
        }
        let n = act.half.len() / batch;
        for (s, ctr) in counters.iter_mut().enumerate() {
            self.lut.eval_vec(&mut act.half[s * n..(s + 1) * n], ctr);
        }
    }

    fn size_bits(&self, _r_o: u32) -> u64 {
        self.lut.size_bits()
    }

    fn write_payload(&self, out: &mut Vec<u8>, _aligned: bool) {
        // the 128 KiB scalar table is u16-coded and always decoded onto
        // the heap — alignment applies to the arena-backed bank stages,
        // and `Stage::storage` stays `None` here for the same reason
        // (no `TableArena`, nothing that could ever be mmap-borrowed;
        // its size still shows up through `size_bits`/payload bytes)
        self.lut.write_wire(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_accumulators_through_the_table() {
        let stage = SigmoidLutStage::new(ScalarLut::sigmoid());
        let mut act = ActBuf::new();
        act.load_f32(&[0.0; 2], 2);
        // value 0 and value 1.0 at frac 16
        act.acc.extend_from_slice(&[0, 1 << 16]);
        act.set_repr(Repr::Acc(16));
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default(); 2];
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.repr(), Repr::Half);
        assert!((act.half[0].to_f32() - 0.5).abs() < 1e-3);
        assert!((act.half[1].to_f32() - 0.731).abs() < 1e-2);
        assert_eq!(ctrs[0].lut_evals, 1);
        assert_eq!(ctrs[1].lut_evals, 1);
        assert_eq!(ctrs[0].mults, 0);
    }
}
