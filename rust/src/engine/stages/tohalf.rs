//! Layer-boundary encode: accumulators → binary16 codes (priority
//! encode + shift, see `engine::f16enc`). Applies the ReLU clamp —
//! downstream float banks assume nonnegative input.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::f16enc;
use crate::engine::scratch::Scratch;
use crate::lut::wire;
use crate::quant::f16::F16;

pub struct ToHalfStage;

impl ToHalfStage {
    pub fn read_payload(_r: &mut wire::Reader) -> wire::Result<ToHalfStage> {
        Ok(ToHalfStage)
    }
}

impl Stage for ToHalfStage {
    fn kind(&self) -> StageKind {
        StageKind::ToHalf
    }

    fn eval_batch(&self, act: &mut ActBuf, _scratch: &mut Scratch, counters: &mut [Counters]) {
        match act.repr() {
            Repr::Acc(frac) => {
                let batch = act.batch();
                f16enc::acc_rows_to_f16_into(&act.acc, batch, frac, &mut act.half, counters);
                act.set_repr(Repr::Half);
            }
            Repr::F32 => {
                act.half.clear();
                act.half
                    .extend(act.f32s.iter().map(|&v| F16::from_f32(v.max(0.0))));
                act.set_repr(Repr::Half);
            }
            _ => {} // codes/binary16 pass through
        }
    }

    fn size_bits(&self, _r_o: u32) -> u64 {
        0
    }

    fn write_payload(&self, _out: &mut Vec<u8>, _aligned: bool) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodes_accs_with_relu() {
        let stage = ToHalfStage;
        let mut act = ActBuf::new();
        act.load_f32(&[0.0; 2], 1);
        act.acc.extend_from_slice(&[-9, 1 << 16]);
        act.set_repr(Repr::Acc(16));
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default()];
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.repr(), Repr::Half);
        assert_eq!(act.half[0].to_f32(), 0.0);
        assert_eq!(act.half[1].to_f32(), 1.0);
        assert!(ctrs[0].compares > 0);
    }
}
