//! The open stage API: one executable pipeline step of a compiled
//! multiplier-less model.
//!
//! [`Stage`] replaces the engine's former closed `Stage`/`Act` enums
//! and their duplicated single-vs-batched match arms. A stage reads the
//! [`ActBuf`] in whatever representation it expects, writes its output
//! buffer, retags the activation, and records its op mix on the
//! per-sample counter rows. The per-sample path is batch-of-one, so
//! there is exactly one evaluation code path per stage kind.
//!
//! Adding a new bank kind is additive: implement [`Stage`] in a new
//! module here, give it a [`StageKind`] tag, emit it from the
//! [`crate::engine::Compiler`], and register its decoder in
//! [`read_stage`] — no engine match arms to edit.
//!
//! Driving one stage by hand (the pipeline normally does this):
//!
//! ```
//! use tablenet::engine::act::{ActBuf, Repr};
//! use tablenet::engine::counters::Counters;
//! use tablenet::engine::scratch::Scratch;
//! use tablenet::engine::stages::{ReluIntStage, Stage, StageKind};
//!
//! let mut act = ActBuf::new();
//! act.load_f32(&[0.0; 3], 1);          // size: 1 sample × 3 features
//! act.acc.clear();
//! act.acc.extend_from_slice(&[5, -7, 0]);
//! act.set_repr(Repr::Acc(32));         // pretend a bank just wrote accs
//! let mut scratch = Scratch::new();
//! let mut counters = vec![Counters::default()];
//! let relu = ReluIntStage;
//! assert_eq!(relu.kind(), StageKind::ReluInt);
//! relu.eval_batch(&mut act, &mut scratch, &mut counters);
//! assert_eq!(&act.acc[..], &[5, 0, 0]);
//! counters[0].assert_multiplier_less();
//! ```
//!
//! Each built-in stage lives in its own module:
//!
//! | module             | stage                         | paper section |
//! |--------------------|-------------------------------|---------------|
//! | [`dense_whole`]    | whole-code fixed dense bank   | §Wx + b       |
//! | [`dense_bitplane`] | bitplane fixed dense bank     | §Fixed point  |
//! | [`dense_float`]    | binary16-plane dense bank     | §Floating pt  |
//! | [`conv_fixed`]     | fixed-point conv bank         | §Conv layers  |
//! | [`conv_float`]     | binary16 conv bank            | §Conv layers  |
//! | [`relu`]           | integer ReLU                  | compare only  |
//! | [`sigmoid`]        | 128 KiB scalar-function LUT   | §Nonlinear f  |
//! | [`maxpool`]        | 2×2 integer max pool          | compare only  |
//! | [`tohalf`]         | acc → binary16 boundary encode| §Floating pt  |
//! | [`tofixed`]        | acc → fixed-code boundary     | §Fixed point  |

pub mod conv_fixed;
pub mod conv_float;
pub mod dense_bitplane;
pub mod dense_float;
pub mod dense_whole;
pub mod maxpool;
pub mod relu;
pub mod sigmoid;
pub mod tofixed;
pub mod tohalf;

pub use conv_fixed::ConvFixedStage;
pub use conv_float::ConvFloatStage;
pub use dense_bitplane::DenseBitplaneStage;
pub use dense_float::DenseFloatStage;
pub use dense_whole::DenseWholeStage;
pub use maxpool::MaxPool2IntStage;
pub use relu::ReluIntStage;
pub use sigmoid::SigmoidLutStage;
pub use tofixed::ToFixedStage;
pub use tohalf::ToHalfStage;

use crate::engine::act::ActBuf;
use crate::engine::counters::Counters;
use crate::engine::fuse::FusedChain;
use crate::engine::scratch::Scratch;
use crate::lut::arena::ArenaResidency;
use crate::lut::wire;
use crate::lut::wire::WireCtx;

/// One executable stage of a compiled pipeline.
///
/// Contract:
/// * `eval_batch` is the only evaluation entry point — batch-of-one IS
///   the per-sample path, so batched and per-sample results are
///   bit-exact by construction;
/// * every data-path primitive lands on the counter row of the sample
///   that incurred it (`counters.len() == act.batch()`), and none of
///   them may be a multiply;
/// * after one warm-up batch of a given geometry, `eval_batch` performs
///   zero heap allocations (all intermediates live in `act`/`scratch`).
pub trait Stage: Send + Sync {
    /// Stable kind tag (artifact serialization, diagnostics).
    fn kind(&self) -> StageKind;

    /// Execute the stage batch-at-a-time: consume `act` in this stage's
    /// input representation, leave the output representation behind.
    fn eval_batch(&self, act: &mut ActBuf, scratch: &mut Scratch, counters: &mut [Counters]);

    /// Total LUT storage in bits at accounting width `r_o` (0 for
    /// table-free stages).
    fn size_bits(&self, r_o: u32) -> u64;

    /// Input elements (features) this stage consumes per sample, when
    /// its geometry pins one (the LUT banks). `None` for element-wise /
    /// width-agnostic stages. The engine reads the pipeline's input
    /// width off the first `Some` — what lets a deployment serve raw
    /// request rows from the artifact alone.
    fn in_elems(&self) -> Option<usize> {
        None
    }

    /// Serialize this stage's payload (tables + metadata) for the
    /// `.ltm` artifact. Must round-trip bit-exactly through the decoder
    /// registered in [`read_stage`]. With `aligned` (container v2) the
    /// stage writes *directly into the container buffer*, padding each
    /// table-arena entry block to a 64-byte boundary of `out` so a
    /// mapped load can borrow it in place; table-free stages ignore the
    /// flag.
    fn write_payload(&self, out: &mut Vec<u8>, aligned: bool);

    /// Residency of this stage's [`TableArena`](crate::lut::arena::TableArena)
    /// storage (bytes, i32-narrowing, owned-vs-borrowed) for `tablenet
    /// inspect` and the serve banner. `None` for stages without an
    /// arena — table-free stages AND the scalar sigmoid LUT (heap-only
    /// by design); the default covers them.
    fn storage(&self) -> Option<ArenaResidency> {
        None
    }

    /// Absorb a fused elementwise chain as this stage's epilogue (the
    /// stage-folding optimizer pass, [`crate::engine::optimize`]). LUT
    /// banks override this to take ownership of the chain; everything
    /// else keeps the default, which refuses by handing the chain back
    /// so the optimizer re-emits its stages standalone.
    fn absorb_chain(&mut self, chain: FusedChain) -> Result<(), FusedChain> {
        Err(chain)
    }

    /// The fused epilogue chain this stage absorbed, if any — drives
    /// `tablenet inspect`'s `bank+elem+elem` display, artifact
    /// validation, and the fused-plan accounting.
    fn fused_chain(&self) -> Option<&FusedChain> {
        None
    }
}

/// Stable stage identifiers. The `u16` tags are the on-disk artifact
/// encoding — append-only, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    DenseWhole,
    DenseBitplane,
    DenseFloat,
    ConvFixed,
    ConvFloat,
    ReluInt,
    SigmoidLut,
    MaxPool2Int,
    ToHalf,
    ToFixed,
}

impl StageKind {
    /// On-disk tag.
    pub fn tag(self) -> u16 {
        match self {
            StageKind::DenseWhole => 1,
            StageKind::DenseBitplane => 2,
            StageKind::DenseFloat => 3,
            StageKind::ConvFixed => 4,
            StageKind::ConvFloat => 5,
            StageKind::ReluInt => 6,
            StageKind::SigmoidLut => 7,
            StageKind::MaxPool2Int => 8,
            StageKind::ToHalf => 9,
            StageKind::ToFixed => 10,
        }
    }

    /// Decode an on-disk tag.
    pub fn from_tag(tag: u16) -> Option<StageKind> {
        Some(match tag {
            1 => StageKind::DenseWhole,
            2 => StageKind::DenseBitplane,
            3 => StageKind::DenseFloat,
            4 => StageKind::ConvFixed,
            5 => StageKind::ConvFloat,
            6 => StageKind::ReluInt,
            7 => StageKind::SigmoidLut,
            8 => StageKind::MaxPool2Int,
            9 => StageKind::ToHalf,
            10 => StageKind::ToFixed,
            _ => return None,
        })
    }

    /// Whether this kind is a LUT bank (owns affine tables, outputs
    /// integer accumulators, can absorb a fused elementwise chain).
    pub fn is_bank(self) -> bool {
        matches!(
            self,
            StageKind::DenseWhole
                | StageKind::DenseBitplane
                | StageKind::DenseFloat
                | StageKind::ConvFixed
                | StageKind::ConvFloat
        )
    }

    /// Human-readable name (diagnostics).
    pub fn name(self) -> &'static str {
        match self {
            StageKind::DenseWhole => "dense-whole",
            StageKind::DenseBitplane => "dense-bitplane",
            StageKind::DenseFloat => "dense-float",
            StageKind::ConvFixed => "conv-fixed",
            StageKind::ConvFloat => "conv-float",
            StageKind::ReluInt => "relu-int",
            StageKind::SigmoidLut => "sigmoid-lut",
            StageKind::MaxPool2Int => "maxpool2-int",
            StageKind::ToHalf => "to-half",
            StageKind::ToFixed => "to-fixed",
        }
    }
}

/// Decode one stage payload by kind — the artifact loader's dispatch
/// table. New stage kinds register here. `ctx` carries the container
/// format and (for mapped v2 artifacts) the backing the LUT banks
/// borrow their arenas from zero-copy.
pub fn read_stage(
    kind: StageKind,
    r: &mut wire::Reader,
    ctx: &WireCtx,
) -> wire::Result<Box<dyn Stage>> {
    Ok(match kind {
        StageKind::DenseWhole => Box::new(DenseWholeStage::read_payload(r, ctx)?),
        StageKind::DenseBitplane => Box::new(DenseBitplaneStage::read_payload(r, ctx)?),
        StageKind::DenseFloat => Box::new(DenseFloatStage::read_payload(r, ctx)?),
        StageKind::ConvFixed => Box::new(ConvFixedStage::read_payload(r, ctx)?),
        StageKind::ConvFloat => Box::new(ConvFloatStage::read_payload(r, ctx)?),
        StageKind::ReluInt => Box::new(ReluIntStage::read_payload(r)?),
        StageKind::SigmoidLut => Box::new(SigmoidLutStage::read_payload(r)?),
        StageKind::MaxPool2Int => Box::new(MaxPool2IntStage::read_payload(r)?),
        StageKind::ToHalf => Box::new(ToHalfStage::read_payload(r)?),
        StageKind::ToFixed => Box::new(ToFixedStage::read_payload(r)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip_and_are_unique() {
        let kinds = [
            StageKind::DenseWhole,
            StageKind::DenseBitplane,
            StageKind::DenseFloat,
            StageKind::ConvFixed,
            StageKind::ConvFloat,
            StageKind::ReluInt,
            StageKind::SigmoidLut,
            StageKind::MaxPool2Int,
            StageKind::ToHalf,
            StageKind::ToFixed,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for k in kinds {
            assert!(seen.insert(k.tag()), "duplicate tag {}", k.tag());
            assert_eq!(StageKind::from_tag(k.tag()), Some(k));
            assert!(!k.name().is_empty());
        }
        assert_eq!(StageKind::from_tag(0), None);
        assert_eq!(StageKind::from_tag(999), None);
    }
}
