//! Layer-boundary encode: accumulators → fixed-point codes via
//! right-shift + clamp, for fixed-format inner layers with a
//! power-of-two input range.

use super::{Stage, StageKind};
use crate::engine::act::{ActBuf, Repr};
use crate::engine::counters::Counters;
use crate::engine::scratch::Scratch;
use crate::lut::wire;

pub struct ToFixedStage {
    pub bits: u32,
    pub range_exp: i32,
}

impl ToFixedStage {
    pub fn read_payload(r: &mut wire::Reader) -> wire::Result<ToFixedStage> {
        let bits = r.u32()?;
        if !(1..=16).contains(&bits) {
            return wire::err(format!("to-fixed: bad bits {bits}"));
        }
        let range_exp = r.i32()?;
        if !(-64..=64).contains(&range_exp) {
            return wire::err(format!("to-fixed: bad range_exp {range_exp}"));
        }
        Ok(ToFixedStage { bits, range_exp })
    }
}

impl Stage for ToFixedStage {
    fn kind(&self) -> StageKind {
        StageKind::ToFixed
    }

    fn eval_batch(&self, act: &mut ActBuf, _scratch: &mut Scratch, counters: &mut [Counters]) {
        match act.repr() {
            Repr::Acc(frac) => {
                // code = clamp(acc >> (frac - bits + range_exp));
                // value represented = code * 2^(range_exp - bits).
                // The shift is clamped into i64 range: an extreme
                // range_exp (possible via plan JSON or artifact) then
                // saturates codes to 0/maxc instead of hitting a
                // masked/overflowing shift.
                let shift =
                    (frac as i32 - self.bits as i32 + self.range_exp).clamp(-63, 63);
                let maxc = (1u32 << self.bits) - 1;
                let batch = act.batch();
                let n = (act.acc.len() / batch) as u64;
                for ctr in counters.iter_mut() {
                    ctr.compares += 2 * n;
                }
                act.codes.clear();
                act.codes.extend(act.acc.iter().map(|&a| {
                    if a <= 0 {
                        return 0;
                    }
                    let c = if shift >= 0 {
                        (a >> shift as u32) as u64
                    } else {
                        (a as u64) << (-shift) as u32
                    };
                    (c as u32).min(maxc)
                }));
                act.set_repr(Repr::Codes(self.bits));
            }
            _ => panic!("tofixed expects accumulators"),
        }
    }

    fn size_bits(&self, _r_o: u32) -> u64 {
        0
    }

    fn write_payload(&self, out: &mut Vec<u8>, _aligned: bool) {
        wire::put_u32(out, self.bits);
        wire::put_i32(out, self.range_exp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizes_with_shift_and_clamp() {
        let stage = ToFixedStage { bits: 3, range_exp: 0 };
        let mut act = ActBuf::new();
        act.load_f32(&[0.0; 3], 1);
        // frac 32: value 0.5 -> code 4 at 3 bits; negatives clamp to 0
        act.acc.extend_from_slice(&[1i64 << 31, -5, i64::MAX / 2]);
        act.set_repr(Repr::Acc(32));
        let mut scratch = Scratch::new();
        let mut ctrs = vec![Counters::default()];
        stage.eval_batch(&mut act, &mut scratch, &mut ctrs);
        assert_eq!(act.repr(), Repr::Codes(3));
        assert_eq!(act.codes, vec![4, 0, 7]);
        assert_eq!(ctrs[0].compares, 6);
    }

    #[test]
    fn payload_roundtrip() {
        let stage = ToFixedStage { bits: 8, range_exp: 3 };
        let mut buf = Vec::new();
        stage.write_payload(&mut buf, false);
        let back = ToFixedStage::read_payload(&mut wire::Reader::new(&buf)).unwrap();
        assert_eq!(back.bits, 8);
        assert_eq!(back.range_exp, 3);
    }
}
