//! Experiment harness: regenerates every figure/table of the paper as
//! printable rows + CSV files. Each paper artifact has one entry point;
//! the `benches/` binaries and the `tablenet` CLI both call in here.

pub mod bench;

use crate::data::Split;
use crate::engine::plan::{AffineMode, EnginePlan};
use crate::engine::scratch::Scratch;
use crate::engine::Compiler;
use crate::nn::Model;
use crate::planner::{evaluate_plan, arch_geometry, PlanPoint};
use crate::quant::FixedFormat;
use crate::tensor::Tensor;
use crate::util::{fmt_bits, fmt_ops};
use anyhow::Result;
use std::path::Path;

/// One row of the Fig. 4 / Fig. 6 accuracy-vs-bits sweep.
#[derive(Debug, Clone)]
pub struct BitsRow {
    pub bits: u32,
    /// LUT engine accuracy at this input precision.
    pub lut_acc: f64,
    /// Reference model on identically quantized inputs (sanity track).
    pub ref_quant_acc: f64,
    /// Full-precision reference accuracy (the orange line in Figs 4/6).
    pub ref_acc: f64,
}

/// Figs. 4 & 6: accuracy vs input bits for the linear classifier.
/// Quantization is applied at eval time (the paper's plateau at ~3 bits
/// comes from input information content; see EXPERIMENTS.md).
pub fn bits_sweep(model: &Model, test: &Split, bits_range: &[u32]) -> Vec<BitsRow> {
    let x_full = Tensor::new(&[test.len(), 784], test.images.clone());
    let ref_acc = model.accuracy(&x_full, &test.labels);
    let mut rows = Vec::new();
    // one scratch threaded through every measured plan: the whole sweep
    // runs on the batched engine path, allocation-free after warm-up
    let mut scratch = Scratch::new();
    for &bits in bits_range {
        let fmt = FixedFormat::new(bits);
        // reference on quantized input
        let xq: Vec<f32> = test.images.iter().map(|&v| fmt.fake_quant(v)).collect();
        let ref_quant_acc =
            model.accuracy(&Tensor::new(&[test.len(), 784], xq), &test.labels);
        // LUT engine at matching precision (bitplane m=14 default shape)
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits, m: 14, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(model).plan(&plan).build().expect("linear LUT compiles");
        let (lut_acc, ctr) =
            lut.accuracy_scratch(&test.images, 784, &test.labels, &mut scratch);
        ctr.assert_multiplier_less();
        rows.push(BitsRow { bits, lut_acc, ref_quant_acc, ref_acc });
    }
    rows
}

/// Measured point for a tradeoff figure: planner costs + engine-measured
/// accuracy and op counters (when materialisable).
#[derive(Debug, Clone)]
pub struct TradeoffRow {
    pub point: PlanPoint,
    pub measured_acc: Option<f64>,
    pub measured_evals: Option<u64>,
    pub measured_ops: Option<u64>,
}

/// Evaluate a sweep of plan points against a model + test split,
/// executing the materialisable ones on the engine.
pub fn tradeoff_rows(
    model: &Model,
    test: &Split,
    points: Vec<PlanPoint>,
    max_measured: usize,
) -> Vec<TradeoffRow> {
    let mut rows = Vec::new();
    let mut measured = 0usize;
    // one scratch reused across every measured plan (batched path)
    let mut scratch = Scratch::new();
    for point in points {
        let mut row = TradeoffRow {
            point,
            measured_acc: None,
            measured_evals: None,
            measured_ops: None,
        };
        // engine tables are i64 in this software simulation (4x the
        // r_o=16 accounting width), so cap measured configs well below
        // the host's memory: <= 512 MiB accounting ≈ 2 GiB resident
        let measurable = row.point.materialisable && row.point.size_bits < 1u64 << 32;
        if measurable && measured < max_measured {
            if let Ok(lut) = Compiler::new(model).plan(&row.point.plan).build() {
                let (acc, ctr) =
                    lut.accuracy_scratch(&test.images, 784, &test.labels, &mut scratch);
                ctr.assert_multiplier_less();
                row.measured_acc = Some(acc);
                row.measured_evals = Some(ctr.lut_evals);
                row.measured_ops = Some(ctr.shift_adds + ctr.adds);
                measured += 1;
            }
        }
        rows.push(row);
    }
    rows
}

/// Print a tradeoff table the way the paper's figures report it
/// (sorted by total LUT size).
pub fn print_tradeoff(title: &str, rows: &mut Vec<TradeoffRow>) {
    rows.sort_by_key(|r| r.point.size_bits);
    println!("\n== {title} ==");
    println!(
        "{:<28} {:>8} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "config", "#LUTs", "size", "adds(paper)", "ref MACs", "meas.acc", "meas.ops"
    );
    for r in rows.iter() {
        println!(
            "{:<28} {:>8} {:>12} {:>12} {:>12} {:>10} {:>10}",
            r.point.label,
            r.point.num_luts,
            fmt_bits(r.point.size_bits),
            fmt_ops(r.point.ops),
            fmt_ops(r.point.ref_macs),
            r.measured_acc
                .map(|a| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "-".into()),
            r.measured_ops.map(fmt_ops).unwrap_or_else(|| "-".into()),
        );
    }
}

/// Print a bits sweep (Figs 4/6 shape).
pub fn print_bits_sweep(title: &str, rows: &[BitsRow]) {
    println!("\n== {title} ==");
    println!(
        "{:>5} {:>10} {:>14} {:>12}",
        "bits", "LUT acc", "ref(quant)", "ref(full)"
    );
    for r in rows {
        println!(
            "{:>5} {:>9.1}% {:>13.1}% {:>11.1}%",
            r.bits,
            r.lut_acc * 100.0,
            r.ref_quant_acc * 100.0,
            r.ref_acc * 100.0
        );
    }
}

/// Dump tradeoff rows to CSV.
pub fn tradeoff_csv(rows: &[TradeoffRow]) -> String {
    let mut s = String::from(
        "config,num_luts,size_bits,lut_evals,adds_paper,adds_exclusive,adds_inclusive,ref_macs,measured_acc,measured_ops\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{}\n",
            r.point.label.replace(',', ";"),
            r.point.num_luts,
            r.point.size_bits,
            r.point.lut_evals,
            r.point.ops,
            r.point.ops_exclusive,
            r.point.ops_inclusive,
            r.point.ref_macs,
            r.measured_acc.map(|a| format!("{a:.4}")).unwrap_or_default(),
            r.measured_ops.map(|o| o.to_string()).unwrap_or_default(),
        ));
    }
    s
}

/// Dump bits-sweep rows to CSV.
pub fn bits_csv(rows: &[BitsRow]) -> String {
    let mut s = String::from("bits,lut_acc,ref_quant_acc,ref_acc\n");
    for r in rows {
        s.push_str(&format!(
            "{},{:.4},{:.4},{:.4}\n",
            r.bits, r.lut_acc, r.ref_quant_acc, r.ref_acc
        ));
    }
    s
}

/// Write a CSV next to the repo's results dir.
pub fn write_csv(dir: &Path, name: &str, contents: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(name), contents)?;
    Ok(())
}

/// In-text configuration check (TXT-LIN / TXT-MLP / TXT-CNN rows of
/// DESIGN.md): paper-claimed vs computed values.
pub fn intext_report() -> Vec<(String, String, String)> {
    use crate::nn::Arch;
    let mut out = Vec::new();
    let lin = arch_geometry(Arch::Linear);
    let p56 = evaluate_plan(&lin, &EnginePlan::linear_default());
    out.push((
        "linear 56 LUTs size".into(),
        "17.5 MiB".into(),
        fmt_bits(p56.size_bits),
    ));
    out.push(("linear 56 LUTs evals".into(), "168".into(), p56.lut_evals.to_string()));
    out.push((
        "linear 56 LUTs shift-adds".into(),
        "1650".into(),
        p56.ops_exclusive.to_string(),
    ));
    let p784 = evaluate_plan(&lin, &EnginePlan::linear_parity());
    out.push((
        "linear 784 LUTs size".into(),
        "30.6 KiB".into(),
        fmt_bits(p784.size_bits),
    ));
    out.push((
        "linear 784 LUTs ops".into(),
        "23520".into(),
        p784.ops_inclusive.to_string(),
    ));
    let mlp = arch_geometry(Arch::Mlp);
    let pm = evaluate_plan(&mlp, &EnginePlan::mlp_default());
    out.push(("MLP LUT count".into(), "2320".into(), pm.num_luts.to_string()));
    out.push((
        "MLP bitplaned size".into(),
        "162.6 MiB".into(),
        fmt_bits(pm.size_bits),
    ));
    out.push((
        "MLP bitplaned shift-adds".into(),
        "14652918".into(),
        pm.ops.to_string(),
    ));
    out.push((
        "MLP reference MACs".into(),
        "1332224".into(),
        pm.ref_macs.to_string(),
    ));
    let cnn = arch_geometry(Arch::Cnn);
    let pc = evaluate_plan(&cnn, &EnginePlan::cnn_default());
    out.push((
        "CNN default size".into(),
        "~400 MiB".into(),
        fmt_bits(pc.size_bits),
    ));
    out.push((
        "CNN reference MACs".into(),
        "12.9M (paper)".into(),
        fmt_ops(pc.ref_macs),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Kind;
    use crate::data::Dataset;
    use crate::train::{train_dense, TrainConfig};

    fn quick_dataset() -> Dataset {
        let (tr_px, tr_lb) = crate::data::synth::generate(Kind::Digits, 400, 5);
        let (te_px, te_lb) = crate::data::synth::generate(Kind::Digits, 150, 6);
        Dataset {
            kind: Kind::Digits,
            train: Split {
                images: tr_px.iter().map(|&v| v as f32 / 255.0).collect(),
                labels: tr_lb.iter().map(|&v| v as usize).collect(),
            },
            test: Split {
                images: te_px.iter().map(|&v| v as f32 / 255.0).collect(),
                labels: te_lb.iter().map(|&v| v as usize).collect(),
            },
        }
    }

    #[test]
    fn bits_sweep_shows_plateau() {
        let ds = quick_dataset();
        let model = train_dense(
            &ds.train,
            &[784, 10],
            &TrainConfig { steps: 250, lr: 0.3, ..Default::default() },
        );
        let rows = bits_sweep(&model, &ds.test, &[1, 2, 3, 4, 8]);
        assert_eq!(rows.len(), 5);
        // 3+ bits should be within a few points of full precision
        let full = rows[0].ref_acc;
        let at3 = rows.iter().find(|r| r.bits == 3).unwrap().lut_acc;
        let at8 = rows.iter().find(|r| r.bits == 8).unwrap().lut_acc;
        assert!(at3 > full - 0.08, "3-bit acc {at3} vs full {full}");
        assert!(at8 > full - 0.05, "8-bit acc {at8} vs full {full}");
        // 1-bit should lose noticeably more than 8-bit
        let at1 = rows.iter().find(|r| r.bits == 1).unwrap().lut_acc;
        assert!(at1 <= at8 + 0.02);
    }

    #[test]
    fn tradeoff_rows_measure_engine() {
        let ds = quick_dataset();
        let model = train_dense(
            &ds.train,
            &[784, 10],
            &TrainConfig { steps: 200, lr: 0.3, ..Default::default() },
        );
        let pts = crate::planner::sweep::linear_tradeoff(3);
        let rows = tradeoff_rows(&model, &ds.test.head(60), pts, 3);
        let measured = rows.iter().filter(|r| r.measured_acc.is_some()).count();
        assert_eq!(measured, 3);
        for r in &rows {
            if let (Some(ops), true) = (r.measured_ops, r.point.materialisable) {
                assert!(ops > 0);
            }
        }
    }

    #[test]
    fn intext_matches() {
        let rows = intext_report();
        let get = |k: &str| {
            rows.iter().find(|(n, _, _)| n == k).map(|(_, _, v)| v.clone()).unwrap()
        };
        assert_eq!(get("linear 56 LUTs evals"), "168");
        assert_eq!(get("linear 56 LUTs shift-adds"), "1650");
        assert_eq!(get("MLP LUT count"), "2320");
        assert_eq!(get("MLP bitplaned shift-adds"), "14652918");
        assert_eq!(get("linear 56 LUTs size"), "17.50 MiB");
    }

    #[test]
    fn csv_output_is_parsable() {
        let rows = vec![BitsRow { bits: 3, lut_acc: 0.9, ref_quant_acc: 0.91, ref_acc: 0.92 }];
        let csv = bits_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].split(',').count(), 4);
    }
}
