//! Micro-benchmark harness (the vendored crate set has no criterion):
//! warmup + timed iterations, outlier-robust statistics, and a stable
//! one-line report format shared by every `benches/*.rs` binary.

use crate::util::{mean, percentile, stddev};
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            0.0
        }
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} {:>12} {:>12} {:>10.1}/s  (n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.per_sec(),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark runner: adaptive iteration count targeting `budget_ms` of
/// total measurement time (min 5 iters), with 10% warmup.
pub struct Bench {
    pub budget_ms: u64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        // honour an env knob so `make bench-quick` can shrink budgets
        let budget_ms = std::env::var("TABLENET_BENCH_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1500);
        Bench { budget_ms, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(budget_ms: u64) -> Bench {
        Bench { budget_ms, results: Vec::new() }
    }

    /// Time `f`, which must consume-and-return a black-box value so the
    /// optimiser cannot elide it.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // one probe iteration to scale the loop
        let probe = Instant::now();
        let v = f();
        std::hint::black_box(v);
        let probe_ns = probe.elapsed().as_nanos().max(1) as f64;
        let budget_ns = (self.budget_ms as f64) * 1e6;
        let iters = ((budget_ns / probe_ns) as usize).clamp(5, 100_000);
        let warmup = (iters / 10).max(1);
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean(&samples),
            p50_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            std_ns: stddev(&samples),
        };
        println!("{res}");
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn header(title: &str) {
        println!("\n### {title}");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            "case", "mean", "p50", "p95", "rate"
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Ratio mean(a)/mean(b) for two recorded results by name.
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?.mean_ns;
        let fb = self.results.iter().find(|r| r.name == b)?.mean_ns;
        Some(fa / fb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new(20);
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 5);
    }

    #[test]
    fn ratio_of_known_workloads() {
        let mut b = Bench::new(30);
        b.run("short", || {
            let mut s = 0u64;
            for i in 0..500u64 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            s
        });
        b.run("long", || {
            let mut s = 0u64;
            for i in 0..50_000u64 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            s
        });
        let ratio = b.ratio("long", "short").unwrap();
        assert!(ratio > 5.0, "long/short ratio {ratio}");
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.1e9), "3.10 s");
    }
}
