//! Reference (multiplier-full) neural networks: the three architectures
//! the paper evaluates — linear classifier, 3-layer MLP, and a
//! LeNet-style CNN — plus the weights-file interchange with the JAX
//! training path (`python/compile/train.py`).
//!
//! This is the paper's comparison baseline: full-precision forward with
//! `p·q` multiply-and-adds per dense layer (counted by `tensor::ops`).

pub mod weights;

use crate::quant::FixedFormat;
use crate::tensor::conv::{conv2d_same, flatten, maxpool2};
use crate::tensor::ops::{add_bias, matmul, relu, transpose};
use crate::tensor::Tensor;


/// The three paper architectures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// Single dense layer 784 x 10.
    Linear,
    /// Dense 784x1024 - ReLU - 1024x512 - ReLU - 512x10.
    Mlp,
    /// LeNet: conv5x5x32 - pool - conv5x5x64 - pool - fc3136x1024 - fc1024x10.
    Cnn,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(Arch::Linear),
            "mlp" => Some(Arch::Mlp),
            "cnn" | "lenet" => Some(Arch::Cnn),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Arch::Linear => "linear",
            Arch::Mlp => "mlp",
            Arch::Cnn => "cnn",
        }
    }
}

/// A layer of the reference network.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Fully connected: `w` is `[p, q]` row-major (output-major, same
    /// orientation the LUT builder consumes), `b` is `[p]`.
    Dense { w: Tensor, b: Tensor },
    /// 'same' conv: `filter` is `[fh, fw, cin, cout]`, `b` is `[cout]`.
    Conv2d { filter: Tensor, b: Tensor },
    Relu,
    /// Logistic sigmoid — implemented by the engine as a 128 KiB
    /// f16->f16 scalar LUT (paper §Computing a nonlinear function f).
    Sigmoid,
    MaxPool2,
    Flatten,
    /// Fake-quantize activations to a fixed-point format (the paper
    /// inserts these "before the input to a CNN or dense linear layer").
    QuantFixed { fmt: FixedFormat },
    /// Fake-quantize activations through IEEE binary16.
    QuantF16,
}

/// A feed-forward model: the paper's Eq. (1).
#[derive(Debug, Clone)]
pub struct Model {
    pub arch: Arch,
    pub layers: Vec<Layer>,
    /// Input shape excluding batch: [784] or [28, 28, 1].
    pub input_shape: Vec<usize>,
}

impl Model {
    /// Forward a batch. Input: `[batch, ...input_shape]`. Output logits
    /// `[batch, 10]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = match layer {
                Layer::Dense { w, b } => {
                    let wt = transpose(w); // [q, p]
                    add_bias(&matmul(&cur, &wt), b)
                }
                Layer::Conv2d { filter, b } => conv2d_same(&cur, filter, b),
                Layer::Relu => relu(&cur),
                Layer::Sigmoid => Tensor::new(
                    cur.shape(),
                    cur.data().iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect(),
                ),
                Layer::MaxPool2 => maxpool2(&cur),
                Layer::Flatten => flatten(&cur),
                Layer::QuantFixed { fmt } => Tensor::new(
                    cur.shape(),
                    cur.data().iter().map(|&v| fmt.fake_quant(v)).collect(),
                ),
                Layer::QuantF16 => Tensor::new(
                    cur.shape(),
                    cur.data()
                        .iter()
                        .map(|&v| crate::quant::f16::F16::fake_quant(v))
                        .collect(),
                ),
            };
        }
        cur
    }

    /// Classification accuracy over a labelled set. Input rows must
    /// already be flattened to `input_shape`.
    pub fn accuracy(&self, images: &Tensor, labels: &[usize]) -> f64 {
        let preds = self.forward(images).argmax_rows();
        let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        correct as f64 / labels.len() as f64
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Dense { w, b } => w.len() + b.len(),
                Layer::Conv2d { filter, b } => filter.len() + b.len(),
                _ => 0,
            })
            .sum()
    }

    /// Weight storage in bytes at f32 — the paper's "30.7 KiB" /
    /// "5.1 MiB" / "12.49 MiB" memory-footprint baseline.
    pub fn weight_bytes(&self) -> usize {
        self.num_params() * 4
    }

    /// Build the linear classifier from raw tensors.
    pub fn linear(w: Tensor, b: Tensor) -> Model {
        assert_eq!(w.shape(), &[10, 784]);
        Model {
            arch: Arch::Linear,
            layers: vec![Layer::Dense { w, b }],
            input_shape: vec![784],
        }
    }

    /// Build the 3-layer MLP.
    pub fn mlp(params: Vec<(Tensor, Tensor)>) -> Model {
        assert_eq!(params.len(), 3);
        let mut layers = Vec::new();
        for (i, (w, b)) in params.into_iter().enumerate() {
            layers.push(Layer::Dense { w, b });
            if i < 2 {
                layers.push(Layer::Relu);
            }
        }
        Model { arch: Arch::Mlp, layers, input_shape: vec![784] }
    }

    /// Build the LeNet CNN.
    pub fn lenet(
        conv1: (Tensor, Tensor),
        conv2: (Tensor, Tensor),
        fc1: (Tensor, Tensor),
        fc2: (Tensor, Tensor),
    ) -> Model {
        Model {
            arch: Arch::Cnn,
            layers: vec![
                Layer::Conv2d { filter: conv1.0, b: conv1.1 },
                Layer::Relu,
                Layer::MaxPool2,
                Layer::Conv2d { filter: conv2.0, b: conv2.1 },
                Layer::Relu,
                Layer::MaxPool2,
                Layer::Flatten,
                Layer::Dense { w: fc1.0, b: fc1.1 },
                Layer::Relu,
                Layer::Dense { w: fc2.0, b: fc2.1 },
            ],
            input_shape: vec![28, 28, 1],
        }
    }

    /// Insert fake-quant layers before every Dense/Conv input, as the
    /// paper does for LUT-aware evaluation: `input_fmt` before the first
    /// layer, `QuantF16` (or a fixed format) before the rest.
    pub fn with_quantization(&self, input_bits: u32, inner_f16: bool, inner_bits: u32) -> Model {
        let mut layers = Vec::new();
        let mut first = true;
        for l in &self.layers {
            match l {
                Layer::Dense { .. } | Layer::Conv2d { .. } => {
                    if first {
                        layers.push(Layer::QuantFixed { fmt: FixedFormat::new(input_bits) });
                        first = false;
                    } else if inner_f16 {
                        layers.push(Layer::QuantF16);
                    } else {
                        layers.push(Layer::QuantFixed { fmt: FixedFormat::new(inner_bits) });
                    }
                    layers.push(l.clone());
                }
                other => layers.push(other.clone()),
            }
        }
        Model { arch: self.arch, layers, input_shape: self.input_shape.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_linear() -> Model {
        let mut rng = Rng::new(1);
        Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::zeros(&[10]),
        )
    }

    #[test]
    fn linear_forward_shape() {
        let m = tiny_linear();
        let x = Tensor::zeros(&[4, 784]);
        assert_eq!(m.forward(&x).shape(), &[4, 10]);
    }

    #[test]
    fn linear_param_count_matches_paper() {
        let m = tiny_linear();
        assert_eq!(m.num_params(), 784 * 10 + 10);
        // paper: "total storage ... 30.7 KiB"
        let kib = m.weight_bytes() as f64 / 1024.0;
        assert!((kib - 30.66).abs() < 0.1, "{kib}");
    }

    #[test]
    fn mlp_param_storage_matches_paper() {
        let mut rng = Rng::new(2);
        let m = Model::mlp(vec![
            (Tensor::randn(&[1024, 784], 0.03, &mut rng), Tensor::zeros(&[1024])),
            (Tensor::randn(&[512, 1024], 0.03, &mut rng), Tensor::zeros(&[512])),
            (Tensor::randn(&[10, 512], 0.03, &mut rng), Tensor::zeros(&[10])),
        ]);
        // paper: "about 5.1 MiB"
        let mib = m.weight_bytes() as f64 / (1024.0 * 1024.0);
        assert!((mib - 5.08).abs() < 0.1, "{mib}");
        let x = Tensor::zeros(&[2, 784]);
        assert_eq!(m.forward(&x).shape(), &[2, 10]);
    }

    #[test]
    fn lenet_param_storage_matches_paper() {
        let mut rng = Rng::new(3);
        let m = Model::lenet(
            (Tensor::randn(&[5, 5, 1, 32], 0.1, &mut rng), Tensor::zeros(&[32])),
            (Tensor::randn(&[5, 5, 32, 64], 0.1, &mut rng), Tensor::zeros(&[64])),
            (Tensor::randn(&[1024, 3136], 0.02, &mut rng), Tensor::zeros(&[1024])),
            (Tensor::randn(&[10, 1024], 0.05, &mut rng), Tensor::zeros(&[10])),
        );
        // paper: "about 12.49 MiB"
        let mib = m.weight_bytes() as f64 / (1024.0 * 1024.0);
        assert!((mib - 12.49).abs() < 0.05, "{mib}");
        let x = Tensor::zeros(&[1, 28, 28, 1]);
        assert_eq!(m.forward(&x).shape(), &[1, 10]);
    }

    #[test]
    fn quantized_model_structure() {
        let m = tiny_linear().with_quantization(3, true, 8);
        assert!(matches!(m.layers[0], Layer::QuantFixed { .. }));
        assert!(matches!(m.layers[1], Layer::Dense { .. }));
    }

    #[test]
    fn quantization_changes_output_boundedly() {
        let mut rng = Rng::new(4);
        let m = tiny_linear();
        let mq = m.with_quantization(8, true, 8);
        let x = Tensor::new(&[1, 784], (0..784).map(|_| rng.f32()).collect());
        let d = m.forward(&x).max_abs_diff(&mq.forward(&x));
        assert!(d < 0.5, "8-bit quantization shifted logits by {d}");
        assert!(d > 0.0, "quantization should not be a no-op");
    }

    #[test]
    fn accuracy_counts_correct() {
        let m = tiny_linear();
        let x = Tensor::zeros(&[3, 784]);
        let preds = m.forward(&x).argmax_rows();
        let acc = m.accuracy(&x, &preds);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn arch_parse() {
        assert_eq!(Arch::parse("linear"), Some(Arch::Linear));
        assert_eq!(Arch::parse("LeNet"), Some(Arch::Cnn));
        assert_eq!(Arch::parse("nope"), None);
    }
}
