//! Weights interchange: the `TBNW` little-endian binary format written
//! by `python/compile/export.py` after JAX training and read here to
//! build both the reference model and the LUT banks.
//!
//! Layout: magic `TBNW` | u32 version | u32 count | count × tensor,
//! tensor = u32 name_len | name bytes | u32 rank | rank × u64 dims |
//! f32 data (row-major).

use crate::nn::{Arch, Model};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"TBNW";
pub const VERSION: u32 = 1;

/// Named tensor collection, order-preserving by name.
pub type WeightMap = BTreeMap<String, Tensor>;

/// Serialize a weight map.
pub fn write_weights<W: Write>(mut w: W, weights: &WeightMap) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(weights.len() as u32).to_le_bytes())?;
    for (name, t) in weights {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize a weight map.
pub fn read_weights<R: Read>(mut r: R) -> Result<WeightMap> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("bad magic {magic:?}, expected TBNW");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported TBNW version {version}");
    }
    let count = read_u32(&mut r)?;
    let mut map = WeightMap::new();
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("tensor name too long ({name_len})");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        let rank = read_u32(&mut r)? as usize;
        if rank > 8 {
            bail!("rank {rank} too large");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        if n > 64 << 20 {
            bail!("tensor {name} too large ({n} elements)");
        }
        let mut buf = vec![0u8; n * 4];
        r.read_exact(&mut buf)
            .with_context(|| format!("reading data of {name}"))?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        map.insert(name, Tensor::new(&shape, data));
    }
    Ok(map)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Save to a file.
pub fn save(path: &Path, weights: &WeightMap) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write_weights(std::io::BufWriter::new(f), weights)
}

/// Load from a file.
pub fn load(path: &Path) -> Result<WeightMap> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    read_weights(std::io::BufReader::new(f))
}

fn take(map: &mut WeightMap, name: &str, shape: &[usize]) -> Result<Tensor> {
    let t = map
        .remove(name)
        .with_context(|| format!("weights file missing tensor '{name}'"))?;
    if t.shape() != shape {
        bail!("tensor '{name}' has shape {:?}, expected {shape:?}", t.shape());
    }
    Ok(t)
}

/// Assemble a [`Model`] of the given architecture from a weight map
/// (shape-checked against the paper's layer sizes).
pub fn model_from_weights(arch: Arch, mut map: WeightMap) -> Result<Model> {
    let model = match arch {
        Arch::Linear => Model::linear(
            take(&mut map, "fc1.w", &[10, 784])?,
            take(&mut map, "fc1.b", &[10])?,
        ),
        Arch::Mlp => Model::mlp(vec![
            (
                take(&mut map, "fc1.w", &[1024, 784])?,
                take(&mut map, "fc1.b", &[1024])?,
            ),
            (
                take(&mut map, "fc2.w", &[512, 1024])?,
                take(&mut map, "fc2.b", &[512])?,
            ),
            (
                take(&mut map, "fc3.w", &[10, 512])?,
                take(&mut map, "fc3.b", &[10])?,
            ),
        ]),
        Arch::Cnn => Model::lenet(
            (
                take(&mut map, "conv1.f", &[5, 5, 1, 32])?,
                take(&mut map, "conv1.b", &[32])?,
            ),
            (
                take(&mut map, "conv2.f", &[5, 5, 32, 64])?,
                take(&mut map, "conv2.b", &[64])?,
            ),
            (
                take(&mut map, "fc1.w", &[1024, 3136])?,
                take(&mut map, "fc1.b", &[1024])?,
            ),
            (
                take(&mut map, "fc2.w", &[10, 1024])?,
                take(&mut map, "fc2.b", &[10])?,
            ),
        ),
    };
    Ok(model)
}

/// Load a model directly from a TBNW file.
pub fn load_model(arch: Arch, path: &Path) -> Result<Model> {
    model_from_weights(arch, load(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_map() -> WeightMap {
        let mut rng = Rng::new(8);
        let mut m = WeightMap::new();
        m.insert("fc1.w".into(), Tensor::randn(&[10, 784], 0.1, &mut rng));
        m.insert("fc1.b".into(), Tensor::randn(&[10], 0.1, &mut rng));
        m
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let map = sample_map();
        let mut buf = Vec::new();
        write_weights(&mut buf, &map).unwrap();
        let back = read_weights(&buf[..]).unwrap();
        assert_eq!(map.len(), back.len());
        for (k, t) in &map {
            assert_eq!(back[k], *t);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let err = read_weights(&b"NOPE\0\0\0\0"[..]).unwrap_err();
        assert!(err.to_string().contains("bad magic"));
    }

    #[test]
    fn rejects_truncated_file() {
        let map = sample_map();
        let mut buf = Vec::new();
        write_weights(&mut buf, &map).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(read_weights(&buf[..]).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&99u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        assert!(read_weights(&buf[..]).is_err());
    }

    #[test]
    fn model_from_weights_builds_linear() {
        let m = model_from_weights(Arch::Linear, sample_map()).unwrap();
        assert_eq!(m.num_params(), 7850);
    }

    #[test]
    fn model_from_weights_checks_shapes() {
        let mut map = sample_map();
        map.insert("fc1.w".into(), Tensor::zeros(&[10, 10]));
        let err = model_from_weights(Arch::Linear, map).unwrap_err();
        assert!(err.to_string().contains("shape"));
    }

    #[test]
    fn model_from_weights_reports_missing() {
        let err = model_from_weights(Arch::Linear, WeightMap::new()).unwrap_err();
        assert!(err.to_string().contains("missing tensor"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tablenet_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let map = sample_map();
        save(&path, &map).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), map.len());
        std::fs::remove_file(&path).ok();
    }
}
