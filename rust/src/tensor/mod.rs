//! Dense tensor substrate: the minimal numeric fabric the reference
//! (multiplier-full) network and the trainer run on. Row-major `f32`
//! storage; shapes are validated at op boundaries.
//!
//! This is deliberately a small, dependency-free substrate — the paper's
//! comparison baseline is "pq multiply-and-add operations for a standard
//! implementation of Wx + b", and [`ops::matmul`] is exactly that
//! implementation (with a multiply counter so the comparison is honest).

pub mod ops;
pub mod conv;

use crate::util::Rng;

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from raw parts; panics if `data.len() != prod(shape)`.
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// All-`v` tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// He-normal initialisation (used by the in-Rust trainer).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * std).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying; total element count must match.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape.to_vec();
        self
    }

    /// 2-D element access.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Index of the maximum element (ties: first).
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }

    /// Row-wise argmax for a [batch, classes] tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        let (b, c) = (self.shape[0], self.shape[1]);
        (0..b)
            .map(|i| {
                let row = &self.data[i * c..(i + 1) * c];
                let mut best = 0;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn new_rejects_mismatch() {
        Tensor::new(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn argmax_ties_first() {
        let t = Tensor::new(&[4], vec![1.0, 3.0, 3.0, 2.0]);
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    fn argmax_rows_works() {
        let t = Tensor::new(&[2, 3], vec![0.0, 1.0, 0.5, 9.0, -1.0, 3.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        let a = Tensor::randn(&[4, 4], 0.1, &mut r1);
        let b = Tensor::randn(&[4, 4], 0.1, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn max_abs_diff_zero_for_same() {
        let t = Tensor::full(&[3, 3], 1.5);
        assert_eq!(t.max_abs_diff(&t.clone()), 0.0);
    }
}
