//! 2-D convolution / pooling on [batch, h, w, c] (NHWC) tensors — the
//! reference implementation of the paper's convolutional layers (LeNet:
//! 5×5 'same' convolutions + 2×2 max pooling).

use super::Tensor;
use crate::tensor::ops::REF_MACS;
use std::sync::atomic::Ordering;

/// 'same'-padded conv2d. `input`: [b,h,w,cin], `filter`: [fh,fw,cin,cout],
/// `bias`: [cout]. Stride 1. Charges `b*h*w*fh*fw*cin*cout` MACs.
pub fn conv2d_same(input: &Tensor, filter: &Tensor, bias: &Tensor) -> Tensor {
    assert_eq!(input.rank(), 4);
    assert_eq!(filter.rank(), 4);
    let (b, h, w, cin) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    let (fh, fw, fcin, cout) = (
        filter.shape()[0],
        filter.shape()[1],
        filter.shape()[2],
        filter.shape()[3],
    );
    assert_eq!(cin, fcin, "conv channel mismatch");
    assert_eq!(bias.shape(), &[cout]);
    let (ph, pw) = (fh / 2, fw / 2);
    let mut out = vec![0.0f32; b * h * w * cout];
    let id = input.data();
    let fd = filter.data();
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let obase = ((bi * h + oy) * w + ox) * cout;
                out[obase..obase + cout].copy_from_slice(bias.data());
                for ky in 0..fh {
                    let iy = oy as isize + ky as isize - ph as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..fw {
                        let ix = ox as isize + kx as isize - pw as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let ibase = ((bi * h + iy as usize) * w + ix as usize) * cin;
                        let fbase = (ky * fw + kx) * cin * cout;
                        for ci in 0..cin {
                            let iv = id[ibase + ci];
                            if iv == 0.0 {
                                continue;
                            }
                            let frow = &fd[fbase + ci * cout..fbase + (ci + 1) * cout];
                            let orow = &mut out[obase..obase + cout];
                            for (o, &f) in orow.iter_mut().zip(frow) {
                                *o += iv * f;
                            }
                        }
                    }
                }
            }
        }
    }
    REF_MACS.fetch_add((b * h * w * fh * fw * cin * cout) as u64, Ordering::Relaxed);
    Tensor::new(&[b, h, w, cout], out)
}

/// 2×2 max pooling, stride 2. Comparison-only (no multiplies), as the
/// paper notes for pooling layers.
pub fn maxpool2(input: &Tensor) -> Tensor {
    assert_eq!(input.rank(), 4);
    let (b, h, w, c) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even h,w");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    let id = input.data();
    for bi in 0..b {
        for y in 0..h {
            for x in 0..w {
                for ci in 0..c {
                    let v = id[((bi * h + y) * w + x) * c + ci];
                    let o = &mut out[((bi * oh + y / 2) * ow + x / 2) * c + ci];
                    if v > *o {
                        *o = v;
                    }
                }
            }
        }
    }
    Tensor::new(&[b, oh, ow, c], out)
}

/// Flatten [b, ...] -> [b, prod(rest)].
pub fn flatten(input: &Tensor) -> Tensor {
    let b = input.shape()[0];
    let rest: usize = input.shape()[1..].iter().product();
    input.clone().reshape(&[b, rest])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{ref_macs, reset_ref_macs};

    #[test]
    fn conv_identity_filter() {
        // 1x1 filter = passthrough scale
        let input = Tensor::new(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let filter = Tensor::new(&[1, 1, 1, 1], vec![2.0]);
        let bias = Tensor::zeros(&[1]);
        let out = conv2d_same(&input, &filter, &bias);
        assert_eq!(out.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn conv_box_filter_sums_neighbourhood() {
        let input = Tensor::full(&[1, 3, 3, 1], 1.0);
        let filter = Tensor::full(&[3, 3, 1, 1], 1.0);
        let bias = Tensor::zeros(&[1]);
        let out = conv2d_same(&input, &filter, &bias);
        // centre pixel sees all 9; corner sees 4
        assert_eq!(out.data()[4], 9.0);
        assert_eq!(out.data()[0], 4.0);
    }

    #[test]
    fn conv_bias_applied() {
        let input = Tensor::zeros(&[1, 2, 2, 1]);
        let filter = Tensor::zeros(&[3, 3, 1, 2]);
        let bias = Tensor::new(&[2], vec![0.5, -0.5]);
        let out = conv2d_same(&input, &filter, &bias);
        assert_eq!(out.shape(), &[1, 2, 2, 2]);
        assert_eq!(out.data()[0], 0.5);
        assert_eq!(out.data()[1], -0.5);
    }

    #[test]
    fn conv_charges_macs() {
        reset_ref_macs();
        let input = Tensor::zeros(&[1, 4, 4, 2]);
        let filter = Tensor::zeros(&[5, 5, 2, 3]);
        let bias = Tensor::zeros(&[3]);
        let _ = conv2d_same(&input, &filter, &bias);
        assert_eq!(ref_macs(), (4 * 4 * 5 * 5 * 2 * 3) as u64);
    }

    #[test]
    fn maxpool_takes_max() {
        let input = Tensor::new(&[1, 2, 2, 1], vec![1.0, 5.0, 3.0, 2.0]);
        let out = maxpool2(&input);
        assert_eq!(out.shape(), &[1, 1, 1, 1]);
        assert_eq!(out.data(), &[5.0]);
    }

    #[test]
    fn maxpool_per_channel() {
        let input = Tensor::new(
            &[1, 2, 2, 2],
            vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0],
        );
        let out = maxpool2(&input);
        assert_eq!(out.data(), &[4.0, 40.0]);
    }

    #[test]
    fn flatten_shape() {
        let input = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(flatten(&input).shape(), &[2, 60]);
    }
}
