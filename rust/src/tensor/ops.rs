//! Elementwise and linear-algebra ops over [`Tensor`], with an op-count
//! instrument so the reference path's multiply-and-add cost is measured,
//! not asserted.

use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global multiply-and-add counter for the *reference* (multiplier-full)
/// path. The LUT engine has its own counters in `engine::counters`; this
/// one exists so tests can prove the reference path really does the
/// `p*q` MACs the paper charges it with.
pub static REF_MACS: AtomicU64 = AtomicU64::new(0);

/// Reset the reference MAC counter (tests/benches).
pub fn reset_ref_macs() {
    REF_MACS.store(0, Ordering::Relaxed);
}

/// Read the reference MAC counter.
pub fn ref_macs() -> u64 {
    REF_MACS.load(Ordering::Relaxed)
}

/// `a @ b` for a:[m,k], b:[k,n] — the paper's "standard implementation
/// of Wx+b" baseline: m*k*n multiply-and-adds.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // skip but still charge: paper charges dense cost
            }
            let brow = &bd[l * n..(l + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    REF_MACS.fetch_add((m * k * n) as u64, Ordering::Relaxed);
    Tensor::new(&[m, n], out)
}

/// Broadcast-add a row vector b:[n] to every row of a:[m,n].
pub fn add_bias(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 1);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(b.shape()[0], n);
    let mut out = a.data().to_vec();
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] += b.data()[j];
        }
    }
    Tensor::new(&[m, n], out)
}

/// Elementwise ReLU — comparison only, no multiplies (paper: "compare
/// and branch").
pub fn relu(a: &Tensor) -> Tensor {
    Tensor::new(
        a.shape(),
        a.data().iter().map(|&x| if x > 0.0 { x } else { 0.0 }).collect(),
    )
}

/// Elementwise add of same-shape tensors.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    Tensor::new(
        a.shape(),
        a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect(),
    )
}

/// Scale by a constant (training-path only; never on the LUT data path).
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    Tensor::new(a.shape(), a.data().iter().map(|&x| x * s).collect())
}

/// Row-wise softmax for [batch, classes].
pub fn softmax_rows(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &a.data()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[i * n + j] /= sum;
        }
    }
    Tensor::new(&[m, n], out)
}

/// Transpose a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.data()[i * n + j];
        }
    }
    Tensor::new(&[n, m], out)
}

/// Mean cross-entropy between softmax probs:[b,c] and integer labels.
pub fn cross_entropy(probs: &Tensor, labels: &[usize]) -> f32 {
    let (b, c) = (probs.shape()[0], probs.shape()[1]);
    assert_eq!(b, labels.len());
    let mut loss = 0.0f32;
    for (i, &l) in labels.iter().enumerate() {
        loss -= probs.data()[i * c + l].max(1e-12).ln();
    }
    loss / b as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], d: &[f32]) -> Tensor {
        Tensor::new(shape, d.to_vec())
    }

    #[test]
    fn matmul_small() {
        let a = t(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let b = t(&[2, 2], &[1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_counts_macs() {
        reset_ref_macs();
        let a = Tensor::full(&[3, 5], 1.0);
        let b = Tensor::full(&[5, 7], 1.0);
        let _ = matmul(&a, &b);
        assert_eq!(ref_macs(), 3 * 5 * 7);
    }

    #[test]
    fn matmul_identity() {
        let a = t(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let eye = t(&[2, 2], &[1.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul(&a, &eye).data(), a.data());
    }

    #[test]
    #[should_panic]
    fn matmul_rejects_bad_dims() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = matmul(&a, &b);
    }

    #[test]
    fn add_bias_broadcasts() {
        let a = Tensor::zeros(&[2, 3]);
        let b = t(&[3], &[1.0, 2.0, 3.0]);
        let c = add_bias(&a, &b);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn relu_clamps() {
        let a = t(&[4], &[-1.0, 0.0, 0.5, 2.0]);
        assert_eq!(relu(&a).data(), &[0.0, 0.0, 0.5, 2.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let s = softmax_rows(&a);
        for i in 0..2 {
            let sum: f32 = s.data()[i * 3..(i + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = t(&[1, 3], &[1.0, 2.0, 3.0]);
        let b = t(&[1, 3], &[101.0, 102.0, 103.0]);
        assert!(softmax_rows(&a).max_abs_diff(&softmax_rows(&b)) < 1e-6);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn cross_entropy_perfect_prediction_is_zero() {
        let p = t(&[1, 2], &[1.0, 0.0]);
        assert!(cross_entropy(&p, &[0]) < 1e-6);
    }
}
