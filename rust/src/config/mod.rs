//! Configuration system: typed configs with JSON file round-trips (via
//! the in-repo [`json`] codec) and CLI overrides (via [`cli`]).

pub mod cli;
pub mod json;

use crate::engine::plan::{AffineMode, EnginePlan};
use crate::nn::Arch;
use anyhow::{anyhow, bail, Context, Result};
use json::Json;
use std::path::{Path, PathBuf};

/// Serving coordinator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Maximum dynamic batch size.
    pub max_batch: usize,
    /// Maximum time a request may wait for batch-mates.
    pub max_wait_us: u64,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Bounded request queue capacity (backpressure limit).
    pub queue_cap: usize,
    /// Per-request deadline in µs (0 = no deadline). A request that is
    /// still waiting when its deadline passes is shed with a typed
    /// `DeadlineExceeded` — it never blocks its caller forever and is
    /// never silently dropped. Checked at batch formation and again
    /// right before execution; time spent inside the backend is not
    /// preempted.
    pub deadline_us: u64,
    /// Mark the model Degraded after this many CONSECUTIVE worker
    /// panics (0 = never auto-degrade). A successful batch resets the
    /// streak; installing a new backend (swap) clears the Degraded
    /// state.
    pub degrade_after: u32,
    /// Relative queue weight in the shared cross-model admission
    /// controller (`serve --admission-budget`): under contention a
    /// weight-3 model is allotted 3x the in-flight rows of a weight-1
    /// model. Ignored when no admission budget is set. Must be >= 1.
    pub admission_weight: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_wait_us: 500,
            workers: 1,
            queue_cap: 1024,
            deadline_us: 0,
            degrade_after: 3,
            admission_weight: 1,
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_batch", Json::num(self.max_batch as f64)),
            ("max_wait_us", Json::num(self.max_wait_us as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("queue_cap", Json::num(self.queue_cap as f64)),
            ("deadline_us", Json::num(self.deadline_us as f64)),
            ("degrade_after", Json::num(self.degrade_after as f64)),
            ("admission_weight", Json::num(self.admission_weight as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        ServeConfig::from_json_over(j, &ServeConfig::default())
    }

    /// Strict decode with `base` supplying any unspecified knob — a
    /// fleet file's per-model override inherits the fleet defaults for
    /// the keys it does not mention, not the global built-ins.
    pub fn from_json_over(j: &Json, base: &ServeConfig) -> Result<ServeConfig> {
        reject_unknown_keys(
            j,
            "serve config",
            &[
                "max_batch",
                "max_wait_us",
                "workers",
                "queue_cap",
                "deadline_us",
                "degrade_after",
                "admission_weight",
            ],
        )?;
        Ok(ServeConfig {
            max_batch: get_usize(j, "max_batch", base.max_batch)?,
            max_wait_us: get_u64(j, "max_wait_us", base.max_wait_us)?,
            workers: get_usize(j, "workers", base.workers)?,
            queue_cap: get_usize(j, "queue_cap", base.queue_cap)?,
            deadline_us: get_u64(j, "deadline_us", base.deadline_us)?,
            degrade_after: get_u64(j, "degrade_after", base.degrade_after as u64)? as u32,
            admission_weight: get_u64(j, "admission_weight", base.admission_weight as u64)?
                as u32,
        })
    }

    /// Apply CLI overrides.
    pub fn override_with(mut self, args: &cli::Args) -> ServeConfig {
        self.max_batch = args.get_usize("max-batch", self.max_batch);
        self.max_wait_us = args.get_u64("max-wait-us", self.max_wait_us);
        self.workers = args.get_usize("workers", self.workers);
        self.queue_cap = args.get_usize("queue-cap", self.queue_cap);
        self.deadline_us = args.get_u64("deadline-us", self.deadline_us);
        self.degrade_after = args.get_u32("degrade-after", self.degrade_after);
        self.admission_weight = args.get_u32("admission-weight", self.admission_weight);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.queue_cap < self.max_batch {
            bail!("queue_cap ({}) < max_batch ({})", self.queue_cap, self.max_batch);
        }
        if self.deadline_us > 0 && self.deadline_us <= self.max_wait_us {
            bail!(
                "deadline_us ({}) <= max_wait_us ({}): every request would expire \
                 while waiting for batch-mates",
                self.deadline_us,
                self.max_wait_us
            );
        }
        if self.admission_weight == 0 {
            bail!("admission_weight must be >= 1 (a zero-weight model could never serve)");
        }
        Ok(())
    }
}

/// One model of a serving fleet: a compiled `.ltm` artifact plus an
/// optional per-model serving override (None = fleet defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub artifact: PathBuf,
    pub serve: Option<ServeConfig>,
}

/// Multi-model serving configuration: fleet-wide defaults plus one
/// [`ModelConfig`] per named model. This is what `tablenet serve`
/// builds from repeated `--artifact name=path` flags or a `--fleet`
/// JSON file, and what the registry starts pipelines from.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FleetConfig {
    pub defaults: ServeConfig,
    pub models: std::collections::BTreeMap<String, ModelConfig>,
}

/// Parse one `--artifact` spec: `name=path`, or a bare `path` whose
/// file stem becomes the model name.
pub fn parse_artifact_spec(spec: &str) -> Result<(String, PathBuf)> {
    if let Some((name, path)) = spec.split_once('=') {
        if name.is_empty() || path.is_empty() {
            bail!("bad --artifact spec '{spec}' (want name=path or path)");
        }
        return Ok((name.to_string(), PathBuf::from(path)));
    }
    let path = PathBuf::from(spec);
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .filter(|s| !s.is_empty())
        .ok_or_else(|| anyhow!("cannot derive a model name from '{spec}'; use name=path"))?
        .to_string();
    Ok((name, path))
}

impl FleetConfig {
    /// The effective serving config of `name`: its override, or the
    /// fleet defaults.
    pub fn effective(&self, name: &str) -> ServeConfig {
        self.models
            .get(name)
            .and_then(|m| m.serve.clone())
            .unwrap_or_else(|| self.defaults.clone())
    }

    pub fn to_json(&self) -> Json {
        let models = self
            .models
            .iter()
            .map(|(name, m)| {
                let mut fields = vec![(
                    "artifact".to_string(),
                    Json::str(&m.artifact.display().to_string()),
                )];
                if let Some(s) = &m.serve {
                    fields.push(("serve".to_string(), s.to_json()));
                }
                (name.clone(), Json::Obj(fields.into_iter().collect()))
            })
            .collect();
        Json::obj(vec![
            ("defaults", self.defaults.to_json()),
            ("models", Json::Obj(models)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FleetConfig> {
        reject_unknown_keys(j, "fleet config", &["defaults", "models"])?;
        let defaults = match j.get("defaults") {
            Some(d) => ServeConfig::from_json(d)?,
            None => ServeConfig::default(),
        };
        let mut models = std::collections::BTreeMap::new();
        if let Some(mj) = j.get("models") {
            let map = mj
                .as_obj()
                .ok_or_else(|| anyhow!("'models' must be an object of name -> model"))?;
            for (name, entry) in map {
                reject_unknown_keys(entry, &format!("model '{name}'"), &["artifact", "serve"])?;
                let artifact = entry
                    .get("artifact")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model '{name}' missing 'artifact'"))?;
                // partial overrides inherit the FLEET defaults, not the
                // global built-ins
                let serve = match entry.get("serve") {
                    Some(s) => Some(ServeConfig::from_json_over(s, &defaults)?),
                    None => None,
                };
                models.insert(
                    name.clone(),
                    ModelConfig { artifact: PathBuf::from(artifact), serve },
                );
            }
        }
        Ok(FleetConfig { defaults, models })
    }

    /// Build from CLI args: an optional `--fleet config.json` base,
    /// fleet-wide knob overrides (`--max-batch` etc. apply to
    /// `defaults`), then repeated `--artifact name=path` additions.
    pub fn from_args(args: &cli::Args) -> Result<FleetConfig> {
        let mut fc = match args.get("fleet") {
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading fleet config {path}"))?;
                let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
                FleetConfig::from_json(&j)?
            }
            None => FleetConfig::default(),
        };
        fc.defaults = fc.defaults.override_with(args);
        // CLI knobs outrank the fleet file everywhere: per-model
        // overrides were materialized over the FILE defaults inside
        // from_json, so apply the same CLI flags to them too — a model
        // keeps its own explicit knobs for flags the CLI didn't set
        for m in fc.models.values_mut() {
            if let Some(s) = m.serve.take() {
                m.serve = Some(s.override_with(args));
            }
        }
        for spec in args.get_all("artifact") {
            let (name, path) = parse_artifact_spec(spec)?;
            // a name collision (two --artifact flags, or a flag
            // shadowing a --fleet entry) is an operator typo, never a
            // silent replace — mirror the registry's duplicate rule
            if fc.models.contains_key(&name) {
                bail!("duplicate model name '{name}' (from --artifact {spec})");
            }
            fc.models.insert(name, ModelConfig { artifact: path, serve: None });
        }
        Ok(fc)
    }

    /// Validate every model's effective config.
    pub fn validate(&self) -> Result<()> {
        for name in self.models.keys() {
            self.effective(name)
                .validate()
                .with_context(|| format!("model '{name}'"))?;
        }
        self.defaults.validate()
    }
}

/// Network-edge configuration for `serve --listen`: socket knobs plus
/// the connection-hardening surface (auth, rate limits, drain grace).
/// Parsed from CLI flags; [`NetEdgeConfig::validate`] enforces the
/// auth posture before the listener binds.
#[derive(Debug, Clone, PartialEq)]
pub struct NetEdgeConfig {
    /// `--listen ADDR` (None = no socket tier).
    pub listen: Option<String>,
    /// `--net-threads N` reactor threads (0 = one per core).
    pub net_threads: usize,
    /// `--admission-budget ROWS` shared in-flight row cap (0 = meter
    /// only).
    pub admission_budget: u64,
    /// `--auth-token SECRET`: require this shared secret in a `Hello`
    /// frame before a connection's first request.
    pub auth_token: Option<String>,
    /// `--insecure-no-auth`: explicit opt-out of the non-loopback auth
    /// requirement.
    pub insecure_no_auth: bool,
    /// `--max-conns N` concurrently open connections (0 = no cap).
    pub max_conns: usize,
    /// `--frame-rate-limit N` request frames/second per connection
    /// (0 = off).
    pub frame_rate_limit: u64,
    /// `--row-rate-limit N` rows/second per connection (0 = off).
    pub row_rate_limit: u64,
    /// `--drain-grace-ms MS` advertised in `GoAway` and enforced on
    /// drain.
    pub drain_grace_ms: u32,
}

impl Default for NetEdgeConfig {
    fn default() -> Self {
        NetEdgeConfig {
            listen: None,
            net_threads: 0,
            admission_budget: 0,
            auth_token: None,
            insecure_no_auth: false,
            max_conns: 0,
            frame_rate_limit: 0,
            row_rate_limit: 0,
            drain_grace_ms: 5_000,
        }
    }
}

/// Whether a `--listen` address is reachable beyond the loopback
/// interface. Unresolvable hostnames count as exposed — the safe
/// default for the auth requirement.
pub fn listen_is_exposed(addr: &str) -> bool {
    let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or(addr);
    let host = host.trim_start_matches('[').trim_end_matches(']');
    if host.eq_ignore_ascii_case("localhost") {
        return false;
    }
    match host.parse::<std::net::IpAddr>() {
        Ok(ip) => !ip.is_loopback(),
        Err(_) => true,
    }
}

impl NetEdgeConfig {
    /// Parse the net-edge flags from CLI args.
    pub fn from_args(args: &cli::Args) -> NetEdgeConfig {
        let d = NetEdgeConfig::default();
        NetEdgeConfig {
            listen: args.get("listen").map(str::to_string),
            net_threads: args.get_usize("net-threads", d.net_threads),
            admission_budget: args.get_u64("admission-budget", d.admission_budget),
            auth_token: args.get("auth-token").map(str::to_string),
            insecure_no_auth: args.switch("insecure-no-auth"),
            max_conns: args.get_usize("max-conns", d.max_conns),
            frame_rate_limit: args.get_u64("frame-rate-limit", d.frame_rate_limit),
            row_rate_limit: args.get_u64("row-rate-limit", d.row_rate_limit),
            drain_grace_ms: args.get_u32("drain-grace-ms", d.drain_grace_ms),
        }
    }

    /// A non-loopback bind without an auth token is a config error
    /// unless `--insecure-no-auth` acknowledges the exposure. An empty
    /// `--auth-token` is always an error (it would accept any Hello).
    pub fn validate(&self) -> Result<()> {
        if matches!(self.auth_token.as_deref(), Some("")) {
            bail!("--auth-token must not be empty");
        }
        if let Some(listen) = &self.listen {
            if listen_is_exposed(listen) && self.auth_token.is_none() && !self.insecure_no_auth {
                bail!(
                    "--listen {listen} is reachable beyond loopback; pass --auth-token SECRET \
                     (or --insecure-no-auth to serve unauthenticated anyway)"
                );
            }
        }
        Ok(())
    }
}

/// Top-level run configuration (paths + arch + plan).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub arch: Arch,
    pub weights: PathBuf,
    pub data_dir: PathBuf,
    pub plan: EnginePlan,
    pub serve: ServeConfig,
}

impl RunConfig {
    pub fn defaults(arch: Arch, artifacts: &Path, data_dir: &Path) -> RunConfig {
        RunConfig {
            arch,
            weights: artifacts.join(format!("weights_{}.bin", arch.name())),
            data_dir: data_dir.to_path_buf(),
            plan: EnginePlan::default_for(arch),
            serve: ServeConfig::default(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::str(self.arch.name())),
            ("weights", Json::str(&self.weights.display().to_string())),
            ("data_dir", Json::str(&self.data_dir.display().to_string())),
            ("plan", plan_to_json(&self.plan)),
            ("serve", self.serve.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        reject_unknown_keys(j, "run config", &["arch", "weights", "data_dir", "plan", "serve"])?;
        let arch_s = j
            .get("arch")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("config missing 'arch'"))?;
        let arch = Arch::parse(arch_s).ok_or_else(|| anyhow!("unknown arch '{arch_s}'"))?;
        let plan = match j.get("plan") {
            Some(p) => plan_from_json(p)?,
            None => EnginePlan::default_for(arch),
        };
        let serve = match j.get("serve") {
            Some(s) => ServeConfig::from_json(s)?,
            None => ServeConfig::default(),
        };
        Ok(RunConfig {
            arch,
            weights: PathBuf::from(
                j.get("weights").and_then(Json::as_str).unwrap_or("artifacts/weights.bin"),
            ),
            data_dir: PathBuf::from(
                j.get("data_dir").and_then(Json::as_str).unwrap_or("data/synth"),
            ),
            plan,
            serve,
        })
    }

    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        RunConfig::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing config {}", path.display()))
    }
}

/// Serialize an [`EnginePlan`] to JSON (manual — no serde offline).
pub fn plan_to_json(p: &EnginePlan) -> Json {
    Json::obj(vec![
        (
            "affine",
            Json::Arr(p.affine.iter().map(mode_to_json).collect()),
        ),
        ("fallback", mode_to_json(&p.fallback)),
        ("r_o", Json::num(p.r_o as f64)),
    ])
}

fn mode_to_json(m: &AffineMode) -> Json {
    match *m {
        AffineMode::WholeFixed { bits, m, range_exp } => Json::obj(vec![
            ("mode", Json::str("whole_fixed")),
            ("bits", Json::num(bits as f64)),
            ("m", Json::num(m as f64)),
            ("range_exp", Json::num(range_exp as f64)),
        ]),
        AffineMode::BitplaneFixed { bits, m, range_exp } => Json::obj(vec![
            ("mode", Json::str("bitplane_fixed")),
            ("bits", Json::num(bits as f64)),
            ("m", Json::num(m as f64)),
            ("range_exp", Json::num(range_exp as f64)),
        ]),
        AffineMode::Float { planes, m } => Json::obj(vec![
            ("mode", Json::str("float")),
            ("planes", Json::num(planes as f64)),
            ("m", Json::num(m as f64)),
        ]),
    }
}

fn mode_from_json(j: &Json) -> Result<AffineMode> {
    let mode = j
        .get("mode")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("affine mode missing 'mode'"))?;
    // range-check the numeric fields here so a bad plan file fails
    // with a config error instead of panicking inside the bank
    // constructors (`FixedFormat::new` asserts 1..=16)
    let bits_checked = |j: &Json| -> Result<u32> {
        let bits = get_u64(j, "bits", 8)? as u32;
        if !(1..=16).contains(&bits) {
            bail!("'bits' must be in 1..=16, got {bits}");
        }
        Ok(bits)
    };
    let m_checked = |j: &Json| -> Result<usize> {
        let m = get_usize(j, "m", 1)?;
        if m == 0 {
            bail!("'m' must be >= 1");
        }
        Ok(m)
    };
    Ok(match mode {
        "whole_fixed" => {
            reject_unknown_keys(j, "whole_fixed mode", &["mode", "bits", "m", "range_exp"])?;
            AffineMode::WholeFixed {
                bits: bits_checked(j)?,
                m: m_checked(j)?,
                range_exp: get_i64(j, "range_exp", 0)? as i32,
            }
        }
        "bitplane_fixed" => {
            reject_unknown_keys(j, "bitplane_fixed mode", &["mode", "bits", "m", "range_exp"])?;
            AffineMode::BitplaneFixed {
                bits: bits_checked(j)?,
                m: m_checked(j)?,
                range_exp: get_i64(j, "range_exp", 0)? as i32,
            }
        }
        "float" => {
            reject_unknown_keys(j, "float mode", &["mode", "planes", "m"])?;
            let planes = get_u64(j, "planes", 11)? as u32;
            if !(1..=11).contains(&planes) {
                bail!("'planes' must be in 1..=11, got {planes}");
            }
            AffineMode::Float { planes, m: m_checked(j)? }
        }
        other => bail!("unknown affine mode '{other}'"),
    })
}

pub fn plan_from_json(j: &Json) -> Result<EnginePlan> {
    reject_unknown_keys(j, "engine plan", &["affine", "fallback", "r_o"])?;
    let affine = j
        .get("affine")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("plan missing 'affine' array"))?
        .iter()
        .map(mode_from_json)
        .collect::<Result<Vec<_>>>()?;
    let fallback = match j.get("fallback") {
        Some(f) => mode_from_json(f)?,
        None => AffineMode::Float { planes: 11, m: 1 },
    };
    Ok(EnginePlan { affine, fallback, r_o: get_u64(j, "r_o", 16)? as u32 })
}

/// Strict decoding: a typo'd key is a config error, never a silent
/// fallback to the default (so `max_batc` fails loudly instead of
/// serving with `max_batch = 32`).
fn reject_unknown_keys(j: &Json, ctx: &str, allowed: &[&str]) -> Result<()> {
    if let Json::Obj(m) = j {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "unknown key '{k}' in {ctx} (allowed: {})",
                    allowed.join(", ")
                );
            }
        }
    }
    Ok(())
}

fn get_usize(j: &Json, k: &str, d: usize) -> Result<usize> {
    match j.get(k) {
        None => Ok(d),
        Some(v) => v
            .as_u64()
            .map(|u| u as usize)
            .ok_or_else(|| anyhow!("'{k}' must be a non-negative integer")),
    }
}

fn get_u64(j: &Json, k: &str, d: u64) -> Result<u64> {
    match j.get(k) {
        None => Ok(d),
        Some(v) => v.as_u64().ok_or_else(|| anyhow!("'{k}' must be a non-negative integer")),
    }
}

fn get_i64(j: &Json, k: &str, d: i64) -> Result<i64> {
    match j.get(k) {
        None => Ok(d),
        Some(v) => v.as_i64().ok_or_else(|| anyhow!("'{k}' must be an integer")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_roundtrip() {
        let c = ServeConfig {
            max_batch: 8,
            max_wait_us: 100,
            workers: 2,
            queue_cap: 64,
            deadline_us: 20_000,
            degrade_after: 5,
            admission_weight: 2,
        };
        let j = c.to_json();
        assert_eq!(ServeConfig::from_json(&j).unwrap(), c);
    }

    #[test]
    fn serve_config_validation() {
        let mut c = ServeConfig::default();
        c.validate().unwrap();
        c.max_batch = 0;
        assert!(c.validate().is_err());
        c = ServeConfig { queue_cap: 1, max_batch: 8, ..ServeConfig::default() };
        assert!(c.validate().is_err());
        // a deadline tighter than the batching wait sheds everything
        c = ServeConfig { max_wait_us: 500, deadline_us: 400, ..ServeConfig::default() };
        assert!(c.validate().is_err());
        c = ServeConfig { max_wait_us: 500, deadline_us: 5_000, ..ServeConfig::default() };
        c.validate().unwrap();
    }

    #[test]
    fn serve_config_new_knobs_cli_and_json() {
        let args = cli::Args::parse(
            ["--deadline-us", "30000", "--degrade-after", "2"].iter().map(|s| s.to_string()),
        );
        let c = ServeConfig::default().override_with(&args);
        assert_eq!(c.deadline_us, 30_000);
        assert_eq!(c.degrade_after, 2);
        // unspecified keys inherit the base (here: the default 0 / 3)
        let j = Json::parse(r#"{"deadline_us": 1000}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.deadline_us, 1000);
        assert_eq!(c.degrade_after, ServeConfig::default().degrade_after);
    }

    #[test]
    fn fleet_config_roundtrip_and_overrides() {
        let mut fc = FleetConfig::default();
        fc.defaults.max_batch = 16;
        fc.models.insert(
            "digits".to_string(),
            ModelConfig { artifact: PathBuf::from("d.ltm"), serve: None },
        );
        fc.models.insert(
            "fashion".to_string(),
            ModelConfig {
                artifact: PathBuf::from("f.ltm"),
                serve: Some(ServeConfig { max_batch: 4, ..ServeConfig::default() }),
            },
        );
        let text = fc.to_json().to_string_pretty();
        let back = FleetConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, fc);
        // per-model override wins; others inherit defaults
        assert_eq!(back.effective("fashion").max_batch, 4);
        assert_eq!(back.effective("digits").max_batch, 16);
        assert_eq!(back.effective("unknown").max_batch, 16);
        back.validate().unwrap();
    }

    #[test]
    fn fleet_config_rejects_unknown_and_malformed_keys() {
        for bad in [
            r#"{"default": {}}"#,
            r#"{"models": {"m": {"artifcat": "x.ltm"}}}"#,
            r#"{"models": {"m": {}}}"#,
            r#"{"models": {"m": {"artifact": "x.ltm", "serve": {"max_batc": 3}}}}"#,
            r#"{"models": [1, 2]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(FleetConfig::from_json(&j).is_err(), "accepted: {bad}");
        }
        let ok = Json::parse(r#"{"models": {"m": {"artifact": "x.ltm"}}}"#).unwrap();
        assert!(FleetConfig::from_json(&ok).is_ok());
    }

    #[test]
    fn fleet_from_repeated_artifact_flags() {
        let args = cli::Args::parse(
            ["--artifact", "digits=d.ltm", "--artifact", "path/to/fashion.ltm",
             "--max-batch", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        let fc = FleetConfig::from_args(&args).unwrap();
        assert_eq!(fc.models.len(), 2);
        assert_eq!(fc.models["digits"].artifact, PathBuf::from("d.ltm"));
        // bare path: model name = file stem
        assert_eq!(fc.models["fashion"].artifact, PathBuf::from("path/to/fashion.ltm"));
        assert_eq!(fc.defaults.max_batch, 8);
    }

    #[test]
    fn partial_model_override_inherits_fleet_defaults() {
        // only 'workers' is overridden; the rest must come from the
        // fleet defaults (max_batch 64), NOT ServeConfig::default()
        let j = Json::parse(
            r#"{"defaults": {"max_batch": 64},
                "models": {"m": {"artifact": "m.ltm", "serve": {"workers": 2}}}}"#,
        )
        .unwrap();
        let fc = FleetConfig::from_json(&j).unwrap();
        let eff = fc.effective("m");
        assert_eq!(eff.workers, 2);
        assert_eq!(eff.max_batch, 64, "override must inherit fleet defaults");
        assert_eq!(eff.queue_cap, ServeConfig::default().queue_cap);
    }

    #[test]
    fn cli_knobs_outrank_fleet_file_for_overridden_models_too() {
        // a model with a partial per-model override must still see CLI
        // flags (CLI > per-model > file defaults), keeping its own
        // explicit knobs for flags the CLI did not set
        let dir = std::env::temp_dir().join("tablenet_fleet_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.json");
        std::fs::write(
            &path,
            r#"{"defaults": {"max_batch": 64},
                "models": {"m": {"artifact": "m.ltm", "serve": {"workers": 2}}}}"#,
        )
        .unwrap();
        let args = cli::Args::parse(
            ["--fleet", path.to_str().unwrap(), "--max-batch", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        let fc = FleetConfig::from_args(&args).unwrap();
        assert_eq!(fc.defaults.max_batch, 8);
        let eff = fc.effective("m");
        assert_eq!(eff.max_batch, 8, "CLI flag must reach overridden models");
        assert_eq!(eff.workers, 2, "model keeps its own explicit knobs");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_artifact_names_are_rejected() {
        let args = cli::Args::parse(
            ["--artifact", "a=old.ltm", "--artifact", "a=new.ltm"]
                .iter()
                .map(|s| s.to_string()),
        );
        let e = FleetConfig::from_args(&args).unwrap_err();
        assert!(format!("{e}").contains("duplicate model name 'a'"), "{e}");
    }

    #[test]
    fn artifact_spec_parsing() {
        assert_eq!(
            parse_artifact_spec("a=m.ltm").unwrap(),
            ("a".to_string(), PathBuf::from("m.ltm"))
        );
        assert_eq!(
            parse_artifact_spec("dir/model_linear.ltm").unwrap(),
            ("model_linear".to_string(), PathBuf::from("dir/model_linear.ltm"))
        );
        assert!(parse_artifact_spec("=x").is_err());
        assert!(parse_artifact_spec("a=").is_err());
    }

    #[test]
    fn net_edge_auth_posture_is_enforced() {
        let parse = |s: &str| {
            cli::Args::parse_with_switches(
                s.split_whitespace().map(String::from),
                cli::Args::SWITCHES,
            )
        };
        // loopback binds never require auth
        for addr in ["127.0.0.1:0", "localhost:9000", "[::1]:9000"] {
            let c = NetEdgeConfig::from_args(&parse(&format!("--listen {addr}")));
            c.validate().unwrap();
            assert!(!listen_is_exposed(addr), "{addr}");
        }
        // exposed binds require a token…
        for addr in ["0.0.0.0:9000", "10.1.2.3:9000", "myhost:9000", "[::]:9000"] {
            assert!(listen_is_exposed(addr), "{addr}");
            let c = NetEdgeConfig::from_args(&parse(&format!("--listen {addr}")));
            let e = c.validate().unwrap_err();
            assert!(format!("{e}").contains("auth-token"), "{e}");
            // …which a token satisfies
            let c = NetEdgeConfig::from_args(&parse(&format!("--listen {addr} --auth-token s3")));
            c.validate().unwrap();
            // …as does the explicit insecure opt-out
            let c = NetEdgeConfig::from_args(&parse(&format!("--listen {addr} --insecure-no-auth")));
            assert!(c.insecure_no_auth);
            c.validate().unwrap();
        }
        // an empty token would match any Hello: rejected everywhere
        let c = NetEdgeConfig {
            auth_token: Some(String::new()),
            ..NetEdgeConfig::default()
        };
        assert!(c.validate().is_err());
        // no --listen: nothing to police
        NetEdgeConfig::default().validate().unwrap();
    }

    #[test]
    fn net_edge_flags_parse() {
        let args = cli::Args::parse_with_switches(
            "--listen 127.0.0.1:0 --net-threads 2 --admission-budget 64 --auth-token hunter2 \
             --max-conns 8 --frame-rate-limit 100 --row-rate-limit 4000 --drain-grace-ms 250"
                .split_whitespace()
                .map(String::from),
            cli::Args::SWITCHES,
        );
        let c = NetEdgeConfig::from_args(&args);
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(c.net_threads, 2);
        assert_eq!(c.admission_budget, 64);
        assert_eq!(c.auth_token.as_deref(), Some("hunter2"));
        assert_eq!(c.max_conns, 8);
        assert_eq!(c.frame_rate_limit, 100);
        assert_eq!(c.row_rate_limit, 4000);
        assert_eq!(c.drain_grace_ms, 250);
        c.validate().unwrap();
    }

    #[test]
    fn plan_roundtrip_all_modes() {
        for plan in [
            EnginePlan::linear_default(),
            EnginePlan::mlp_default(),
            EnginePlan::cnn_default(),
        ] {
            let j = plan_to_json(&plan);
            let text = j.to_string_pretty();
            let back = plan_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, plan);
        }
    }

    #[test]
    fn run_config_roundtrip() {
        let rc = RunConfig::defaults(
            Arch::Mlp,
            Path::new("artifacts"),
            Path::new("data/synth"),
        );
        let j = rc.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.arch, Arch::Mlp);
        assert_eq!(back.plan, rc.plan);
        assert_eq!(back.weights, rc.weights);
    }

    #[test]
    fn cli_overrides_apply() {
        let args = cli::Args::parse(
            ["--max-batch", "4", "--workers", "3"].iter().map(|s| s.to_string()),
        );
        let c = ServeConfig::default().override_with(&args);
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.workers, 3);
        assert_eq!(c.queue_cap, ServeConfig::default().queue_cap);
    }

    #[test]
    fn bad_configs_error_cleanly() {
        assert!(RunConfig::from_json(&Json::parse("{}").unwrap()).is_err());
        let j = Json::parse(r#"{"arch": "warp"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"arch":"mlp","serve":{"max_batch":-2}}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn unknown_keys_are_rejected_not_ignored() {
        // typo'd serve key
        let j = Json::parse(r#"{"max_batc": 4}"#).unwrap();
        let e = ServeConfig::from_json(&j).unwrap_err();
        assert!(format!("{e}").contains("max_batc"), "{e}");
        // typo'd run-config key
        let j = Json::parse(r#"{"arch":"mlp","wieghts":"w.bin"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
        // typo'd plan key
        let j = Json::parse(r#"{"affine": [], "ro": 16}"#).unwrap();
        assert!(plan_from_json(&j).is_err());
        // typo'd mode key
        let j = Json::parse(r#"{"affine": [{"mode":"float","planez":3}], "r_o": 16}"#)
            .unwrap();
        let e = plan_from_json(&j).unwrap_err();
        assert!(format!("{e}").contains("planez"), "{e}");
        // well-formed configs still decode
        let j = Json::parse(r#"{"max_batch": 4}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().max_batch, 4);
    }

    #[test]
    fn out_of_range_mode_fields_are_rejected() {
        for bad in [
            r#"{"affine": [{"mode":"float","planes":0}], "r_o": 16}"#,
            r#"{"affine": [{"mode":"float","planes":12}], "r_o": 16}"#,
            r#"{"affine": [{"mode":"bitplane_fixed","bits":0}], "r_o": 16}"#,
            r#"{"affine": [{"mode":"whole_fixed","bits":17}], "r_o": 16}"#,
            r#"{"affine": [{"mode":"float","planes":11,"m":0}], "r_o": 16}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(plan_from_json(&j).is_err(), "accepted: {bad}");
        }
        let ok = Json::parse(
            r#"{"affine": [{"mode":"float","planes":11,"m":1}], "r_o": 16}"#,
        )
        .unwrap();
        assert!(plan_from_json(&ok).is_ok());
    }
}
