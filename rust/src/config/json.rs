//! Minimal JSON codec (parse + pretty-print). The offline vendored crate
//! set has no serde/serde_json, so the config system carries its own
//! implementation — complete enough for config files, plan files and
//! metrics dumps: objects, arrays, strings with escapes, numbers, bools,
//! null; rejects trailing garbage and malformed input with positions.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- constructors ----------------------------------------------------
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    // -- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|v| v.fract() == 0.0).map(|v| v as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Borrow the key/value map, if this is an object (the multi-model
    /// fleet config iterates model entries by name this way).
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed getter with a path-style error message.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError {
            pos: 0,
            msg: format!("missing key '{key}'"),
        })
    }

    // -- serialisation ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let j = Json::obj(vec![
            ("name", Json::str("tablenet")),
            ("bits", Json::arr(vec![Json::num(1), Json::num(2)])),
            ("nested", Json::obj(vec![("x", Json::Bool(false))])),
        ]);
        for s in [j.to_string(), j.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), j);
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
        let esc = Json::parse("\"\\u2603\"").unwrap();
        assert_eq!(esc.as_str(), Some("☃"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn error_reports_position() {
        let e = Json::parse("[1, @]").unwrap_err();
        assert_eq!(e.pos, 4);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::num(42).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn as_u64_rejects_fractional() {
        assert_eq!(Json::num(1.5).as_u64(), None);
        assert_eq!(Json::num(-3).as_u64(), None);
        assert_eq!(Json::num(7).as_u64(), Some(7));
    }
}
