//! Tiny CLI argument parser (the vendored crate set has no clap):
//! `--flag value`, `--flag=value`, boolean `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Every `--key value` occurrence in order — lets flags repeat
    /// (`--artifact a=x.ltm --artifact b=y.ltm`); `flags` keeps the
    /// last occurrence for the scalar getters.
    repeats: Vec<(String, String)>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    /// A bare `--flag` consumes the next token as its value unless the
    /// flag is listed in `switches` (pure booleans) or the next token is
    /// another flag. Use [`Args::parse`] when no switches are needed.
    pub fn parse_with_switches<I: IntoIterator<Item = String>>(
        args: I,
        switches: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.repeats.push((k.to_string(), v.to_string()));
                } else if switches.contains(&rest) {
                    out.bools.push(rest.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v.clone());
                    out.repeats.push((rest.to_string(), v));
                } else {
                    out.bools.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse with no declared boolean switches.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        Args::parse_with_switches(args, &[])
    }

    /// Boolean switch names used across the `tablenet` CLI.
    pub const SWITCHES: &'static [&'static str] = &[
        "verbose",
        "dry-run",
        "help",
        "version",
        "no-ref",
        "no-fuse",
        "csv",
        "quiet",
        "drain",
        "insecure-no-auth",
        "watch-retire-on-delete",
    ];

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Args {
        Args::parse_with_switches(std::env::args().skip(1), Self::SWITCHES)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Every value of a repeated `--key value` flag, in argv order.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.repeats
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// True boolean switch only (ignores key=value flags).
    pub fn switch(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("serve --port 8080 --arch linear data.bin");
        assert_eq!(a.positional, vec!["serve", "data.bin"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("arch"), Some("linear"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--bits=3 --m=14");
        assert_eq!(a.get_u32("bits", 0), 3);
        assert_eq!(a.get_usize("m", 0), 14);
    }

    #[test]
    fn boolean_switches() {
        let a = Args::parse_with_switches(
            "--verbose run --dry-run".split_whitespace().map(String::from),
            &["verbose", "dry-run"],
        );
        assert!(a.switch("verbose"));
        assert!(a.switch("dry-run"));
        assert!(!a.switch("quiet"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn undeclared_flag_eats_next_token() {
        let a = parse("--out file.txt");
        assert_eq!(a.get("out"), Some("file.txt"));
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("cmd --flag");
        assert!(a.switch("flag"));
    }

    #[test]
    fn repeated_flags_accumulate_in_order() {
        let a = parse("serve --artifact digits=d.ltm --artifact fashion=f.ltm");
        assert_eq!(a.get_all("artifact"), vec!["digits=d.ltm", "fashion=f.ltm"]);
        // scalar getter sees the last occurrence
        assert_eq!(a.get("artifact"), Some("fashion=f.ltm"));
        // equals form mixes with space form
        let a = parse("--artifact=x.ltm --artifact y.ltm");
        assert_eq!(a.get_all("artifact"), vec!["x.ltm", "y.ltm"]);
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn defaults_kick_in() {
        let a = parse("cmd");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "x"), "x");
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
    }

    #[test]
    fn flag_value_looks_positional() {
        // --out file.txt: file.txt is consumed as the value
        let a = parse("--out file.txt rest");
        assert_eq!(a.get("out"), Some("file.txt"));
        assert_eq!(a.positional, vec!["rest"]);
    }
}
