//! Dynamic batching policy: collect requests until the batch is full or
//! the oldest request has waited `max_wait` — the standard
//! latency/throughput knob of serving systems (vLLM-style), applied to
//! the LUT engine.
//!
//! The policy is a pure function over a channel receiver so it can be
//! tested deterministically without the full coordinator.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batch formation parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait_us: u64) -> Self {
        BatchPolicy { max_batch, max_wait: Duration::from_micros(max_wait_us) }
    }

    /// The policy a [`crate::config::ServeConfig`] describes — each
    /// registered model runs its own policy (per-model batching knobs).
    pub fn from_cfg(cfg: &crate::config::ServeConfig) -> Self {
        BatchPolicy::new(cfg.max_batch, cfg.max_wait_us)
    }
}

/// Collect the next batch from `rx`. Blocks for the first item; then
/// keeps accepting until `max_batch` items are queued or `max_wait` has
/// elapsed since the first item arrived. Returns `None` when the channel
/// is closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn fills_to_max_batch_when_queue_is_hot() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = next_batch(&rx, BatchPolicy::new(4, 10_000)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, BatchPolicy::new(4, 10_000)).unwrap();
        assert_eq!(b2, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let start = Instant::now();
        let b = next_batch(&rx, BatchPolicy::new(64, 2_000)).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(start.elapsed() >= Duration::from_micros(1_500));
    }

    #[test]
    fn preserves_fifo_order() {
        let (tx, rx) = mpsc::channel();
        let producer = thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let mut seen = Vec::new();
        while let Some(b) = next_batch(&rx, BatchPolicy::new(7, 500)) {
            seen.extend(b);
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn returns_none_on_closed_empty_channel() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::new(4, 100)).is_none());
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = mpsc::channel();
        tx.send(9).unwrap();
        drop(tx);
        let b = next_batch(&rx, BatchPolicy::new(4, 100)).unwrap();
        assert_eq!(b, vec![9]);
        assert!(next_batch(&rx, BatchPolicy::new(4, 100)).is_none());
    }

    #[test]
    fn batch_never_exceeds_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..1000 {
            tx.send(i).unwrap();
        }
        drop(tx);
        while let Some(b) = next_batch(&rx, BatchPolicy::new(13, 1_000)) {
            assert!(b.len() <= 13);
        }
    }
}
