//! Deterministic fault injection for the serving runtime.
//!
//! A seeded [`FaultPlan`] describes which faults to inject and how
//! often; a [`FaultInjector`] turns it into reproducible per-batch
//! decisions (a counter-indexed splitmix64 stream, so two runs with the
//! same seed inject the exact same fault sequence regardless of thread
//! timing of everything else). Faults supported:
//!
//! * **latency** — a batch sleeps `latency_us` before executing,
//!   exercising deadline shedding and, under sustained load, queue
//!   pressure (the bounded request queue fills and admission control
//!   sheds with `QueueFull`);
//! * **worker panics** — a batch panics inside the worker's
//!   `catch_unwind` perimeter, exercising panic isolation, the
//!   deterministic `WorkerPanicked` fail path and Degraded marking;
//! * **artifact corruption** — [`FaultInjector::corrupt`] flips a
//!   deterministic payload byte in an artifact image so swap / watch-dir
//!   paths can rehearse checksum rejection and rollback.
//!
//! The hook is zero-cost when disabled: the coordinator holds an
//! `Option<Arc<FaultInjector>>` and the hot path pays one `None` check.
//! Injected panics carry the [`InjectedPanic`] marker payload;
//! [`silence_injected_panics`] keeps them out of stderr while leaving
//! every real panic's report intact.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Panic payload marker for injected worker panics, so panic hooks and
/// tests can tell rehearsed faults from real bugs.
pub struct InjectedPanic;

/// What to inject, how often, and under which seed. Probabilities are
/// per batch execution in `[0, 1]`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a batch sleeps before executing.
    pub latency_prob: f64,
    /// Injected sleep length (µs).
    pub latency_us: u64,
    /// Probability a batch panics inside the worker.
    pub panic_prob: f64,
}

impl FaultPlan {
    /// Parse a `key=value,key=value` spec, e.g.
    /// `seed=7,latency_prob=0.05,latency_us=2000,panic_prob=0.02`.
    /// Unknown keys and out-of-range probabilities are errors, not
    /// silently ignored knobs.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((k, v)) = part.split_once('=') else {
                bail!("bad fault-plan entry '{part}' (want key=value)");
            };
            let prob = |v: &str| -> Result<f64> {
                let p: f64 = v.parse().map_err(|_| {
                    anyhow::anyhow!("fault-plan '{k}' must be a number, got '{v}'")
                })?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("fault-plan '{k}' must be in [0, 1], got {p}");
                }
                Ok(p)
            };
            match k {
                "seed" => plan.seed = v.parse()?,
                "latency_prob" => plan.latency_prob = prob(v)?,
                "latency_us" => plan.latency_us = v.parse()?,
                "panic_prob" => plan.panic_prob = prob(v)?,
                other => bail!(
                    "unknown fault-plan key '{other}' \
                     (allowed: seed, latency_prob, latency_us, panic_prob)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing.
    pub fn is_noop(&self) -> bool {
        self.latency_prob == 0.0 && self.panic_prob == 0.0
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "seed={} latency {:.1}% x {}µs, panic {:.1}%",
            self.seed,
            self.latency_prob * 100.0,
            self.latency_us,
            self.panic_prob * 100.0
        )
    }
}

/// Shared, thread-safe decision stream over a [`FaultPlan`]. One
/// injector serves every pipeline of a registry; each decision consumes
/// one counter slot, so the full fault sequence is a pure function of
/// `(seed, decision index)`.
pub struct FaultInjector {
    plan: FaultPlan,
    calls: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector { plan, calls: AtomicU64::new(0) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decisions drawn so far (each batch consumes up to two).
    pub fn decisions(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Next uniform draw in `[0, 1)` — splitmix64 over the seed and a
    /// global decision counter.
    fn roll(&self) -> f64 {
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        let mut z = self
            .plan
            .seed
            .wrapping_add(n.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Per-batch hook, called by a worker INSIDE its `catch_unwind`
    /// perimeter: maybe sleep (latency fault), maybe panic (isolation
    /// fault). The panic carries [`InjectedPanic`] so hooks can tell it
    /// apart from real bugs.
    pub fn perturb_batch(&self) {
        if self.plan.latency_prob > 0.0 && self.roll() < self.plan.latency_prob {
            std::thread::sleep(Duration::from_micros(self.plan.latency_us));
        }
        if self.plan.panic_prob > 0.0 && self.roll() < self.plan.panic_prob {
            std::panic::panic_any(InjectedPanic);
        }
    }

    /// Deterministically corrupt one artifact payload byte (never the
    /// first 64 header bytes, so the file still parses far enough to
    /// reach per-stage checksum validation — the failure mode a torn
    /// deploy produces).
    pub fn corrupt(bytes: &mut [u8], seed: u64) {
        if bytes.len() <= 64 {
            if let Some(b) = bytes.last_mut() {
                *b ^= 0xA5;
            }
            return;
        }
        let span = bytes.len() - 64;
        let idx = 64 + (seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) % span as u64) as usize;
        bytes[idx] ^= 0xA5;
    }
}

/// Install a panic hook that swallows [`InjectedPanic`] reports (the
/// rehearsed faults are caught and accounted by the workers; their
/// default-hook stack traces would drown real diagnostics) while
/// forwarding every other panic to the previous hook. Idempotent enough
/// for tests: chaining twice still forwards real panics.
pub fn silence_injected_panics() {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        if info.payload().is::<InjectedPanic>() {
            return;
        }
        prev(info);
    }));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_and_defaults() {
        let p = FaultPlan::parse("seed=7,latency_prob=0.25,latency_us=2000,panic_prob=0.5")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.latency_us, 2000);
        assert!((p.latency_prob - 0.25).abs() < 1e-12);
        assert!(!p.is_noop());
        // empty spec = noop plan
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_noop());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("latency_prob=1.5").is_err());
        assert!(FaultPlan::parse("panic_prob=-0.1").is_err());
        assert!(FaultPlan::parse("panci_prob=0.1").is_err());
        assert!(FaultPlan::parse("seed").is_err());
        assert!(FaultPlan::parse("latency_prob=x").is_err());
    }

    #[test]
    fn decision_stream_is_deterministic_and_uniformish() {
        let a = FaultInjector::new(FaultPlan { seed: 42, ..Default::default() });
        let b = FaultInjector::new(FaultPlan { seed: 42, ..Default::default() });
        let xs: Vec<f64> = (0..64).map(|_| a.roll()).collect();
        let ys: Vec<f64> = (0..64).map(|_| b.roll()).collect();
        assert_eq!(xs, ys, "same seed must replay the same stream");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((0.3..0.7).contains(&mean), "suspicious draw stream, mean {mean}");
        let c = FaultInjector::new(FaultPlan { seed: 43, ..Default::default() });
        assert_ne!(xs[0], c.roll(), "different seeds must diverge");
    }

    #[test]
    fn injected_panic_is_catchable_and_typed() {
        let inj =
            FaultInjector::new(FaultPlan { panic_prob: 1.0, seed: 1, ..Default::default() });
        let err = std::panic::catch_unwind(|| inj.perturb_batch())
            .expect_err("panic_prob=1 must panic");
        assert!(err.is::<InjectedPanic>());
        assert_eq!(inj.decisions(), 1);
    }

    #[test]
    fn corrupt_flips_exactly_one_byte_past_the_header() {
        let clean: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        let mut dirty = clean.clone();
        FaultInjector::corrupt(&mut dirty, 9);
        let diffs: Vec<usize> =
            (0..clean.len()).filter(|&i| clean[i] != dirty[i]).collect();
        assert_eq!(diffs.len(), 1);
        assert!(diffs[0] >= 64, "header bytes must stay intact");
        // deterministic: the same seed flips the same byte
        let mut again = clean.clone();
        FaultInjector::corrupt(&mut again, 9);
        assert_eq!(dirty, again);
    }
}
