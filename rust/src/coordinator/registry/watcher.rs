//! Deploy watcher: config-free rolling deploys from a directory of
//! `.ltm` artifacts.
//!
//! Point a [`DirWatcher`] at a directory and the fleet follows the
//! filesystem: a new `model.ltm` is auto-registered under its stem
//! (`model`), and overwriting a file whose *content* changed hot-swaps
//! that model through the registry's atomic
//! [`Coordinator::swap`](crate::coordinator::Coordinator::swap) — the
//! same versioned `BackendSlot` path `--swap` uses, so in-flight
//! batches finish on the old version, later batches take the new one,
//! and no request is lost. Combined with the mmap-borrowing v2 artifact
//! loader, dropping a large bank into the watch dir deploys it at disk
//! streaming speed: the load verifies checksums in one sequential scan
//! and borrows every arena in place — no decode, no allocation, no
//! memcpy of table payloads.
//!
//! Change detection is two-tier and never re-reads table payloads:
//! `(mtime, len)` gates a cheap re-check, and the artifact's own stored
//! checksum ([`artifact::content_fingerprint`], O(header)) decides
//! whether content actually changed — a bare `touch` does not redeploy.
//! A file that fails to parse is reported once ([`WatchEvent::Failed`])
//! and retried with **capped exponential backoff** (see
//! [`WatcherOptions::retry_base`]): the first retry after ~500ms, then
//! doubling up to a 30s cap, so a permanently-bad artifact costs a few
//! load attempts per minute instead of one per poll, while an artifact
//! healed in place (same stat, fixed bytes) deploys on the next retry
//! without waiting for an mtime change. Repeat failures with the SAME
//! error stay silent; the error is re-reported when it changes.
//!
//! Watcher-driven swaps are **quarantined**
//! ([`ModelRegistry::swap_quarantined`]): the candidate must survive a
//! golden batch before the version bump, and a rejected candidate
//! leaves the incumbent serving.
//!
//! **Manifest sidecars:** a `model.ltm.json` file next to `model.ltm`
//! pins that stem's [`ServeConfig`] (batch policy, deadline, degrade
//! threshold, admission weight), strictly decoded over the watcher's
//! base config — a typo'd key, malformed JSON or invalid combination
//! is a [`WatchEvent::Failed`] and the pair **fails closed**: nothing
//! deploys under default config by accident, and an incumbent keeps
//! serving its existing config. A sidecar-only change re-registers the
//! model under the new config ([`WatchEvent::Reconfigured`]) — batch
//! policy cannot change under a live coordinator, so this is the one
//! watcher path with a brief routing gap (retire + register) rather
//! than an atomic swap.
//!
//! **Replacing a live model must be an atomic rename** (copy to a temp
//! name — anything not `*.ltm` is ignored — then `mv` over the stem):
//! the previous version serves zero-copy from a mapping of the OLD
//! inode, and an in-place overwrite would truncate/mutate the file
//! under that mapping (SIGBUS / torn tables on request threads).
//! Rename swaps the directory entry without touching the serving
//! inode. By default deleting a file does NOT retire its model: the
//! mapped artifact keeps serving (the mapping outlives the directory
//! entry), matching the standard rolling-deploy contract; retire
//! explicitly via [`ModelRegistry::retire`]. Opt in to delete-driven
//! retirement with [`WatcherOptions::retire_on_delete`]
//! (`--watch-retire-on-delete`): a watched stem whose file vanishes is
//! retired ([`WatchEvent::Retired`]), and re-adding the file later
//! re-registers it fresh (version restarts at 1).

use super::{ModelRegistry, RegistryError};
use crate::config::json::Json;
use crate::config::ServeConfig;
use crate::coordinator::Backend;
use crate::engine::{artifact, LutModel};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime};

/// One observed deploy action (or failure) from a directory scan.
#[derive(Debug, Clone)]
pub enum WatchEvent {
    /// A new stem appeared and is now served under `name`.
    Registered {
        name: String,
        path: PathBuf,
        /// Input features of the deployed pipeline (for request
        /// synthesis / admission checks).
        features: Option<usize>,
        /// Every table bank borrows its arena from the mapped artifact
        /// (the v2 zero-copy fast path); false = at least one owned
        /// copy (v1 artifact, non-unix, or misaligned block).
        zero_copy: bool,
    },
    /// An existing model's file content changed; the registry installed
    /// the new backend as `version`.
    Swapped { name: String, path: PathBuf, version: u64, features: Option<usize>, zero_copy: bool },
    /// The stem's `.ltm.json` sidecar pinned a different
    /// [`ServeConfig`]: the model was re-registered under it (retire +
    /// register — a brief routing gap, since batch policy cannot change
    /// under a live coordinator; the version counter restarts at 1).
    Reconfigured { name: String, path: PathBuf },
    /// A file could not be fingerprinted, parsed, or deployed. Reported
    /// once per content state; the file is retried after it changes.
    Failed { path: PathBuf, error: String },
    /// A watched stem's file was deleted and
    /// [`WatcherOptions::retire_on_delete`] is on: the model was
    /// retired from the registry.
    Retired { name: String },
}

impl std::fmt::Display for WatchEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WatchEvent::Registered { name, path, zero_copy, .. } => write!(
                f,
                "registered model '{name}' from {} ({})",
                path.display(),
                if *zero_copy { "zero-copy" } else { "copied" }
            ),
            WatchEvent::Swapped { name, path, version, zero_copy, .. } => write!(
                f,
                "swapped model '{name}' -> v{version} from {} ({})",
                path.display(),
                if *zero_copy { "zero-copy" } else { "copied" }
            ),
            WatchEvent::Reconfigured { name, path } => {
                write!(f, "reconfigured model '{name}' per {}.json", path.display())
            }
            WatchEvent::Failed { path, error } => {
                write!(f, "watch: {} rejected: {error}", path.display())
            }
            WatchEvent::Retired { name } => {
                write!(f, "retired model '{name}' (watched file deleted)")
            }
        }
    }
}

/// Watcher configuration.
#[derive(Debug, Clone)]
pub struct WatcherOptions {
    /// Batching/worker config for models the watcher registers (swaps
    /// keep the target model's existing pipeline config).
    pub serve_cfg: ServeConfig,
    /// Directory poll interval.
    pub poll: Duration,
    /// First retry delay after a file fails to deploy; each consecutive
    /// failure doubles it.
    pub retry_base: Duration,
    /// Ceiling for the doubled retry delay.
    pub retry_cap: Duration,
    /// Retire a model when its watched `.ltm` file is deleted (off by
    /// default: the standard rolling-deploy contract keeps a mapped
    /// artifact serving after its directory entry vanishes).
    pub retire_on_delete: bool,
}

impl Default for WatcherOptions {
    fn default() -> Self {
        WatcherOptions {
            serve_cfg: ServeConfig::default(),
            poll: Duration::from_millis(200),
            retry_base: Duration::from_millis(500),
            retry_cap: Duration::from_secs(30),
            retire_on_delete: false,
        }
    }
}

/// Last deployed (or rejected) state of one watched stem.
struct FileState {
    mtime: Option<SystemTime>,
    len: u64,
    /// `(mtime, len)` of the `.ltm.json` sidecar; `None` = no sidecar.
    /// A sidecar appearing, vanishing, or changing stat re-checks the
    /// pair just like an artifact stat change does.
    sidecar: Option<(Option<SystemTime>, u64)>,
    /// Content fingerprint of the deployed artifact; `None` while the
    /// current file content is known-bad (parse/deploy failure).
    fingerprint: Option<u64>,
    /// Consecutive deploy failures of this stem (0 once deployed).
    failures: u32,
    /// Next retry of a known-bad file (capped exponential backoff);
    /// `None` once deployed.
    retry_at: Option<Instant>,
    /// Error of the last failed attempt; repeat failures with the same
    /// error are retried silently, a changed error is re-reported.
    last_error: Option<String>,
}

impl FileState {
    fn deployed(
        mtime: Option<SystemTime>,
        len: u64,
        sidecar: Option<(Option<SystemTime>, u64)>,
        fingerprint: u64,
    ) -> FileState {
        FileState {
            mtime,
            len,
            sidecar,
            fingerprint: Some(fingerprint),
            failures: 0,
            retry_at: None,
            last_error: None,
        }
    }
}

/// `model.ltm` -> `model.ltm.json`: appended, not substituted, so the
/// sidecar never collides with another stem's artifact and sorts next
/// to its model in listings.
fn sidecar_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".json");
    PathBuf::from(os)
}

/// Strictly decode a `.ltm.json` sidecar over the watcher's base
/// config. Any unknown key, malformed JSON, or invalid combination is
/// an error — never a silent fall-back to defaults.
fn read_sidecar(path: &Path, base: &ServeConfig) -> Result<ServeConfig, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("sidecar {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| format!("sidecar {}: {e}", path.display()))?;
    let cfg = ServeConfig::from_json_over(&j, base)
        .map_err(|e| format!("sidecar {}: {e:#}", path.display()))?;
    cfg.validate().map_err(|e| format!("sidecar {}: {e:#}", path.display()))?;
    Ok(cfg)
}

/// The synchronous scan engine behind [`DirWatcher`]: one call = one
/// directory pass. Split out so deploy logic is testable without
/// threads and embeddable in other control loops.
pub struct DirScanner {
    dir: PathBuf,
    cfg: ServeConfig,
    seen: BTreeMap<String, FileState>,
    /// Last directory-level read error, reported once (not once per
    /// poll) until the directory becomes readable again.
    dir_error: Option<String>,
    retry_base: Duration,
    retry_cap: Duration,
    retries: u64,
    retire_on_delete: bool,
}

impl DirScanner {
    pub fn new(dir: impl Into<PathBuf>, cfg: ServeConfig) -> DirScanner {
        DirScanner {
            dir: dir.into(),
            cfg,
            seen: BTreeMap::new(),
            dir_error: None,
            retry_base: Duration::from_millis(500),
            retry_cap: Duration::from_secs(30),
            retries: 0,
            retire_on_delete: false,
        }
    }

    /// Override the failure-retry backoff (first delay `base`, doubling
    /// per consecutive failure up to `cap`).
    pub fn with_backoff(mut self, base: Duration, cap: Duration) -> DirScanner {
        self.retry_base = base;
        self.retry_cap = cap;
        self
    }

    /// Retire a model when its watched file vanishes (see
    /// [`WatcherOptions::retire_on_delete`]).
    pub fn with_retire_on_delete(mut self, on: bool) -> DirScanner {
        self.retire_on_delete = on;
        self
    }

    /// Backoff-driven re-attempts of known-bad files so far.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    fn backoff(&self, failures: u32) -> Duration {
        let exp = failures.saturating_sub(1).min(16);
        (self.retry_base * 2u32.pow(exp)).min(self.retry_cap)
    }

    /// One directory pass: register new `.ltm` stems, swap changed
    /// ones, report failures. Returns the events of this pass (empty =
    /// nothing changed).
    pub fn scan(&mut self, registry: &ModelRegistry) -> Vec<WatchEvent> {
        let mut events = Vec::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => {
                self.dir_error = None;
                e
            }
            Err(e) => {
                let error = format!("reading watch dir: {e}");
                if self.dir_error.as_ref() != Some(&error) {
                    self.dir_error = Some(error.clone());
                    events.push(WatchEvent::Failed { path: self.dir.clone(), error });
                }
                return events;
            }
        };
        let mut present: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("ltm") {
                continue;
            }
            let Some(name) = path.file_stem().and_then(|s| s.to_str()).map(str::to_string)
            else {
                continue;
            };
            let meta = match entry.metadata() {
                Ok(m) if m.is_file() => m,
                _ => continue,
            };
            present.insert(name.clone());
            let mtime = meta.modified().ok();
            let len = meta.len();
            let spath = sidecar_path(&path);
            let sidecar = std::fs::metadata(&spath)
                .ok()
                .filter(|m| m.is_file())
                .map(|m| (m.modified().ok(), m.len()));
            let now = Instant::now();
            let (prev_failures, prev_error) = match self.seen.get(&name) {
                Some(st) => {
                    if st.mtime == mtime && st.len == len && st.sidecar == sidecar {
                        // untouched since last look: deployed files are
                        // done; known-bad files are re-attempted once
                        // their backoff window expires, so a file fixed
                        // in place (same stat, healed bytes) deploys
                        // without waiting for an mtime change
                        match st.retry_at {
                            Some(t) if now >= t => self.retries += 1,
                            _ => continue,
                        }
                    }
                    (st.failures, st.last_error.clone())
                }
                None => (0, None),
            };
            let backoff = self.backoff(prev_failures + 1);
            let fail = |error: String, events: &mut Vec<WatchEvent>| {
                if prev_error.as_ref() != Some(&error) {
                    events.push(WatchEvent::Failed {
                        path: path.clone(),
                        error: error.clone(),
                    });
                }
                FileState {
                    mtime,
                    len,
                    sidecar,
                    fingerprint: None,
                    failures: prev_failures + 1,
                    retry_at: Some(now + backoff),
                    last_error: Some(error),
                }
            };
            // stat changed (or new stem, or retry due): decide via the
            // artifact's own stored checksum — O(header), no table
            // bytes re-read
            let fp = match artifact::content_fingerprint(&path) {
                Ok(fp) => fp,
                Err(e) => {
                    let st = fail(format!("{e:#}"), &mut events);
                    self.seen.insert(name, st);
                    continue;
                }
            };
            // resolve the sidecar (if any) BEFORE deciding to deploy: a
            // bad sidecar fails the PAIR closed — nothing deploys under
            // defaults by accident, an incumbent keeps its config
            let sidecar_cfg = match sidecar {
                None => None,
                Some(_) => match read_sidecar(&spath, &self.cfg) {
                    Ok(cfg) => Some(cfg),
                    Err(error) => {
                        let st = fail(error, &mut events);
                        self.seen.insert(name, st);
                        continue;
                    }
                },
            };
            let artifact_changed =
                self.seen.get(&name).and_then(|s| s.fingerprint) != Some(fp);
            // only a sidecar pins config; without one, config never
            // forces a deploy (swaps keep the incumbent's pipeline
            // config, as before)
            let cfg_changed = sidecar_cfg
                .as_ref()
                .is_some_and(|want| registry.serve_config(&name).as_ref() != Some(want));
            if !artifact_changed && !cfg_changed {
                // bare touch of artifact or sidecar: content and config
                // both match what is already serving — no deploy
                self.seen.insert(name, FileState::deployed(mtime, len, sidecar, fp));
                continue;
            }
            let cfg = sidecar_cfg.as_ref().unwrap_or(&self.cfg);
            match deploy(registry, &name, &path, cfg, cfg_changed) {
                Ok(ev) => {
                    self.seen.insert(name, FileState::deployed(mtime, len, sidecar, fp));
                    events.push(ev);
                }
                Err(error) => {
                    let st = fail(error, &mut events);
                    self.seen.insert(name, st);
                }
            }
        }
        if self.retire_on_delete {
            // a watched stem whose file vanished: retire the model (only
            // stems that actually deployed — a known-bad file that gets
            // deleted is just forgotten). Re-adding the file later
            // re-registers it fresh.
            let vanished: Vec<String> = self
                .seen
                .keys()
                .filter(|n| !present.contains(n.as_str()))
                .cloned()
                .collect();
            for name in vanished {
                let was_deployed =
                    self.seen.remove(&name).is_some_and(|st| st.fingerprint.is_some());
                if !was_deployed {
                    continue;
                }
                match registry.retire(&name) {
                    Ok(_final_snapshot) => events.push(WatchEvent::Retired { name }),
                    Err(e) => events.push(WatchEvent::Failed {
                        path: self.dir.join(format!("{name}.ltm")),
                        error: format!("retire on delete: {e}"),
                    }),
                }
            }
        }
        events
    }
}

/// Load `path` and install it under `name`: register a new stem,
/// hot-swap when the name is already serving (including names
/// registered outside the watcher, e.g. `--artifact`), or — when a
/// sidecar pinned a different config (`reconfigure`) — re-register
/// under the new [`ServeConfig`].
fn deploy(
    registry: &ModelRegistry,
    name: &str,
    path: &Path,
    cfg: &ServeConfig,
    reconfigure: bool,
) -> Result<WatchEvent, String> {
    let lut = LutModel::load(path).map_err(|e| format!("{e:#}"))?;
    let features = lut.input_features();
    let storage = lut.storage_summary();
    let zero_copy = storage.banks > 0 && storage.borrowed == storage.banks;
    let backend: Arc<dyn Backend> = Arc::new(lut);
    match registry.register(name, backend.clone(), cfg) {
        Ok(()) => Ok(WatchEvent::Registered {
            name: name.to_string(),
            path: path.to_path_buf(),
            features,
            zero_copy,
        }),
        Err(RegistryError::DuplicateModel(_)) if reconfigure => {
            // the sidecar pinned a different pipeline config: batching
            // policy cannot change under a live coordinator, so retire
            // and re-register (the one watcher path with a brief
            // routing gap; the version counter restarts at 1)
            registry.retire(name).map_err(|e| e.to_string())?;
            registry.register(name, backend, cfg).map_err(|e| e.to_string())?;
            Ok(WatchEvent::Reconfigured { name: name.to_string(), path: path.to_path_buf() })
        }
        Err(RegistryError::DuplicateModel(_)) => {
            // rolling deploy of a live model: quarantined — the
            // candidate must survive a golden batch, a rejection leaves
            // the incumbent serving and surfaces as WatchEvent::Failed
            let version =
                registry.swap_quarantined(name, backend).map_err(|e| e.to_string())?;
            Ok(WatchEvent::Swapped {
                name: name.to_string(),
                path: path.to_path_buf(),
                version,
                features,
                zero_copy,
            })
        }
        Err(e) => Err(e.to_string()),
    }
}

#[derive(Default)]
struct StatsCells {
    scans: AtomicU64,
    registered: AtomicU64,
    swapped: AtomicU64,
    reconfigured: AtomicU64,
    failed: AtomicU64,
    retries: AtomicU64,
    retired: AtomicU64,
}

/// Cumulative watcher counters (cheap atomic reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WatcherStats {
    /// Completed directory passes.
    pub scans: u64,
    /// Models auto-registered.
    pub registered: u64,
    /// Rolling deploys (content-change hot-swaps).
    pub swapped: u64,
    /// Sidecar-driven config re-registrations.
    pub reconfigured: u64,
    /// Files rejected (parse/deploy failures).
    pub failed: u64,
    /// Backoff-driven re-attempts of known-bad files.
    pub retries: u64,
    /// Models retired because their watched file was deleted
    /// ([`WatcherOptions::retire_on_delete`]).
    pub retired: u64,
}

/// A background thread polling one directory and deploying into a
/// [`ModelRegistry`]. Stops (and joins) on [`DirWatcher::stop`] or
/// drop.
pub struct DirWatcher {
    stop: Arc<AtomicBool>,
    stats: Arc<StatsCells>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl DirWatcher {
    /// Start watching `dir`, deploying into `registry` (a shared handle
    /// onto the caller's fleet). `on_event` fires on the watcher thread
    /// for every deploy/failure — keep it quick (logging, pool
    /// bookkeeping).
    pub fn start(
        registry: ModelRegistry,
        dir: impl Into<PathBuf>,
        opts: WatcherOptions,
        on_event: impl Fn(&WatchEvent) + Send + 'static,
    ) -> DirWatcher {
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(StatsCells::default());
        let (stop_t, stats_t) = (stop.clone(), stats.clone());
        let dir = dir.into();
        let handle = std::thread::Builder::new()
            .name("ltm-watcher".into())
            .spawn(move || {
                let mut scanner = DirScanner::new(dir, opts.serve_cfg.clone())
                    .with_backoff(opts.retry_base, opts.retry_cap)
                    .with_retire_on_delete(opts.retire_on_delete);
                while !stop_t.load(Ordering::Relaxed) {
                    for ev in scanner.scan(&registry) {
                        match &ev {
                            WatchEvent::Registered { .. } => &stats_t.registered,
                            WatchEvent::Swapped { .. } => &stats_t.swapped,
                            WatchEvent::Reconfigured { .. } => &stats_t.reconfigured,
                            WatchEvent::Failed { .. } => &stats_t.failed,
                            WatchEvent::Retired { .. } => &stats_t.retired,
                        }
                        .fetch_add(1, Ordering::Relaxed);
                        on_event(&ev);
                    }
                    stats_t.scans.fetch_add(1, Ordering::Relaxed);
                    stats_t.retries.store(scanner.retries(), Ordering::Relaxed);
                    // sleep in short slices so stop() returns promptly
                    // even under long poll intervals
                    let mut left = opts.poll;
                    while left > Duration::ZERO && !stop_t.load(Ordering::Relaxed) {
                        let step = left.min(Duration::from_millis(25));
                        std::thread::sleep(step);
                        left = left.saturating_sub(step);
                    }
                }
            })
            .expect("spawning the watcher thread");
        DirWatcher { stop, stats, handle: Some(handle) }
    }

    /// Counters so far.
    pub fn stats(&self) -> WatcherStats {
        WatcherStats {
            scans: self.stats.scans.load(Ordering::Relaxed),
            registered: self.stats.registered.load(Ordering::Relaxed),
            swapped: self.stats.swapped.load(Ordering::Relaxed),
            reconfigured: self.stats.reconfigured.load(Ordering::Relaxed),
            failed: self.stats.failed.load(Ordering::Relaxed),
            retries: self.stats.retries.load(Ordering::Relaxed),
            retired: self.stats.retired.load(Ordering::Relaxed),
        }
    }

    /// Stop polling, join the thread, return the final counters. The
    /// registry and its models keep serving — the watcher only adds.
    pub fn stop(mut self) -> WatcherStats {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
        self.stats()
    }
}

impl Drop for DirWatcher {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::EnginePlan;
    use crate::engine::Compiler;
    use crate::nn::Model;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn sandbox(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tablenet_watch_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn small_artifact_bytes(seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        let lut = Compiler::new(&model)
            .plan(&EnginePlan::linear_default())
            .build()
            .unwrap();
        artifact::to_bytes(&lut)
    }

    #[test]
    fn scanner_registers_swaps_and_ignores_noise() {
        let dir = sandbox("scanner");
        let registry = ModelRegistry::new();
        let mut scanner = DirScanner::new(&dir, ServeConfig::default());

        // empty dir, non-artifact files, and a directory named *.ltm
        // produce nothing
        assert!(scanner.scan(&registry).is_empty());
        std::fs::write(dir.join("README.txt"), b"not a model").unwrap();
        std::fs::create_dir(dir.join("not_a_file.ltm")).unwrap();
        assert!(scanner.scan(&registry).is_empty());

        // a dropped artifact registers under its stem and serves
        let v1_bytes = small_artifact_bytes(1);
        std::fs::write(dir.join("digits.ltm"), &v1_bytes).unwrap();
        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1, "{evs:?}");
        let features = match &evs[0] {
            WatchEvent::Registered { name, features, .. } => {
                assert_eq!(name, "digits");
                features.unwrap()
            }
            other => panic!("expected Registered, got {other:?}"),
        };
        assert_eq!(features, 784);
        let client = registry.client();
        client.infer("digits", vec![0.3; features]).unwrap();

        // steady state: no stat change -> no events, no fingerprints
        assert!(scanner.scan(&registry).is_empty());

        // rewriting IDENTICAL content is not a deploy (fingerprint
        // equality catches the mtime bump)
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm"), &v1_bytes).unwrap();
        let evs = scanner.scan(&registry);
        assert!(evs.is_empty(), "bare touch must not redeploy: {evs:?}");
        assert_eq!(client.infer("digits", vec![0.3; features]).unwrap().version, 1);

        // overwriting with DIFFERENT content hot-swaps to v2
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm"), small_artifact_bytes(2)).unwrap();
        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1, "{evs:?}");
        match &evs[0] {
            WatchEvent::Swapped { name, version, .. } => {
                assert_eq!((name.as_str(), *version), ("digits", 2));
            }
            other => panic!("expected Swapped, got {other:?}"),
        }
        assert_eq!(client.infer("digits", vec![0.3; features]).unwrap().version, 2);

        // a corrupt artifact is reported ONCE and never deployed...
        std::fs::write(dir.join("broken.ltm"), b"LTM1 garbage").unwrap();
        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1);
        assert!(matches!(&evs[0], WatchEvent::Failed { .. }), "{evs:?}");
        assert_eq!(registry.models().len(), 1);
        assert!(scanner.scan(&registry).is_empty(), "failure must not re-report");

        // ...and heals once the file is rewritten valid
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("broken.ltm"), small_artifact_bytes(3)).unwrap();
        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1);
        assert!(
            matches!(&evs[0], WatchEvent::Registered { name, .. } if name == "broken"),
            "{evs:?}"
        );
        assert_eq!(registry.models().len(), 2);

        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scanner_swaps_models_registered_outside_the_watcher() {
        // a watch-dir file whose stem matches a statically-registered
        // model becomes a rolling deploy of that model
        let dir = sandbox("static");
        let registry = ModelRegistry::new();
        let lut = artifact::from_bytes(&small_artifact_bytes(4)).unwrap();
        registry.register("m", Arc::new(lut), &ServeConfig::default()).unwrap();
        let mut scanner = DirScanner::new(&dir, ServeConfig::default());
        std::fs::write(dir.join("m.ltm"), small_artifact_bytes(5)).unwrap();
        let evs = scanner.scan(&registry);
        assert!(
            matches!(&evs[0], WatchEvent::Swapped { name, version: 2, .. } if name == "m"),
            "{evs:?}"
        );
        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Write + rename: the atomic deploy pattern the watcher contract
    /// requires for REPLACING a live model (the old version serves from
    /// a mapping of the old inode; rename never lets a scan — or a
    /// serving thread — see a half-written file).
    fn deploy_atomic(dir: &Path, name: &str, bytes: &[u8]) {
        let tmp = dir.join(format!("{name}.tmp"));
        std::fs::write(&tmp, bytes).unwrap();
        std::fs::rename(&tmp, dir.join(name)).unwrap();
    }

    #[test]
    fn watcher_thread_deploys_end_to_end() {
        let dir = sandbox("thread");
        let registry = ModelRegistry::new();
        let watcher = DirWatcher::start(
            registry.clone(),
            &dir,
            WatcherOptions { poll: Duration::from_millis(20), ..Default::default() },
            |_| {},
        );

        let wait_until = |pred: &dyn Fn() -> bool, what: &str| {
            let t0 = std::time::Instant::now();
            while !pred() {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "timed out waiting for {what}"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        };

        // drop a model in (atomically — the poll races plain writes):
        // it appears in the fleet without any call on the registry from
        // this thread
        deploy_atomic(&dir, "live.ltm", &small_artifact_bytes(6));
        wait_until(&|| !registry.models().is_empty(), "auto-registration");
        let client = registry.client();
        assert_eq!(client.infer("live", vec![0.1; 784]).unwrap().version, 1);

        // replace with new content: version bumps with zero downtime
        deploy_atomic(&dir, "live.ltm", &small_artifact_bytes(7));
        wait_until(
            &|| registry.models().first().is_some_and(|m| m.version == 2),
            "rolling deploy",
        );
        assert_eq!(client.infer("live", vec![0.1; 784]).unwrap().version, 2);

        let stats = watcher.stop();
        assert!(stats.scans >= 2, "{stats:?}");
        assert_eq!((stats.registered, stats.swapped, stats.failed), (1, 1, 0));
        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn known_bad_files_retry_with_backoff_and_heal_in_place() {
        let dir = sandbox("backoff");
        let registry = ModelRegistry::new();
        let mut scanner = DirScanner::new(&dir, ServeConfig::default())
            .with_backoff(Duration::from_millis(100), Duration::from_secs(1));

        // a good artifact with one payload byte flipped: every byte
        // past the header is covered by some checksum, so the load must
        // reject it
        let good = small_artifact_bytes(8);
        let mut bad = good.clone();
        crate::coordinator::faults::FaultInjector::corrupt(&mut bad, 1);
        let path = dir.join("healme.ltm");
        std::fs::write(&path, &bad).unwrap();

        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1);
        assert!(matches!(&evs[0], WatchEvent::Failed { .. }), "{evs:?}");
        assert!(registry.models().is_empty());

        // inside the backoff window: no retry, no event
        assert!(scanner.scan(&registry).is_empty());
        assert_eq!(scanner.retries(), 0);

        // past the window: retried; the SAME error stays silent
        std::thread::sleep(Duration::from_millis(120));
        assert!(scanner.scan(&registry).is_empty(), "unchanged error must not re-report");
        assert_eq!(scanner.retries(), 1);

        // heal IN PLACE: same byte count, mtime pinned back — the stat
        // gate cannot explain the recovery, only the backoff retry can
        let mtime = std::fs::metadata(&path).unwrap().modified().unwrap();
        std::fs::write(&path, &good).unwrap();
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_modified(mtime).unwrap();
        drop(f);

        // second backoff step doubled to 200ms
        std::thread::sleep(Duration::from_millis(250));
        let evs = scanner.scan(&registry);
        assert_eq!(scanner.retries(), 2);
        assert!(
            matches!(&evs[0], WatchEvent::Registered { name, .. } if name == "healme"),
            "{evs:?}"
        );
        assert_eq!(registry.models().len(), 1);
        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sidecars_pin_config_and_fail_closed() {
        let dir = sandbox("sidecar");
        let registry = ModelRegistry::new();
        let mut scanner = DirScanner::new(&dir, ServeConfig::default());

        // sidecar present at first sight: registered under the pinned
        // config, unspecified keys inherited from the watcher's base
        std::fs::write(dir.join("digits.ltm"), small_artifact_bytes(21)).unwrap();
        std::fs::write(
            dir.join("digits.ltm.json"),
            r#"{"max_batch": 4, "admission_weight": 3}"#,
        )
        .unwrap();
        let evs = scanner.scan(&registry);
        assert!(
            matches!(&evs[0], WatchEvent::Registered { name, .. } if name == "digits"),
            "{evs:?}"
        );
        let cfg = registry.serve_config("digits").unwrap();
        assert_eq!((cfg.max_batch, cfg.admission_weight), (4, 3));
        assert_eq!(cfg.queue_cap, ServeConfig::default().queue_cap);
        let client = registry.client();
        client.infer("digits", vec![0.2; 784]).unwrap();

        // steady state: neither file changed -> nothing happens
        assert!(scanner.scan(&registry).is_empty());

        // sidecar-only change: re-registered under the new config (the
        // artifact content did not change; version restarts at 1)
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm.json"), r#"{"max_batch": 8}"#).unwrap();
        let evs = scanner.scan(&registry);
        assert!(
            matches!(&evs[0], WatchEvent::Reconfigured { name, .. } if name == "digits"),
            "{evs:?}"
        );
        let cfg = registry.serve_config("digits").unwrap();
        assert_eq!((cfg.max_batch, cfg.admission_weight), (8, 1));
        client.infer("digits", vec![0.2; 784]).unwrap();
        assert!(scanner.scan(&registry).is_empty(), "reconfigure must settle");

        // a typo'd key fails CLOSED: one Failed event, the incumbent
        // keeps serving its existing config
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm.json"), r#"{"max_batc": 16}"#).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Failed { .. }), "{evs:?}");
        assert_eq!(
            registry.serve_config("digits").unwrap().max_batch,
            8,
            "incumbent config must survive a bad sidecar"
        );
        client.infer("digits", vec![0.2; 784]).unwrap();

        // an invalid combination is rejected by validate(), same path
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm.json"), r#"{"admission_weight": 0}"#).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Failed { .. }), "{evs:?}");
        client.infer("digits", vec![0.2; 784]).unwrap();

        // healing the sidecar redeploys (the failure dropped the
        // fingerprint, so this lands as a quarantined swap)
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm.json"), r#"{"max_batch": 8}"#).unwrap();
        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1, "{evs:?}");
        assert_eq!(registry.serve_config("digits").unwrap().max_batch, 8);

        // artifact content change with an unchanged sidecar: a normal
        // quarantined hot-swap that keeps the pinned config
        std::thread::sleep(Duration::from_millis(15));
        std::fs::write(dir.join("digits.ltm"), small_artifact_bytes(22)).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Swapped { name, .. } if name == "digits"), "{evs:?}");
        assert_eq!(registry.serve_config("digits").unwrap().max_batch, 8);

        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sidecar_appearing_next_to_a_live_model_reconfigures_it() {
        let dir = sandbox("sidecar_live");
        let registry = ModelRegistry::new();
        let mut scanner = DirScanner::new(&dir, ServeConfig::default());

        // no sidecar: registered under the watcher's base config
        std::fs::write(dir.join("m.ltm"), small_artifact_bytes(23)).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Registered { .. }), "{evs:?}");

        // dropping a sidecar in afterwards re-registers under it
        std::fs::write(dir.join("m.ltm.json"), r#"{"deadline_us": 900000}"#).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Reconfigured { name, .. } if name == "m"), "{evs:?}");
        assert_eq!(registry.serve_config("m").unwrap().deadline_us, 900_000);

        // removing the sidecar UNPINS but does not revert: without one,
        // config never forces a deploy, so the incumbent keeps the last
        // pinned config until its content changes or a sidecar returns
        std::fs::remove_file(dir.join("m.ltm.json")).unwrap();
        let evs = scanner.scan(&registry);
        assert!(evs.is_empty(), "removing a sidecar must not force a deploy: {evs:?}");
        assert!(scanner.scan(&registry).is_empty());
        assert_eq!(registry.serve_config("m").unwrap().deadline_us, 900_000);

        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retire_on_delete_retires_and_readd_redeploys() {
        let dir = sandbox("retire");
        let registry = ModelRegistry::new();
        let mut scanner =
            DirScanner::new(&dir, ServeConfig::default()).with_retire_on_delete(true);

        std::fs::write(dir.join("digits.ltm"), small_artifact_bytes(31)).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Registered { .. }), "{evs:?}");
        let client = registry.client();
        client.infer("digits", vec![0.2; 784]).unwrap();

        // deleting the watched file retires the model
        std::fs::remove_file(dir.join("digits.ltm")).unwrap();
        let evs = scanner.scan(&registry);
        assert_eq!(evs.len(), 1, "{evs:?}");
        assert!(
            matches!(&evs[0], WatchEvent::Retired { name } if name == "digits"),
            "{evs:?}"
        );
        assert!(registry.models().is_empty());
        assert!(client.infer("digits", vec![0.2; 784]).is_err());
        // retirement settles: no repeat events for the same deletion
        assert!(scanner.scan(&registry).is_empty());

        // re-adding the file re-registers from scratch at version 1
        std::thread::sleep(Duration::from_millis(15));
        deploy_atomic(&dir, "digits.ltm", &small_artifact_bytes(32));
        let evs = scanner.scan(&registry);
        assert!(
            matches!(&evs[0], WatchEvent::Registered { name, .. } if name == "digits"),
            "{evs:?}"
        );
        assert_eq!(client.infer("digits", vec![0.2; 784]).unwrap().version, 1);

        // a never-deployed (known-bad) file that vanishes is simply
        // forgotten — nothing to retire
        std::fs::write(dir.join("broken.ltm"), b"LTM1 garbage").unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Failed { .. }), "{evs:?}");
        std::fs::remove_file(dir.join("broken.ltm")).unwrap();
        assert!(scanner.scan(&registry).is_empty(), "known-bad delete must be silent");
        assert_eq!(registry.models().len(), 1);

        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delete_without_retire_on_delete_keeps_serving() {
        let dir = sandbox("no_retire");
        let registry = ModelRegistry::new();
        let mut scanner = DirScanner::new(&dir, ServeConfig::default());

        std::fs::write(dir.join("m.ltm"), small_artifact_bytes(33)).unwrap();
        let evs = scanner.scan(&registry);
        assert!(matches!(&evs[0], WatchEvent::Registered { .. }), "{evs:?}");

        // default posture: deletion is NOT a deploy signal; the
        // incumbent keeps serving from memory
        std::fs::remove_file(dir.join("m.ltm")).unwrap();
        assert!(scanner.scan(&registry).is_empty());
        assert_eq!(registry.models().len(), 1);
        registry.client().infer("m", vec![0.2; 784]).unwrap();

        registry.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
