//! Hot-swappable model registry: the fleet-management half of the
//! serving runtime. A [`ModelRegistry`] owns one named
//! [`Coordinator`] pipeline per model — its own [`super::batcher`]
//! policy, its own worker pool (and per-worker `Scratch`), its own
//! metrics — and supports rolling deployments over live traffic:
//!
//! * [`ModelRegistry::register`] — start serving a new named model;
//! * [`ModelRegistry::swap`] — atomic zero-downtime version bump: all
//!   subsequent batches run the new backend, in-flight batches finish
//!   on the old one, no request lost, no batch mixing versions;
//! * [`ModelRegistry::retire`] — drain a model's pipeline and remove it
//!   from the fleet, leaving every other model untouched;
//! * [`ModelRegistry::fleet`] — per-model snapshots rolled up into a
//!   [`FleetSnapshot`] (exact per-model op counters, zero multiplies
//!   asserted per model).
//!
//! Request dispatch by model name lives in [`super::router`]
//! ([`super::router::FleetClient`]); clients resolve names against the
//! live table, so registrations, swaps and retirements are visible
//! without re-handing out clients.
//!
//! ```
//! use std::sync::Arc;
//! use tablenet::config::ServeConfig;
//! use tablenet::coordinator::registry::ModelRegistry;
//! use tablenet::coordinator::{Backend, InferOutput};
//! use tablenet::engine::counters::Counters;
//!
//! struct Echo(usize);
//! impl Backend for Echo {
//!     fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
//!         images
//!             .iter()
//!             .map(|_| InferOutput { class: self.0, logits: vec![1.0], counters: Counters::default() })
//!             .collect()
//!     }
//! }
//!
//! let registry = ModelRegistry::new();
//! registry.register("echo", Arc::new(Echo(0)), &ServeConfig::default()).unwrap();
//! let client = registry.client();
//! assert_eq!(client.infer("echo", vec![0.0]).unwrap().version, 1);
//! registry.swap("echo", Arc::new(Echo(1))).unwrap();   // zero-downtime bump
//! let served = client.infer("echo", vec![0.0]).unwrap();
//! assert_eq!((served.version, served.class), (2, 1));
//! registry.shutdown().assert_multiplier_less();
//! ```

pub mod watcher;

use super::faults::FaultInjector;
use super::metrics::{FleetSnapshot, ModelSnapshot, Snapshot};
use super::router::FleetClient;
use super::{Backend, Coordinator, HealthState};
use crate::config::ServeConfig;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// Registry-level errors (dispatch-time errors are
/// [`super::router::RouteError`]).
#[derive(Debug)]
pub enum RegistryError {
    DuplicateModel(String),
    UnknownModel(String),
    InvalidConfig(String),
    /// A quarantined swap failed its golden-batch self-check; the
    /// incumbent version is untouched and keeps serving.
    SwapRejected { model: String, reason: String },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::DuplicateModel(m) => {
                write!(f, "model '{m}' is already registered")
            }
            RegistryError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            RegistryError::InvalidConfig(e) => write!(f, "invalid serve config: {e}"),
            RegistryError::SwapRejected { model, reason } => {
                write!(f, "swap of '{model}' rejected (incumbent keeps serving): {reason}")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// Deterministic golden rows for quarantined swaps: the candidate must
/// survive these before it replaces the incumbent. Empty when the input
/// width is unknown (no basis to synthesize rows).
fn golden_rows(features: Option<usize>) -> Vec<Vec<f32>> {
    let Some(f) = features else { return Vec::new() };
    let mut rng = crate::util::Rng::new(0x601D_BA7C);
    (0..4).map(|_| (0..f).map(|_| rng.f32()).collect()).collect()
}

/// One registered model: its running pipeline plus the config it was
/// started with.
pub(super) struct ModelEntry {
    pub(super) coord: Coordinator,
    pub(super) cfg: ServeConfig,
}

/// The live model table, shared between the registry handle and every
/// [`FleetClient`].
pub(super) struct RegistryShared {
    pub(super) models: RwLock<BTreeMap<String, ModelEntry>>,
    /// Fault-injection hook handed to every pipeline started through
    /// this registry; `None` in production (zero cost on the hot path).
    pub(super) faults: Option<Arc<FaultInjector>>,
}

/// Identity card of a registered model at listing time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    pub name: String,
    /// Installed backend version (1 = as registered).
    pub version: u64,
    /// `Backend::name` of the installed backend.
    pub backend: &'static str,
    /// Worker threads of this model's pipeline.
    pub workers: usize,
}

/// A set of named, versioned, independently-batched model pipelines
/// behind one management handle.
pub struct ModelRegistry {
    shared: Arc<RegistryShared>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        ModelRegistry::new()
    }
}

impl Clone for ModelRegistry {
    /// Another handle onto the SAME fleet (the model table is shared,
    /// not copied) — this is how the deploy watcher thread holds the
    /// registry while the serving thread keeps its own handle.
    fn clone(&self) -> Self {
        ModelRegistry { shared: self.shared.clone() }
    }
}

impl ModelRegistry {
    /// An empty fleet; add models with [`ModelRegistry::register`].
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            shared: Arc::new(RegistryShared {
                models: RwLock::new(BTreeMap::new()),
                faults: None,
            }),
        }
    }

    /// An empty fleet whose pipelines all run under `faults` — the
    /// chaos-testing entry point. Fault decisions come from one shared
    /// injector, so the full fault sequence across the fleet is
    /// reproducible from the plan's seed.
    pub fn with_faults(faults: Arc<FaultInjector>) -> ModelRegistry {
        ModelRegistry {
            shared: Arc::new(RegistryShared {
                models: RwLock::new(BTreeMap::new()),
                faults: Some(faults),
            }),
        }
    }

    /// Start serving `backend` under `name` with its own batching
    /// pipeline configured by `cfg`. Errors if the name is taken or the
    /// config is invalid; on success the model is immediately routable
    /// from every existing [`FleetClient`].
    pub fn register(
        &self,
        name: &str,
        backend: Arc<dyn Backend>,
        cfg: &ServeConfig,
    ) -> Result<(), RegistryError> {
        cfg.validate().map_err(|e| RegistryError::InvalidConfig(e.to_string()))?;
        let mut models = self.shared.models.write().unwrap();
        if models.contains_key(name) {
            return Err(RegistryError::DuplicateModel(name.to_string()));
        }
        models.insert(
            name.to_string(),
            ModelEntry {
                coord: Coordinator::start_with_faults(
                    backend,
                    cfg,
                    self.shared.faults.clone(),
                ),
                cfg: cfg.clone(),
            },
        );
        Ok(())
    }

    /// Atomic zero-downtime hot-swap of `name` to a new backend
    /// version (see [`Coordinator::swap`] for the batch-level
    /// guarantees). Returns the new version number.
    pub fn swap(&self, name: &str, backend: Arc<dyn Backend>) -> Result<u64, RegistryError> {
        let models = self.shared.models.read().unwrap();
        let entry = models
            .get(name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        Ok(entry.coord.swap(backend))
    }

    /// Quarantined hot-swap: before the version bump, the candidate
    /// backend must run a deterministic golden batch without panicking
    /// and produce well-formed outputs (see
    /// [`Coordinator::swap_checked`]). On rejection the incumbent keeps
    /// serving at its current version and the error names the reason.
    /// A successful swap also clears a `Degraded` health latch.
    pub fn swap_quarantined(
        &self,
        name: &str,
        backend: Arc<dyn Backend>,
    ) -> Result<u64, RegistryError> {
        let models = self.shared.models.read().unwrap();
        let entry = models
            .get(name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        // Prefer the candidate's own declared input width; fall back to
        // the incumbent's so opaque probe backends still get screened.
        let features = backend.input_features().or_else(|| entry.coord.input_features());
        let golden = golden_rows(features);
        entry.coord.swap_checked(backend, &golden).map_err(|e| {
            RegistryError::SwapRejected { model: name.to_string(), reason: e.to_string() }
        })
    }

    /// Drain `name`'s pipeline (every accepted request is served) and
    /// remove it from the fleet. Subsequent routes to `name` fail with
    /// `UnknownModel`; other models are untouched. Returns the retired
    /// pipeline's final metrics.
    pub fn retire(&self, name: &str) -> Result<Snapshot, RegistryError> {
        let entry = {
            let mut models = self.shared.models.write().unwrap();
            models
                .remove(name)
                .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?
        };
        // shutdown outside the lock: draining must not block routing
        // to the rest of the fleet
        Ok(entry.coord.shutdown())
    }

    /// A dispatch handle over the live table (cheap to clone).
    pub fn client(&self) -> FleetClient {
        FleetClient::new(self.shared.clone())
    }

    /// The registered models, name-sorted, with installed versions.
    pub fn models(&self) -> Vec<ModelInfo> {
        self.shared
            .models
            .read()
            .unwrap()
            .iter()
            .map(|(name, e)| ModelInfo {
                name: name.clone(),
                version: e.coord.version(),
                backend: e.coord.backend_name(),
                workers: e.cfg.workers,
            })
            .collect()
    }

    /// The [`ServeConfig`] `name`'s pipeline is currently running
    /// under (as registered; a live pipeline's batching policy never
    /// changes in place). `None` if the model is not registered.
    pub fn serve_config(&self, name: &str) -> Option<ServeConfig> {
        self.shared.models.read().unwrap().get(name).map(|e| e.cfg.clone())
    }

    /// Total requests served across the fleet — cheap atomic reads,
    /// safe to poll in a tight loop (unlike [`ModelRegistry::fleet`],
    /// which clones and sorts every model's latency samples).
    pub fn fleet_completed(&self) -> u64 {
        self.shared
            .models
            .read()
            .unwrap()
            .values()
            .map(|e| e.coord.completed())
            .sum()
    }

    /// Live per-model snapshots rolled up into a fleet view.
    pub fn fleet(&self) -> FleetSnapshot {
        let models = self.shared.models.read().unwrap();
        let mut fleet = FleetSnapshot::default();
        for (name, e) in models.iter() {
            fleet.models.insert(
                name.clone(),
                ModelSnapshot {
                    version: e.coord.version(),
                    backend: e.coord.backend_name().to_string(),
                    degraded: e.coord.health() == HealthState::Degraded,
                    stats: e.coord.client().metrics(),
                },
            );
        }
        fleet
    }

    /// Drain and stop every pipeline; returns the final fleet snapshot.
    pub fn shutdown(self) -> FleetSnapshot {
        let mut models = self.shared.models.write().unwrap();
        let mut fleet = FleetSnapshot::default();
        for (name, e) in std::mem::take(&mut *models) {
            let version = e.coord.version();
            let backend = e.coord.backend_name().to_string();
            let degraded = e.coord.health() == HealthState::Degraded;
            fleet.models.insert(
                name,
                ModelSnapshot { version, backend, degraded, stats: e.coord.shutdown() },
            );
        }
        fleet
    }
}

#[cfg(test)]
mod tests {
    use super::super::InferOutput;
    use super::*;
    use crate::engine::counters::Counters;

    /// Fixed-class probe backend.
    struct Fixed(usize);

    impl Backend for Fixed {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "fixed"
        }
    }

    #[test]
    fn register_swap_retire_lifecycle() {
        let reg = ModelRegistry::new();
        let cfg = ServeConfig::default();
        reg.register("a", Arc::new(Fixed(1)), &cfg).unwrap();
        reg.register("b", Arc::new(Fixed(2)), &cfg).unwrap();
        // duplicate name is an error, not a silent replace
        assert!(matches!(
            reg.register("a", Arc::new(Fixed(9)), &cfg),
            Err(RegistryError::DuplicateModel(_))
        ));
        let infos = reg.models();
        assert_eq!(infos.len(), 2);
        assert_eq!((infos[0].name.as_str(), infos[0].version), ("a", 1));

        let client = reg.client();
        assert_eq!(client.infer("a", vec![0.0]).unwrap().class, 1);
        assert_eq!(client.infer("b", vec![0.0]).unwrap().class, 2);

        // hot-swap 'a' to a new version; 'b' unaffected
        assert_eq!(reg.swap("a", Arc::new(Fixed(7))).unwrap(), 2);
        let r = client.infer("a", vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (7, 2));
        assert_eq!(client.infer("b", vec![0.0]).unwrap().version, 1);
        assert!(matches!(
            reg.swap("nope", Arc::new(Fixed(0))),
            Err(RegistryError::UnknownModel(_))
        ));

        // retire 'b'; its snapshot is final, and routing to it now fails
        let snap = reg.retire("b").unwrap();
        assert_eq!(snap.completed, 2);
        assert!(client.infer("b", vec![0.0]).is_err());
        assert_eq!(client.infer("a", vec![0.0]).unwrap().class, 7);
        assert!(matches!(reg.retire("b"), Err(RegistryError::UnknownModel(_))));

        let fleet = reg.shutdown();
        assert_eq!(fleet.models.len(), 1);
        assert_eq!(fleet.models["a"].version, 2);
        fleet.assert_multiplier_less();
    }

    #[test]
    fn late_registration_is_visible_to_existing_clients() {
        let reg = ModelRegistry::new();
        let client = reg.client();
        assert!(client.infer("late", vec![0.0]).is_err());
        reg.register("late", Arc::new(Fixed(4)), &ServeConfig::default()).unwrap();
        assert_eq!(client.infer("late", vec![0.0]).unwrap().class, 4);
        reg.shutdown();
    }

    #[test]
    fn invalid_config_is_rejected_at_register() {
        let reg = ModelRegistry::new();
        let bad = ServeConfig { max_batch: 0, ..ServeConfig::default() };
        assert!(matches!(
            reg.register("x", Arc::new(Fixed(0)), &bad),
            Err(RegistryError::InvalidConfig(_))
        ));
        assert!(reg.models().is_empty());
        reg.shutdown();
    }

    #[test]
    fn fleet_snapshot_attributes_ops_per_model() {
        let reg = ModelRegistry::new();
        let cfg = ServeConfig::default();
        reg.register("a", Arc::new(Fixed(1)), &cfg).unwrap();
        reg.register("b", Arc::new(Fixed(2)), &cfg).unwrap();
        let client = reg.client();
        for _ in 0..3 {
            client.infer("a", vec![0.0]).unwrap();
        }
        client.infer("b", vec![0.0]).unwrap();
        let fleet = reg.fleet();
        assert_eq!(fleet.models["a"].stats.ops.lut_evals, 3);
        assert_eq!(fleet.models["b"].stats.ops.lut_evals, 1);
        assert_eq!(fleet.completed(), 4);
        reg.shutdown();
    }

    /// Backend that panics on every batch — a broken candidate build.
    struct Exploding;

    impl Backend for Exploding {
        fn infer_batch(&self, _images: &[Vec<f32>]) -> Vec<InferOutput> {
            panic!("candidate build is broken");
        }

        fn name(&self) -> &'static str {
            "exploding"
        }

        fn input_features(&self) -> Option<usize> {
            Some(1)
        }
    }

    #[test]
    fn quarantined_swap_rejects_broken_candidate_and_keeps_incumbent() {
        super::super::faults::silence_injected_panics();
        let reg = ModelRegistry::new();
        reg.register("m", Arc::new(Fixed(3)), &ServeConfig::default()).unwrap();
        let client = reg.client();
        assert_eq!(client.infer("m", vec![0.0]).unwrap().class, 3);

        let err = reg.swap_quarantined("m", Arc::new(Exploding)).unwrap_err();
        match &err {
            RegistryError::SwapRejected { model, reason } => {
                assert_eq!(model, "m");
                assert!(reason.contains("panicked"), "reason: {reason}");
            }
            other => panic!("expected SwapRejected, got {other:?}"),
        }
        // incumbent untouched: same version, still serving
        let r = client.infer("m", vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (3, 1));

        // a healthy candidate passes quarantine and bumps the version
        assert_eq!(reg.swap_quarantined("m", Arc::new(Fixed(8))).unwrap(), 2);
        let r = client.infer("m", vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (8, 2));

        assert!(matches!(
            reg.swap_quarantined("nope", Arc::new(Fixed(0))),
            Err(RegistryError::UnknownModel(_))
        ));
        reg.shutdown();
    }

    #[test]
    fn golden_rows_are_deterministic_and_sized() {
        assert!(golden_rows(None).is_empty());
        let a = golden_rows(Some(5));
        let b = golden_rows(Some(5));
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|row| row.len() == 5));
    }
}
