//! Request router: per-request dispatch by model name over the live
//! registry table. This is the data-plane half of the serving runtime —
//! the control plane (register / swap / retire) is
//! [`super::registry::ModelRegistry`].
//!
//! A [`FleetClient`] resolves the model name against the registry **at
//! call time**, so it observes the fleet as it changes: a model
//! registered after the client was handed out is routable, a retired
//! model fails with [`RouteError::UnknownModel`], and a hot-swapped
//! model keeps serving without the client noticing (beyond the bumped
//! `Response::version`). Each model's pipeline batches independently,
//! so one saturated tenant cannot stall another.
//!
//! ```
//! use std::sync::Arc;
//! use tablenet::config::ServeConfig;
//! use tablenet::coordinator::registry::ModelRegistry;
//! use tablenet::coordinator::router::RouteError;
//! use tablenet::coordinator::{Backend, InferOutput};
//! use tablenet::engine::counters::Counters;
//!
//! struct Echo;
//! impl Backend for Echo {
//!     fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
//!         images
//!             .iter()
//!             .map(|row| InferOutput {
//!                 class: 0,
//!                 logits: vec![row.len() as f32],
//!                 counters: Counters::default(),
//!             })
//!             .collect()
//!     }
//! }
//!
//! let registry = ModelRegistry::new();
//! let client = registry.client();              // handed out BEFORE any model
//! assert!(matches!(
//!     client.infer("echo", vec![0.0]),
//!     Err(RouteError::UnknownModel(_))
//! ));
//! registry.register("echo", Arc::new(Echo), &ServeConfig::default()).unwrap();
//! let r = client.infer("echo", vec![0.0; 3]).unwrap();   // routable now
//! assert_eq!(r.logits, vec![3.0]);
//! registry.shutdown().assert_multiplier_less();
//! ```

use super::registry::RegistryShared;
use super::{Client, Pending, Response, SubmitError};
use std::sync::Arc;

/// Cloneable multi-model dispatch handle over the live registry.
#[derive(Clone)]
pub struct FleetClient {
    shared: Arc<RegistryShared>,
}

/// Routing error.
#[derive(Debug)]
pub enum RouteError {
    UnknownModel(String),
    Submit(SubmitError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            RouteError::Submit(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RouteError {}

impl FleetClient {
    pub(super) fn new(shared: Arc<RegistryShared>) -> FleetClient {
        FleetClient { shared }
    }

    /// Resolve `model` against the live table. The read lock is held
    /// only for the lookup — the actual submit/wait happens outside it,
    /// so slow inference never blocks fleet management or other routes.
    fn resolve(&self, model: &str) -> Result<Client, RouteError> {
        self.shared
            .models
            .read()
            .unwrap()
            .get(model)
            .map(|e| e.coord.client())
            .ok_or_else(|| RouteError::UnknownModel(model.to_string()))
    }

    /// Resolve `model` once and hand back its pipeline [`Client`].
    /// Useful when a caller routes many rows to one model (the net
    /// tier's dispatchers): one registry lookup instead of one per
    /// row. The handle pins resolution time, not the model — a swap
    /// is observed (same pipeline), a retire surfaces as `ShutDown`.
    pub fn client(&self, model: &str) -> Result<Client, RouteError> {
        self.resolve(model)
    }

    /// Fail-fast submit without waiting: returns a
    /// [`Pending`] to redeem later.
    pub fn submit(&self, model: &str, image: Vec<f32>) -> Result<Pending, RouteError> {
        self.resolve(model)?.submit(image).map_err(RouteError::Submit)
    }

    /// Blocking submit without waiting (no fail-fast).
    pub fn submit_blocking(&self, model: &str, image: Vec<f32>) -> Result<Pending, RouteError> {
        self.resolve(model)?.submit_blocking(image).map_err(RouteError::Submit)
    }

    /// Route an inference to a named model (blocking).
    pub fn infer(&self, model: &str, image: Vec<f32>) -> Result<Response, RouteError> {
        self.resolve(model)?.infer_blocking(image).map_err(RouteError::Submit)
    }

    /// Fail-fast variant (backpressure-aware).
    pub fn try_infer(&self, model: &str, image: Vec<f32>) -> Result<Response, RouteError> {
        self.resolve(model)?.infer(image).map_err(RouteError::Submit)
    }

    /// Names currently routable, sorted.
    pub fn models(&self) -> Vec<String> {
        self.shared.models.read().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::ModelRegistry;
    use super::super::{Backend, InferOutput};
    use super::*;
    use crate::config::ServeConfig;
    use crate::engine::counters::Counters;

    /// Backend that answers with a fixed class (model identity probe).
    struct Fixed(usize);

    impl Backend for Fixed {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "fixed"
        }
    }

    fn fleet_of(models: &[(&str, usize)], cfg: &ServeConfig) -> ModelRegistry {
        let reg = ModelRegistry::new();
        for &(name, class) in models {
            reg.register(name, std::sync::Arc::new(Fixed(class)), cfg).unwrap();
        }
        reg
    }

    #[test]
    fn routes_to_the_right_model() {
        let reg = fleet_of(&[("a", 1), ("b", 2)], &ServeConfig::default());
        let client = reg.client();
        assert_eq!(client.models(), vec!["a".to_string(), "b".to_string()]);
        for _ in 0..20 {
            assert_eq!(client.infer("a", vec![0.0]).unwrap().class, 1);
            assert_eq!(client.infer("b", vec![0.0]).unwrap().class, 2);
        }
        let fleet = reg.shutdown();
        assert_eq!(fleet.models["a"].stats.completed, 20);
        assert_eq!(fleet.models["b"].stats.completed, 20);
    }

    #[test]
    fn submit_then_wait_matches_infer() {
        let reg = fleet_of(&[("a", 1), ("b", 2)], &ServeConfig::default());
        let client = reg.client();
        // submit a whole batch before redeeming any verdict — the
        // decoupled path the net tier's dispatchers use
        let pendings: Vec<_> =
            (0..10).map(|i| client.submit(if i % 2 == 0 { "a" } else { "b" }, vec![0.0])).collect();
        for (i, p) in pendings.into_iter().enumerate() {
            let resp = p.unwrap().wait().unwrap();
            assert_eq!(resp.class, 1 + i % 2);
        }
        let resolved = client.client("a").unwrap();
        assert_eq!(resolved.infer(vec![0.0]).unwrap().class, 1);
        assert!(matches!(client.submit("ghost", vec![0.0]), Err(RouteError::UnknownModel(_))));
        let fleet = reg.shutdown();
        assert_eq!(fleet.completed(), 11);
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let reg = fleet_of(&[("only", 0)], &ServeConfig::default());
        let client = reg.client();
        match client.infer("nope", vec![0.0]) {
            Err(RouteError::UnknownModel(m)) => assert_eq!(m, "nope"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        reg.shutdown();
    }

    #[test]
    fn pipelines_are_isolated() {
        // saturating model 'slow' must not stall model 'fast'
        struct Slow;
        impl Backend for Slow {
            fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
                std::thread::sleep(std::time::Duration::from_millis(20));
                Fixed(9).infer_batch(images)
            }
            fn name(&self) -> &'static str {
                "slow"
            }
        }
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait_us: 10,
            workers: 1,
            queue_cap: 4,
            ..ServeConfig::default()
        };
        let reg = ModelRegistry::new();
        reg.register("slow", std::sync::Arc::new(Slow), &cfg).unwrap();
        reg.register("fast", std::sync::Arc::new(Fixed(3)), &cfg).unwrap();
        let client = reg.client();
        // occupy the slow pipeline
        let slow_client = client.clone();
        let h = std::thread::spawn(move || {
            for _ in 0..5 {
                let _ = slow_client.infer("slow", vec![0.0]);
            }
        });
        // fast stays fast
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            assert_eq!(client.infer("fast", vec![0.0]).unwrap().class, 3);
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(50),
            "fast pipeline was blocked by the slow one"
        );
        h.join().unwrap();
        reg.shutdown();
    }

    #[test]
    fn backpressure_surfaces_as_submit_error() {
        struct Stall;
        impl Backend for Stall {
            fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Fixed(0).infer_batch(images)
            }
            fn name(&self) -> &'static str {
                "stall"
            }
        }
        let reg = ModelRegistry::new();
        reg.register(
            "m",
            std::sync::Arc::new(Stall),
            &ServeConfig {
                max_batch: 1,
                max_wait_us: 10,
                workers: 1,
                queue_cap: 2,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let client = reg.client();
        let mut rejected = 0;
        let mut joins = Vec::new();
        for _ in 0..8 {
            let c = client.clone();
            joins.push(std::thread::spawn(move || {
                matches!(c.try_infer("m", vec![0.0]), Err(RouteError::Submit(_)))
            }));
        }
        for j in joins {
            if j.join().unwrap() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected some rejections under saturation");
        reg.shutdown();
    }
}
