//! Multi-model router: serves several named models (e.g. `digits` and
//! `fashion` linear classifiers, or a linear + MLP pair) behind one
//! client API, each with its own batching pipeline — the multi-tenant
//! shape of a production inference router, applied to the LUT engine.

use super::metrics::Snapshot;
use super::{Backend, Coordinator, Response, SubmitError};
use crate::config::ServeConfig;
use std::collections::BTreeMap;
use std::sync::Arc;

/// A set of independently-batched model pipelines behind one handle.
pub struct Router {
    pipelines: BTreeMap<String, Coordinator>,
}

/// Cloneable multi-model client.
#[derive(Clone)]
pub struct RouterClient {
    clients: BTreeMap<String, super::Client>,
}

/// Routing error.
#[derive(Debug)]
pub enum RouteError {
    UnknownModel(String),
    Submit(SubmitError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            RouteError::Submit(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RouteError {}

impl Router {
    /// Start one pipeline per named backend. Each model gets the same
    /// serving config (per-model configs would be a trivial extension).
    pub fn start(models: Vec<(String, Arc<dyn Backend>)>, cfg: &ServeConfig) -> Router {
        let pipelines = models
            .into_iter()
            .map(|(name, backend)| (name, Coordinator::start(backend, cfg)))
            .collect();
        Router { pipelines }
    }

    pub fn client(&self) -> RouterClient {
        RouterClient {
            clients: self
                .pipelines
                .iter()
                .map(|(n, c)| (n.clone(), c.client()))
                .collect(),
        }
    }

    pub fn models(&self) -> Vec<&str> {
        self.pipelines.keys().map(String::as_str).collect()
    }

    /// Drain every pipeline; returns per-model snapshots.
    pub fn shutdown(self) -> BTreeMap<String, Snapshot> {
        self.pipelines
            .into_iter()
            .map(|(n, c)| (n, c.shutdown()))
            .collect()
    }
}

impl RouterClient {
    /// Route an inference to a named model (blocking).
    pub fn infer(&self, model: &str, image: Vec<f32>) -> Result<Response, RouteError> {
        let client = self
            .clients
            .get(model)
            .ok_or_else(|| RouteError::UnknownModel(model.to_string()))?;
        client.infer_blocking(image).map_err(RouteError::Submit)
    }

    /// Fail-fast variant (backpressure-aware).
    pub fn try_infer(&self, model: &str, image: Vec<f32>) -> Result<Response, RouteError> {
        let client = self
            .clients
            .get(model)
            .ok_or_else(|| RouteError::UnknownModel(model.to_string()))?;
        client.infer(image).map_err(RouteError::Submit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::counters::Counters;

    /// Backend that answers with a fixed class (model identity probe).
    struct Fixed(usize);

    impl Backend for Fixed {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<super::super::InferOutput> {
            images
                .iter()
                .map(|_| super::super::InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "fixed"
        }
    }

    #[test]
    fn routes_to_the_right_model() {
        let router = Router::start(
            vec![
                ("a".to_string(), Arc::new(Fixed(1)) as Arc<dyn Backend>),
                ("b".to_string(), Arc::new(Fixed(2)) as Arc<dyn Backend>),
            ],
            &ServeConfig::default(),
        );
        let client = router.client();
        for _ in 0..20 {
            assert_eq!(client.infer("a", vec![0.0]).unwrap().class, 1);
            assert_eq!(client.infer("b", vec![0.0]).unwrap().class, 2);
        }
        let snaps = router.shutdown();
        assert_eq!(snaps["a"].completed, 20);
        assert_eq!(snaps["b"].completed, 20);
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let router = Router::start(
            vec![("only".to_string(), Arc::new(Fixed(0)) as Arc<dyn Backend>)],
            &ServeConfig::default(),
        );
        let client = router.client();
        match client.infer("nope", vec![0.0]) {
            Err(RouteError::UnknownModel(m)) => assert_eq!(m, "nope"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        router.shutdown();
    }

    #[test]
    fn pipelines_are_isolated() {
        // saturating model 'slow' must not stall model 'fast'
        struct Slow;
        impl Backend for Slow {
            fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<super::super::InferOutput> {
                std::thread::sleep(std::time::Duration::from_millis(20));
                Fixed(9).infer_batch(images)
            }
            fn name(&self) -> &'static str {
                "slow"
            }
        }
        let router = Router::start(
            vec![
                ("slow".to_string(), Arc::new(Slow) as Arc<dyn Backend>),
                ("fast".to_string(), Arc::new(Fixed(3)) as Arc<dyn Backend>),
            ],
            &ServeConfig { max_batch: 1, max_wait_us: 10, workers: 1, queue_cap: 4 },
        );
        let client = router.client();
        // occupy the slow pipeline
        let slow_client = client.clone();
        let h = std::thread::spawn(move || {
            for _ in 0..5 {
                let _ = slow_client.infer("slow", vec![0.0]);
            }
        });
        // fast stays fast
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            assert_eq!(client.infer("fast", vec![0.0]).unwrap().class, 3);
        }
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(50),
            "fast pipeline was blocked by the slow one"
        );
        h.join().unwrap();
        router.shutdown();
    }
}
