//! Serving runtime (Layer 3): a multi-model registry of named,
//! versioned, hot-swappable backends, each behind its own dynamic
//! batching pipeline with bounded-queue backpressure — serving the
//! multiplier-less engine the way an edge fleet deployment would
//! (paper §Concluding remarks: one small table model per task/sensor).
//!
//! Topology (one pipeline per registered model):
//!
//! ```text
//! FleetClient::infer("name", row)
//!      │ registry lookup (live: register/swap/retire visible)
//!      ▼
//! bounded request queue ──► batcher thread (max_batch / max_wait)
//!                               ▼
//!                         batch queue ──► N worker threads
//!                                           │ BackendSlot::get ─ one
//!                                           │ (version, backend) per batch
//!                                           │ Backend::infer_batch_scratch
//!                                           ▼
//!                                per-request response channel
//! ```
//!
//! Invariants (tested, incl. property tests in `rust/tests/`):
//! * no request is lost or duplicated — every submitted request gets
//!   exactly one response (success or a typed [`ServeError`]),
//!   including across [`Coordinator::swap`] hot-swaps, worker panics
//!   and deadline sheds;
//! * a batch executes entirely on ONE backend version: workers take the
//!   `(version, backend)` pair once per batch, so a swap installs the
//!   new version for subsequent batches while in-flight batches finish
//!   on the old one — no batch ever mixes versions;
//! * batches never exceed `max_batch`;
//! * FIFO order is preserved through the batcher (single-worker config
//!   preserves it end-to-end);
//! * the engine op counters aggregated in metrics show zero multiplies,
//!   per model, not just in aggregate.
//!
//! Failure semantics (the self-healing layer):
//! * a request past its [`deadline`](crate::config::ServeConfig::deadline_us)
//!   is shed with [`ServeError::DeadlineExceeded`] at batch formation or
//!   right before execution — it never blocks its caller forever;
//! * a worker panic (backend bug or injected fault) is caught at the
//!   batch perimeter: every request of the panicked batch fails
//!   deterministically with [`ServeError::WorkerPanicked`] (failed, not
//!   re-queued — re-execution could duplicate externally visible work),
//!   the worker survives with a fresh [`Scratch`], and a supervisor
//!   restarts the whole loop if bookkeeping itself ever panics;
//! * after `degrade_after` CONSECUTIVE panics the model is marked
//!   [`HealthState::Degraded`] (latched until a swap installs a new
//!   backend; a successful batch resets the streak but not the latch);
//! * [`Coordinator::swap_checked`] quarantines a candidate backend on a
//!   golden batch before the version bump and rejects it — incumbent
//!   untouched — on panic, output-arity mismatch or non-finite logits.

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod registry;
pub mod router;

use crate::engine::counters::Counters;
use crate::engine::scratch::Scratch;
use crate::engine::{BatchInference, LutModel};
use batcher::{next_batch, BatchPolicy};
use faults::FaultInjector;
use metrics::Metrics;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock that survives a poisoned mutex: a worker panic between lock and
/// unlock must not take down every other worker with `PoisonError`
/// unwraps — the guarded state (channel receiver, slot pair) stays
/// consistent because all writes to it are single assignments.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Inference backend abstraction: the LUT engine, the PJRT reference
/// model, or a test double.
pub trait Backend: Send + Sync + 'static {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput>;

    /// Batched entry point with a worker-owned [`Scratch`]. Backends
    /// with a true batched path (the LUT engine) override this to run
    /// allocation-free; the default ignores the scratch and falls back
    /// to [`Backend::infer_batch`].
    fn infer_batch_scratch(
        &self,
        images: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Vec<InferOutput> {
        let _ = scratch;
        self.infer_batch(images)
    }

    /// Input row width this backend expects, when known. Used for
    /// admission checks and golden-batch synthesis in quarantined
    /// swaps; `None` = unknown/any (the swap self-check is skipped).
    fn input_features(&self) -> Option<usize> {
        None
    }

    fn name(&self) -> &'static str;
}

/// One inference result from a backend.
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub class: usize,
    pub logits: Vec<f32>,
    pub counters: Counters,
}

impl Backend for LutModel {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        let mut scratch = Scratch::new();
        self.infer_batch_scratch(images, &mut scratch)
    }

    /// The real batched path: request rows land in the activation
    /// buffer with ONE copy (`LutModel::infer_batch_rows_into` — no
    /// intermediate flattened staging), every stage executes
    /// batch-at-a-time over the table arenas, and `max_batch > 1` buys
    /// actual throughput instead of a serial loop.
    fn infer_batch_scratch(
        &self,
        images: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Vec<InferOutput> {
        if images.is_empty() {
            return Vec::new();
        }
        let features = images[0].len();
        if images.iter().any(|img| img.len() != features) {
            // heterogeneous rows cannot be batched; serve per sample
            return images
                .iter()
                .map(|img| {
                    let inf = self.infer(img);
                    InferOutput {
                        class: inf.class,
                        logits: inf.logits,
                        counters: inf.counters,
                    }
                })
                .collect();
        }
        let batch = images.len();
        let mut out = BatchInference::default();
        self.infer_batch_rows_into(images, scratch, &mut out);
        let nclass = out.logits.len() / batch;
        (0..batch)
            .map(|s| InferOutput {
                class: out.classes[s],
                logits: out.logits[s * nclass..(s + 1) * nclass].to_vec(),
                // exact per-request attribution: the engine's stage
                // pipeline lands every op on the counter row of the
                // sample that incurred it (tenant billing stays exact
                // under dynamic batching)
                counters: out.per_sample[s],
            })
            .collect()
    }

    fn input_features(&self) -> Option<usize> {
        LutModel::input_features(self)
    }

    fn name(&self) -> &'static str {
        "lut-engine"
    }
}

/// What a request's response channel carries: a served [`Response`] or
/// the typed reason it was not served.
type Verdict = Result<Response, ServeError>;

/// A queued request (or the shutdown sentinel).
enum Request {
    Infer {
        image: Vec<f32>,
        enqueued: Instant,
        /// Absolute expiry; `None` = no deadline.
        deadline: Option<Instant>,
        resp: SyncSender<Verdict>,
    },
    /// Drains the queue up to this point, then stops the pipeline.
    Shutdown,
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub logits: Vec<f32>,
    /// Backend version that served this request (monotonic per
    /// pipeline, 1 = the initially installed backend). Every response
    /// is attributable to exactly one version: the worker executes the
    /// whole batch on the one backend it took from the slot.
    pub version: u64,
    /// Time spent waiting for batch-mates + in the queue.
    pub queue_us: u64,
    /// Total latency submit -> response send.
    pub total_us: u64,
}

/// The hot-swap point of a pipeline: the current `(version, backend)`
/// pair. Workers take the pair once per batch under a short lock, so a
/// batch executes entirely on one version; [`BackendSlot::swap`]
/// installs the next version for all subsequent batches while in-flight
/// batches finish on the Arc they already hold.
struct BackendSlot {
    current: Mutex<(u64, Arc<dyn Backend>)>,
}

impl BackendSlot {
    fn new(backend: Arc<dyn Backend>) -> BackendSlot {
        BackendSlot { current: Mutex::new((1, backend)) }
    }

    fn get(&self) -> (u64, Arc<dyn Backend>) {
        let g = lock_unpoisoned(&self.current);
        (g.0, g.1.clone())
    }

    fn swap(&self, backend: Arc<dyn Backend>) -> u64 {
        let mut g = lock_unpoisoned(&self.current);
        g.0 += 1;
        g.1 = backend;
        g.0
    }
}

/// Typed serving error: every way a submitted request can fail to be
/// served. Nothing here blocks forever and nothing is silently dropped
/// — each variant is counted in the pipeline's metrics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Shed at admission: the bounded request queue is full
    /// (backpressure / load-shedding).
    QueueFull,
    /// Shed in flight: the request's deadline expired after `waited_us`
    /// µs — at batch formation or right before execution.
    DeadlineExceeded { waited_us: u64 },
    /// The worker executing this request's batch panicked; the whole
    /// batch was failed deterministically (never re-queued — a retry
    /// could duplicate externally visible work).
    WorkerPanicked,
    /// The coordinator has shut down.
    ShutDown,
}

/// Pre-fault-tolerance name for [`ServeError`], kept so existing
/// `SubmitError::{QueueFull, ShutDown}` call sites keep compiling.
pub type SubmitError = ServeError;

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "request queue full"),
            ServeError::DeadlineExceeded { waited_us } => {
                write!(f, "deadline exceeded after {waited_us}µs")
            }
            ServeError::WorkerPanicked => write!(f, "worker panicked executing the batch"),
            ServeError::ShutDown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Liveness of one model's pipeline as seen by its panic supervisor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    /// `degrade_after` consecutive worker panics were observed; latched
    /// until a swap installs a new backend.
    Degraded,
}

/// Consecutive-panic tracker behind [`Coordinator::health`].
struct Health {
    consecutive: AtomicU32,
    degraded: AtomicBool,
    /// 0 = never auto-degrade.
    degrade_after: u32,
}

impl Health {
    fn new(degrade_after: u32) -> Health {
        Health {
            consecutive: AtomicU32::new(0),
            degraded: AtomicBool::new(false),
            degrade_after,
        }
    }

    /// A batch executed cleanly: the streak resets, but a latched
    /// Degraded state stays until a new backend is installed (a model
    /// that panics every Nth request must not flap back to Healthy).
    fn on_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
    }

    fn on_panic(&self) {
        let streak = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if self.degrade_after > 0 && streak >= self.degrade_after {
            self.degraded.store(true, Ordering::Relaxed);
        }
    }

    /// A swap installed a fresh backend: clean slate.
    fn reset(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
        self.degraded.store(false, Ordering::Relaxed);
    }

    fn state(&self) -> HealthState {
        if self.degraded.load(Ordering::Relaxed) {
            HealthState::Degraded
        } else {
            HealthState::Healthy
        }
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
    /// Per-request deadline from the pipeline's config; `None` = off.
    deadline: Option<Duration>,
}

impl Client {
    fn request(&self, image: Vec<f32>) -> (Request, Receiver<Verdict>) {
        let (rtx, rrx) = sync_channel(1);
        let enqueued = Instant::now();
        let deadline = self.deadline.map(|d| enqueued + d);
        (Request::Infer { image, enqueued, deadline, resp: rtx }, rrx)
    }

    fn await_verdict(rrx: Receiver<Verdict>) -> Result<Response, ServeError> {
        match rrx.recv() {
            Ok(verdict) => verdict,
            // pipeline dropped the responder without answering: only
            // possible on teardown
            Err(_) => Err(ServeError::ShutDown),
        }
    }

    /// Enqueue without waiting: fails fast with `QueueFull` when
    /// saturated, otherwise returns a [`Pending`] to redeem for the
    /// verdict. This is the decoupled half of [`Client::infer`], used
    /// by callers (the net tier's dispatchers) that submit a whole
    /// batch of rows before collecting any verdict.
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending, ServeError> {
        let (req, rrx) = self.request(image);
        match self.tx.try_send(req) {
            Ok(()) => Ok(Pending { rrx }),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejection();
                Err(ServeError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::ShutDown),
        }
    }

    /// Enqueue without waiting, blocking (no fail-fast) when the queue
    /// is full.
    pub fn submit_blocking(&self, image: Vec<f32>) -> Result<Pending, ServeError> {
        let (req, rrx) = self.request(image);
        self.tx.send(req).map_err(|_| ServeError::ShutDown)?;
        Ok(Pending { rrx })
    }

    /// Submit and wait for the response. Applies backpressure: fails
    /// fast with `QueueFull` instead of blocking when saturated; a
    /// configured deadline bounds the wait with `DeadlineExceeded`.
    pub fn infer(&self, image: Vec<f32>) -> Result<Response, ServeError> {
        self.submit(image)?.wait()
    }

    /// Blocking submit (no fail-fast), still bounded by the queue.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response, ServeError> {
        self.submit_blocking(image)?.wait()
    }

    pub fn metrics(&self) -> metrics::Snapshot {
        self.metrics.snapshot()
    }
}

/// A submitted, not-yet-redeemed request (from [`Client::submit`]).
/// Dropping it without [`wait`](Pending::wait)ing is safe: the
/// pipeline still executes and accounts the request, the verdict is
/// simply discarded.
pub struct Pending {
    rrx: Receiver<Verdict>,
}

impl Pending {
    /// Block for the pipeline's verdict on this request.
    pub fn wait(self) -> Result<Response, ServeError> {
        Client::await_verdict(self.rrx)
    }
}

/// A rejected quarantined swap: why the candidate backend was not
/// installed. The incumbent version is untouched and keeps serving.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SwapRejection {
    pub reason: String,
}

impl std::fmt::Display for SwapRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "swap rejected: {}", self.reason)
    }
}

impl std::error::Error for SwapRejection {}

/// The running coordinator: one model's batching pipeline around a
/// hot-swappable [`BackendSlot`]. Call [`Coordinator::shutdown`] to
/// drain and join all threads (safe even while client clones are still
/// alive — their subsequent submits fail with `ShutDown`).
pub struct Coordinator {
    client: Client,
    slot: Arc<BackendSlot>,
    health: Arc<Health>,
    handles: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start with the given backend (installed as version 1) and
    /// serving config. No fault injection.
    pub fn start(backend: Arc<dyn Backend>, cfg: &crate::config::ServeConfig) -> Coordinator {
        Coordinator::start_with_faults(backend, cfg, None)
    }

    /// Start with an optional deterministic [`FaultInjector`] hooked
    /// into the workers (chaos testing). `None` costs the hot path one
    /// branch.
    pub fn start_with_faults(
        backend: Arc<dyn Backend>,
        cfg: &crate::config::ServeConfig,
        faults: Option<Arc<FaultInjector>>,
    ) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        let slot = Arc::new(BackendSlot::new(backend));
        let health = Arc::new(Health::new(cfg.degrade_after));
        let (req_tx, req_rx) = sync_channel::<Request>(cfg.queue_cap);
        let (batch_tx, batch_rx) =
            sync_channel::<Vec<WorkItem>>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let policy = BatchPolicy::from_cfg(cfg);
        let mut handles = Vec::new();

        // batcher thread
        {
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || {
                batcher_loop(req_rx, batch_tx, policy, metrics);
            }));
        }
        // worker pool, each under a restart supervisor
        for _ in 0..cfg.workers {
            let slot = slot.clone();
            let metrics = metrics.clone();
            let batch_rx = batch_rx.clone();
            let health = health.clone();
            let faults = faults.clone();
            handles.push(std::thread::spawn(move || {
                supervised_worker(batch_rx, slot, metrics, health, faults);
            }));
        }

        let deadline =
            (cfg.deadline_us > 0).then(|| Duration::from_micros(cfg.deadline_us));
        Coordinator {
            client: Client { tx: req_tx, metrics, deadline },
            slot,
            health,
            handles,
        }
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Atomic zero-downtime hot-swap: install `backend` as the next
    /// version. All batches taken after this call execute on the new
    /// backend; batches already in flight finish on the old one (their
    /// workers hold its Arc). No request is lost — the queue and the
    /// pipeline threads are untouched. Clears a Degraded state (the
    /// panicking backend is gone). Returns the new version number.
    pub fn swap(&self, backend: Arc<dyn Backend>) -> u64 {
        self.client.metrics.record_swap();
        let v = self.slot.swap(backend);
        self.health.reset();
        v
    }

    /// Quarantined hot-swap: run the candidate on `golden` rows BEFORE
    /// the version bump and reject it — incumbent untouched, still
    /// serving — if it panics, returns the wrong number of outputs,
    /// produces non-finite logits, or changes the logit arity the
    /// incumbent established. An empty `golden` skips the self-check
    /// (callers without known input geometry fall back to a raw swap).
    ///
    /// The self-check runs inline on the caller (control-plane) thread,
    /// never on the serving workers.
    pub fn swap_checked(
        &self,
        backend: Arc<dyn Backend>,
        golden: &[Vec<f32>],
    ) -> Result<u64, SwapRejection> {
        if !golden.is_empty() {
            let candidate = backend.clone();
            let outputs = catch_unwind(AssertUnwindSafe(|| candidate.infer_batch(golden)))
                .map_err(|_| SwapRejection {
                    reason: "candidate panicked on the golden batch".to_string(),
                })?;
            let reject = |reason: String| Err(SwapRejection { reason });
            if outputs.len() != golden.len() {
                return reject(format!(
                    "candidate returned {} outputs for {} golden rows",
                    outputs.len(),
                    golden.len()
                ));
            }
            for (i, out) in outputs.iter().enumerate() {
                if out.logits.is_empty() {
                    return reject(format!("candidate produced no logits on golden row {i}"));
                }
                if out.logits.iter().any(|v| !v.is_finite()) {
                    return reject(format!(
                        "candidate produced non-finite logits on golden row {i}"
                    ));
                }
            }
            // arity check against the incumbent: clients already consume
            // its logit shape. Logit VALUES are allowed to differ — a
            // new version legitimately changes them. A panicking
            // incumbent (why we're swapping) skips the comparison.
            let (_, incumbent) = self.slot.get();
            if let Ok(reference) =
                catch_unwind(AssertUnwindSafe(|| incumbent.infer_batch(golden)))
            {
                for (i, (cand, inc)) in outputs.iter().zip(&reference).enumerate() {
                    if cand.logits.len() != inc.logits.len() {
                        return reject(format!(
                            "logit arity changed on golden row {i}: incumbent {} vs \
                             candidate {}",
                            inc.logits.len(),
                            cand.logits.len()
                        ));
                    }
                }
            }
        }
        Ok(self.swap(backend))
    }

    /// Currently installed backend version (1 = initial).
    pub fn version(&self) -> u64 {
        self.slot.get().0
    }

    /// Requests served so far — one atomic load, no snapshot cost.
    /// Poll this (not [`Client::metrics`], which clones and sorts the
    /// latency samples) when watching load progress.
    pub fn completed(&self) -> u64 {
        self.client.metrics.completed.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// `Backend::name` of the currently installed backend.
    pub fn backend_name(&self) -> &'static str {
        self.slot.get().1.name()
    }

    /// `Backend::input_features` of the currently installed backend.
    pub fn input_features(&self) -> Option<usize> {
        self.slot.get().1.input_features()
    }

    /// Supervisor's view of the pipeline: Healthy, or Degraded after
    /// `degrade_after` consecutive worker panics (latched until a swap).
    pub fn health(&self) -> HealthState {
        self.health.state()
    }

    /// Graceful shutdown: requests queued before this call are served,
    /// then the pipeline stops and all threads are joined.
    pub fn shutdown(mut self) -> metrics::Snapshot {
        let metrics = self.client.metrics.clone();
        // blocking send: guarantees the sentinel lands even under load
        let _ = self.client.tx.send(Request::Shutdown);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        metrics.snapshot()
    }
}

/// Shed `item` with a typed deadline error if it has expired.
fn shed_if_expired(item: WorkItem, metrics: &Metrics) -> Option<WorkItem> {
    match item.deadline {
        Some(d) if Instant::now() >= d => {
            metrics.record_deadline_shed();
            let waited_us = item.enqueued.elapsed().as_micros() as u64;
            let _ = item.resp.send(Err(ServeError::DeadlineExceeded { waited_us }));
            None
        }
        _ => Some(item),
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    tx: SyncSender<Vec<WorkItem>>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    'outer: while let Some(batch) = next_batch(&rx, policy) {
        let mut items = Vec::with_capacity(batch.len());
        let mut stop = false;
        for req in batch {
            match req {
                Request::Infer { image, enqueued, deadline, resp } => {
                    // deadline gate #1: a request that expired while
                    // queued is shed here instead of wasting a batch
                    // slot (typed response, counted, caller unblocked)
                    let item = WorkItem { image, enqueued, deadline, resp };
                    if let Some(live) = shed_if_expired(item, &metrics) {
                        items.push(live);
                    }
                }
                Request::Shutdown => {
                    stop = true;
                    break;
                }
            }
        }
        if !items.is_empty() {
            metrics.record_batch(items.len());
            if tx.send(items).is_err() {
                break 'outer;
            }
        }
        if stop {
            break 'outer;
        }
    }
    // tx drops here; workers drain remaining batches and exit
}

struct WorkItem {
    image: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    resp: SyncSender<Verdict>,
}

/// Worker under a restart supervisor: a panic that escapes the
/// per-batch `catch_unwind` (bookkeeping bug, poisoned lock recovery
/// path) restarts the loop with fresh state instead of silently
/// shrinking the worker pool. Returns when the batch channel closes.
fn supervised_worker(
    rx: Arc<Mutex<Receiver<Vec<WorkItem>>>>,
    slot: Arc<BackendSlot>,
    metrics: Arc<Metrics>,
    health: Arc<Health>,
    faults: Option<Arc<FaultInjector>>,
) {
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            worker_loop(&rx, &slot, &metrics, &health, faults.as_deref())
        }));
        match run {
            Ok(()) => break, // clean exit: pipeline shut down
            Err(_) => metrics.record_worker_restart(),
        }
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Vec<WorkItem>>>,
    slot: &BackendSlot,
    metrics: &Metrics,
    health: &Health,
    faults: Option<&FaultInjector>,
) {
    // worker-owned scratch: all batched-engine intermediates live here
    // and are reused for the lifetime of the worker — across hot-swaps
    // too (steady-state serving allocates nothing inside the engine)
    let mut scratch = Scratch::new();
    loop {
        let batch = {
            let guard = lock_unpoisoned(rx);
            guard.recv()
        };
        let Ok(batch) = batch else { break };
        let start = Instant::now();
        // deadline gate #2: shed items that expired while the batch sat
        // in the batch queue, then split payloads from bookkeeping
        // without copying image data
        let mut images = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for item in batch {
            if let Some(live) = shed_if_expired(item, metrics) {
                images.push(live.image);
                meta.push((live.enqueued, live.resp));
            }
        }
        if images.is_empty() {
            continue;
        }
        // ONE (version, backend) pair for the whole batch: a concurrent
        // swap changes later batches, never splits this one
        let (version, backend) = slot.get();
        // panic perimeter: a backend bug (or injected fault) must cost
        // exactly this batch, deterministically, not the worker thread
        let executed = catch_unwind(AssertUnwindSafe(|| {
            if let Some(f) = faults {
                f.perturb_batch();
            }
            backend.infer_batch_scratch(&images, &mut scratch)
        }));
        let outputs = match executed {
            Ok(outputs) => outputs,
            Err(payload) => {
                // fail the whole batch with a typed error — never
                // re-queue (a retry could duplicate externally visible
                // work), never leave a caller blocked
                health.on_panic();
                metrics.record_panicked(meta.len() as u64);
                for (_, resp) in meta {
                    let _ = resp.send(Err(ServeError::WorkerPanicked));
                }
                // the panic may have left half-written intermediates
                scratch = Scratch::new();
                drop(payload);
                continue;
            }
        };
        health.on_success();
        let mut outs = outputs.into_iter();
        for (enqueued, resp) in meta {
            match outs.next() {
                Some(out) => {
                    let queue_us = (start - enqueued).as_micros() as u64;
                    let total_us = enqueued.elapsed().as_micros() as u64;
                    metrics.record_request(queue_us as f64, total_us as f64, version, out.counters);
                    let _ = resp.send(Ok(Response {
                        class: out.class,
                        logits: out.logits,
                        version,
                        queue_us,
                        total_us,
                    }));
                }
                // a misbehaving backend returned too few outputs: the
                // unmatched callers still get exactly one (typed)
                // response instead of hanging on a dropped channel
                None => {
                    metrics.record_panicked(1);
                    let _ = resp.send(Err(ServeError::WorkerPanicked));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    /// Echo backend: class = image[0] as usize.
    struct Echo;

    impl Backend for Echo {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|img| InferOutput {
                    class: img[0] as usize,
                    logits: vec![img[0]],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "echo"
        }
    }

    /// Slow backend for backpressure tests.
    struct Slow;

    impl Backend for Slow {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Echo.infer_batch(images)
        }

        fn name(&self) -> &'static str {
            "slow"
        }
    }

    #[test]
    fn round_trips_a_request() {
        let coord = Coordinator::start(Arc::new(Echo), &ServeConfig::default());
        let client = coord.client();
        let r = client.infer(vec![7.0, 0.0]).unwrap();
        assert_eq!(r.class, 7);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.ops.lut_evals, 1);
    }

    #[test]
    fn serves_many_requests_from_many_threads() {
        let coord = Coordinator::start(
            Arc::new(Echo),
            &ServeConfig {
                max_batch: 8,
                max_wait_us: 200,
                workers: 2,
                queue_cap: 256,
                ..ServeConfig::default()
            },
        );
        let mut joins = Vec::new();
        for t in 0..4 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..50 {
                    let v = ((t * 50 + i) % 10) as f32;
                    let r = client.infer_blocking(vec![v]).unwrap();
                    assert_eq!(r.class, v as usize, "wrong response routing");
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 200);
        assert_eq!(snap.rejected, 0);
        // batching actually happened (mean batch > 1 under load) OR the
        // load was too light — accept either but require all batches <= 8
        assert!(snap.mean_batch >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        let coord = Coordinator::start(
            Arc::new(Slow),
            &ServeConfig {
                max_batch: 1,
                max_wait_us: 10,
                workers: 1,
                queue_cap: 2,
                ..ServeConfig::default()
            },
        );
        let client = coord.client();
        let mut rejected = 0;
        let mut joins = Vec::new();
        for _ in 0..8 {
            let c = client.clone();
            joins.push(std::thread::spawn(move || c.infer(vec![1.0]).is_err()));
        }
        for j in joins {
            if j.join().unwrap() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected some rejections under saturation");
        let snap = coord.shutdown();
        assert_eq!(snap.rejected as usize, rejected);
        assert_eq!(snap.completed as usize + rejected, 8);
    }

    #[test]
    fn lut_backend_batched_matches_per_sample() {
        use crate::engine::plan::{AffineMode, EnginePlan};
        use crate::engine::Compiler;
        use crate::nn::Model;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(44);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let images: Vec<Vec<f32>> =
            (0..6).map(|_| (0..784).map(|_| rng.f32()).collect()).collect();
        // UFCS: the trait entry point the coordinator workers use
        let outs = Backend::infer_batch(&lut, &images);
        assert_eq!(outs.len(), images.len());
        let mut total = Counters::default();
        for (s, out) in outs.iter().enumerate() {
            let single = lut.infer(&images[s]);
            assert_eq!(out.class, single.class, "class diverges at {s}");
            assert_eq!(out.logits, single.logits, "logits diverge at {s}");
            // per-request counters are EXACT, not batch-to-first-sample
            assert_eq!(out.counters, single.counters, "counters diverge at {s}");
            total += single.counters;
        }
        let mut agg = Counters::default();
        for o in &outs {
            agg += o.counters;
        }
        assert_eq!(agg, total);
        agg.assert_multiplier_less();
    }

    /// Backend stamping its installed version: class == stamp.
    struct VersionEcho(usize);

    impl Backend for VersionEcho {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters::default(),
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "version-echo"
        }
    }

    #[test]
    fn swap_installs_new_version_for_subsequent_requests() {
        let coord = Coordinator::start(Arc::new(VersionEcho(1)), &ServeConfig::default());
        let client = coord.client();
        let r = client.infer_blocking(vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (1, 1));
        assert_eq!(coord.version(), 1);
        let v2 = coord.swap(Arc::new(VersionEcho(2)));
        assert_eq!(v2, 2);
        assert_eq!(coord.version(), 2);
        // quiesced pipeline: the next batch must run on the new backend
        let r = client.infer_blocking(vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (2, 2));
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.swaps, 1);
    }

    #[test]
    fn swap_loses_no_requests_under_load() {
        let coord = Coordinator::start(
            Arc::new(VersionEcho(1)),
            &ServeConfig {
                max_batch: 8,
                max_wait_us: 100,
                workers: 2,
                queue_cap: 512,
                ..ServeConfig::default()
            },
        );
        let mut joins = Vec::new();
        for _ in 0..4 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                for _ in 0..60 {
                    let r = client.infer_blocking(vec![0.0]).unwrap();
                    // exact attribution: the stamped class IS the
                    // version the coordinator reports
                    assert_eq!(r.class as u64, r.version, "mixed-version response");
                    seen.push(r.version);
                }
                seen
            }));
        }
        for v in 2..=3usize {
            std::thread::sleep(std::time::Duration::from_millis(2));
            coord.swap(Arc::new(VersionEcho(v)));
        }
        let mut versions = Vec::new();
        for j in joins {
            versions.extend(j.join().unwrap());
        }
        assert_eq!(versions.len(), 240, "a request was lost or duplicated");
        assert!(versions.iter().all(|&v| (1..=3).contains(&v)));
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 240);
        assert_eq!(snap.swaps, 2);
    }

    #[test]
    fn shutdown_joins_cleanly_with_no_traffic() {
        let coord = Coordinator::start(Arc::new(Echo), &ServeConfig::default());
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn responses_route_to_correct_callers() {
        // interleave many distinct values; every caller must get its own
        let coord = Coordinator::start(
            Arc::new(Echo),
            &ServeConfig {
                max_batch: 16,
                max_wait_us: 500,
                workers: 1,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        let client = coord.client();
        let results: Vec<(usize, usize)> = (0..32)
            .map(|i| {
                let r = client.infer_blocking(vec![(i % 10) as f32]).unwrap();
                (i % 10, r.class)
            })
            .collect();
        for (want, got) in results {
            assert_eq!(want, got);
        }
        coord.shutdown();
    }

    #[test]
    fn expired_requests_are_shed_with_a_typed_error() {
        // one Slow worker: request A occupies it for ~30ms; request B
        // (10ms deadline) expires in the batch queue and must come back
        // as DeadlineExceeded instead of blocking its caller
        let coord = Coordinator::start(
            Arc::new(Slow),
            &ServeConfig {
                max_batch: 1,
                max_wait_us: 100,
                workers: 1,
                queue_cap: 16,
                deadline_us: 10_000,
                degrade_after: 0,
                ..crate::config::ServeConfig::default()
            },
        );
        let client = coord.client();
        let c = client.clone();
        let first = std::thread::spawn(move || c.infer_blocking(vec![1.0]));
        std::thread::sleep(Duration::from_millis(5));
        match client.infer_blocking(vec![2.0]) {
            Err(ServeError::DeadlineExceeded { waited_us }) => {
                assert!(waited_us >= 10_000, "shed before its deadline: {waited_us}µs")
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let first = first.join().unwrap();
        let snap = coord.shutdown();
        // exactly one verdict each, nothing lost: A served (or itself
        // shed on a pathologically slow machine), B shed
        assert!(snap.deadline_shed >= 1, "{snap:?}");
        assert_eq!(snap.completed + snap.deadline_shed, 2);
        assert_eq!(first.is_ok(), snap.completed == 1);
    }

    /// Panics (with the typed marker) when image[0] < 0, else echoes.
    struct Grenade;

    impl Backend for Grenade {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            if images.iter().any(|img| img[0] < 0.0) {
                std::panic::panic_any(faults::InjectedPanic);
            }
            Echo.infer_batch(images)
        }

        fn name(&self) -> &'static str {
            "grenade"
        }
    }

    #[test]
    fn worker_panics_fail_the_batch_and_latch_degraded() {
        faults::silence_injected_panics();
        let coord = Coordinator::start(
            Arc::new(Grenade),
            &ServeConfig {
                max_batch: 1,
                max_wait_us: 100,
                workers: 1,
                queue_cap: 16,
                deadline_us: 0,
                degrade_after: 2,
                ..crate::config::ServeConfig::default()
            },
        );
        let client = coord.client();
        // a panicked batch fails deterministically; the worker survives
        assert_eq!(client.infer_blocking(vec![3.0]).unwrap().class, 3);
        assert_eq!(client.infer_blocking(vec![-1.0]).unwrap_err(), ServeError::WorkerPanicked);
        assert_eq!(coord.health(), HealthState::Healthy, "one panic is not a streak");
        // a clean batch resets the streak...
        assert_eq!(client.infer_blocking(vec![4.0]).unwrap().class, 4);
        assert_eq!(client.infer_blocking(vec![-1.0]).unwrap_err(), ServeError::WorkerPanicked);
        assert_eq!(coord.health(), HealthState::Healthy);
        // ...but two CONSECUTIVE panics latch Degraded
        assert_eq!(client.infer_blocking(vec![-1.0]).unwrap_err(), ServeError::WorkerPanicked);
        assert_eq!(coord.health(), HealthState::Degraded);
        // latched: a later success still serves but does not clear it
        assert_eq!(client.infer_blocking(vec![5.0]).unwrap().class, 5);
        assert_eq!(coord.health(), HealthState::Degraded);
        // a swap installs a new backend and clears the latch
        coord.swap(Arc::new(Echo));
        assert_eq!(coord.health(), HealthState::Healthy);
        assert_eq!(client.infer_blocking(vec![6.0]).unwrap().class, 6);

        let snap = coord.shutdown();
        assert_eq!(snap.panicked, 3);
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.completed + snap.panicked, 7, "a verdict went missing");
    }

    /// Candidate producing a fixed logit arity.
    struct Arity(usize);

    impl Backend for Arity {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: 0,
                    logits: vec![0.5; self.0],
                    counters: Counters::default(),
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "arity"
        }
    }

    #[test]
    fn quarantined_swap_rejects_bad_candidates_and_keeps_incumbent() {
        faults::silence_injected_panics();
        let coord = Coordinator::start(Arc::new(Arity(1)), &ServeConfig::default());
        let client = coord.client();
        let golden = vec![vec![0.1], vec![0.9]];

        // panicking candidate: rejected, incumbent untouched
        let err = coord.swap_checked(Arc::new(Grenade), &[vec![-1.0]]).unwrap_err();
        assert!(err.reason.contains("panicked"), "{err}");
        assert_eq!(coord.version(), 1);
        assert!(client.infer_blocking(vec![0.2]).is_ok());

        // arity change: clients consume the incumbent's logit shape
        let err = coord.swap_checked(Arc::new(Arity(3)), &golden).unwrap_err();
        assert!(err.reason.contains("arity"), "{err}");
        assert_eq!(coord.version(), 1);

        // well-behaved candidate passes quarantine
        assert_eq!(coord.swap_checked(Arc::new(Arity(1)), &golden).unwrap(), 2);
        assert_eq!(client.infer_blocking(vec![0.2]).unwrap().version, 2);

        // empty golden batch = explicit raw-swap fallback
        assert_eq!(coord.swap_checked(Arc::new(Arity(7)), &[]).unwrap(), 3);
        let snap = coord.shutdown();
        assert_eq!(snap.swaps, 2);
    }
}
