//! Serving runtime (Layer 3): a multi-model registry of named,
//! versioned, hot-swappable backends, each behind its own dynamic
//! batching pipeline with bounded-queue backpressure — serving the
//! multiplier-less engine the way an edge fleet deployment would
//! (paper §Concluding remarks: one small table model per task/sensor).
//!
//! Topology (one pipeline per registered model):
//!
//! ```text
//! FleetClient::infer("name", row)
//!      │ registry lookup (live: register/swap/retire visible)
//!      ▼
//! bounded request queue ──► batcher thread (max_batch / max_wait)
//!                               ▼
//!                         batch queue ──► N worker threads
//!                                           │ BackendSlot::get ─ one
//!                                           │ (version, backend) per batch
//!                                           │ Backend::infer_batch_scratch
//!                                           ▼
//!                                per-request response channel
//! ```
//!
//! Invariants (tested, incl. property tests in `rust/tests/`):
//! * no request is lost or duplicated — every submitted request gets
//!   exactly one response (or an explicit rejection at submit time),
//!   including across [`Coordinator::swap`] hot-swaps;
//! * a batch executes entirely on ONE backend version: workers take the
//!   `(version, backend)` pair once per batch, so a swap installs the
//!   new version for subsequent batches while in-flight batches finish
//!   on the old one — no batch ever mixes versions;
//! * batches never exceed `max_batch`;
//! * FIFO order is preserved through the batcher (single-worker config
//!   preserves it end-to-end);
//! * the engine op counters aggregated in metrics show zero multiplies,
//!   per model, not just in aggregate.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod router;

use crate::engine::counters::Counters;
use crate::engine::scratch::Scratch;
use crate::engine::{BatchInference, LutModel};
use batcher::{next_batch, BatchPolicy};
use metrics::Metrics;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Inference backend abstraction: the LUT engine, the PJRT reference
/// model, or a test double.
pub trait Backend: Send + Sync + 'static {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput>;

    /// Batched entry point with a worker-owned [`Scratch`]. Backends
    /// with a true batched path (the LUT engine) override this to run
    /// allocation-free; the default ignores the scratch and falls back
    /// to [`Backend::infer_batch`].
    fn infer_batch_scratch(
        &self,
        images: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Vec<InferOutput> {
        let _ = scratch;
        self.infer_batch(images)
    }

    fn name(&self) -> &'static str;
}

/// One inference result from a backend.
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub class: usize,
    pub logits: Vec<f32>,
    pub counters: Counters,
}

impl Backend for LutModel {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        let mut scratch = Scratch::new();
        self.infer_batch_scratch(images, &mut scratch)
    }

    /// The real batched path: request rows land in the activation
    /// buffer with ONE copy (`LutModel::infer_batch_rows_into` — no
    /// intermediate flattened staging), every stage executes
    /// batch-at-a-time over the table arenas, and `max_batch > 1` buys
    /// actual throughput instead of a serial loop.
    fn infer_batch_scratch(
        &self,
        images: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Vec<InferOutput> {
        if images.is_empty() {
            return Vec::new();
        }
        let features = images[0].len();
        if images.iter().any(|img| img.len() != features) {
            // heterogeneous rows cannot be batched; serve per sample
            return images
                .iter()
                .map(|img| {
                    let inf = self.infer(img);
                    InferOutput {
                        class: inf.class,
                        logits: inf.logits,
                        counters: inf.counters,
                    }
                })
                .collect();
        }
        let batch = images.len();
        let mut out = BatchInference::default();
        self.infer_batch_rows_into(images, scratch, &mut out);
        let nclass = out.logits.len() / batch;
        (0..batch)
            .map(|s| InferOutput {
                class: out.classes[s],
                logits: out.logits[s * nclass..(s + 1) * nclass].to_vec(),
                // exact per-request attribution: the engine's stage
                // pipeline lands every op on the counter row of the
                // sample that incurred it (tenant billing stays exact
                // under dynamic batching)
                counters: out.per_sample[s],
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "lut-engine"
    }
}

/// A queued request (or the shutdown sentinel).
enum Request {
    Infer {
        image: Vec<f32>,
        enqueued: Instant,
        resp: SyncSender<Response>,
    },
    /// Drains the queue up to this point, then stops the pipeline.
    Shutdown,
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub logits: Vec<f32>,
    /// Backend version that served this request (monotonic per
    /// pipeline, 1 = the initially installed backend). Every response
    /// is attributable to exactly one version: the worker executes the
    /// whole batch on the one backend it took from the slot.
    pub version: u64,
    /// Time spent waiting for batch-mates + in the queue.
    pub queue_us: u64,
    /// Total latency submit -> response send.
    pub total_us: u64,
}

/// The hot-swap point of a pipeline: the current `(version, backend)`
/// pair. Workers take the pair once per batch under a short lock, so a
/// batch executes entirely on one version; [`BackendSlot::swap`]
/// installs the next version for all subsequent batches while in-flight
/// batches finish on the Arc they already hold.
struct BackendSlot {
    current: Mutex<(u64, Arc<dyn Backend>)>,
}

impl BackendSlot {
    fn new(backend: Arc<dyn Backend>) -> BackendSlot {
        BackendSlot { current: Mutex::new((1, backend)) }
    }

    fn get(&self) -> (u64, Arc<dyn Backend>) {
        let g = self.current.lock().unwrap();
        (g.0, g.1.clone())
    }

    fn swap(&self, backend: Arc<dyn Backend>) -> u64 {
        let mut g = self.current.lock().unwrap();
        g.0 += 1;
        g.1 = backend;
        g.0
    }
}

/// Submission error: the queue is full (backpressure) or the
/// coordinator has shut down.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "request queue full"),
            SubmitError::ShutDown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Submit and wait for the response. Applies backpressure: fails
    /// fast with `QueueFull` instead of blocking when saturated.
    pub fn infer(&self, image: Vec<f32>) -> Result<Response, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request::Infer { image, enqueued: Instant::now(), resp: rtx };
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejection();
                return Err(SubmitError::QueueFull);
            }
            Err(TrySendError::Disconnected(_)) => return Err(SubmitError::ShutDown),
        }
        rrx.recv().map_err(|_| SubmitError::ShutDown)
    }

    /// Blocking submit (no fail-fast), still bounded by the queue.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request::Infer { image, enqueued: Instant::now(), resp: rtx };
        self.tx.send(req).map_err(|_| SubmitError::ShutDown)?;
        rrx.recv().map_err(|_| SubmitError::ShutDown)
    }

    pub fn metrics(&self) -> metrics::Snapshot {
        self.metrics.snapshot()
    }
}

/// The running coordinator: one model's batching pipeline around a
/// hot-swappable [`BackendSlot`]. Call [`Coordinator::shutdown`] to
/// drain and join all threads (safe even while client clones are still
/// alive — their subsequent submits fail with `ShutDown`).
pub struct Coordinator {
    client: Client,
    slot: Arc<BackendSlot>,
    handles: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start with the given backend (installed as version 1) and
    /// serving config.
    pub fn start(backend: Arc<dyn Backend>, cfg: &crate::config::ServeConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        let slot = Arc::new(BackendSlot::new(backend));
        let (req_tx, req_rx) = sync_channel::<Request>(cfg.queue_cap);
        let (batch_tx, batch_rx) =
            sync_channel::<Vec<WorkItem>>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let policy = BatchPolicy::from_cfg(cfg);
        let mut handles = Vec::new();

        // batcher thread
        {
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || {
                batcher_loop(req_rx, batch_tx, policy, metrics);
            }));
        }
        // worker pool
        for _ in 0..cfg.workers {
            let slot = slot.clone();
            let metrics = metrics.clone();
            let batch_rx = batch_rx.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(batch_rx, slot, metrics);
            }));
        }

        Coordinator { client: Client { tx: req_tx, metrics }, slot, handles }
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Atomic zero-downtime hot-swap: install `backend` as the next
    /// version. All batches taken after this call execute on the new
    /// backend; batches already in flight finish on the old one (their
    /// workers hold its Arc). No request is lost — the queue and the
    /// pipeline threads are untouched. Returns the new version number.
    pub fn swap(&self, backend: Arc<dyn Backend>) -> u64 {
        self.client.metrics.record_swap();
        self.slot.swap(backend)
    }

    /// Currently installed backend version (1 = initial).
    pub fn version(&self) -> u64 {
        self.slot.get().0
    }

    /// Requests served so far — one atomic load, no snapshot cost.
    /// Poll this (not [`Client::metrics`], which clones and sorts the
    /// latency samples) when watching load progress.
    pub fn completed(&self) -> u64 {
        self.client.metrics.completed.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// `Backend::name` of the currently installed backend.
    pub fn backend_name(&self) -> &'static str {
        self.slot.get().1.name()
    }

    /// Graceful shutdown: requests queued before this call are served,
    /// then the pipeline stops and all threads are joined.
    pub fn shutdown(mut self) -> metrics::Snapshot {
        let metrics = self.client.metrics.clone();
        // blocking send: guarantees the sentinel lands even under load
        let _ = self.client.tx.send(Request::Shutdown);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        metrics.snapshot()
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    tx: SyncSender<Vec<WorkItem>>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    'outer: while let Some(batch) = next_batch(&rx, policy) {
        let mut items = Vec::with_capacity(batch.len());
        let mut stop = false;
        for req in batch {
            match req {
                Request::Infer { image, enqueued, resp } => {
                    items.push((image, enqueued, resp))
                }
                Request::Shutdown => {
                    stop = true;
                    break;
                }
            }
        }
        if !items.is_empty() {
            metrics.record_batch(items.len());
            if tx.send(items).is_err() {
                break 'outer;
            }
        }
        if stop {
            break 'outer;
        }
    }
    // tx drops here; workers drain remaining batches and exit
}

type WorkItem = (Vec<f32>, Instant, SyncSender<Response>);

fn worker_loop(
    rx: Arc<Mutex<Receiver<Vec<WorkItem>>>>,
    slot: Arc<BackendSlot>,
    metrics: Arc<Metrics>,
) {
    // worker-owned scratch: all batched-engine intermediates live here
    // and are reused for the lifetime of the worker — across hot-swaps
    // too (steady-state serving allocates nothing inside the engine)
    let mut scratch = Scratch::new();
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { break };
        let start = Instant::now();
        // split payloads from bookkeeping without copying image data
        let mut images = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for (img, enqueued, resp) in batch {
            images.push(img);
            meta.push((enqueued, resp));
        }
        // ONE (version, backend) pair for the whole batch: a concurrent
        // swap changes later batches, never splits this one
        let (version, backend) = slot.get();
        let outputs = backend.infer_batch_scratch(&images, &mut scratch);
        debug_assert_eq!(outputs.len(), meta.len());
        for ((enqueued, resp), out) in meta.into_iter().zip(outputs) {
            let queue_us = (start - enqueued).as_micros() as u64;
            let total_us = enqueued.elapsed().as_micros() as u64;
            metrics.record_request(queue_us as f64, total_us as f64, out.counters);
            let _ = resp.send(Response {
                class: out.class,
                logits: out.logits,
                version,
                queue_us,
                total_us,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    /// Echo backend: class = image[0] as usize.
    struct Echo;

    impl Backend for Echo {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|img| InferOutput {
                    class: img[0] as usize,
                    logits: vec![img[0]],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "echo"
        }
    }

    /// Slow backend for backpressure tests.
    struct Slow;

    impl Backend for Slow {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Echo.infer_batch(images)
        }

        fn name(&self) -> &'static str {
            "slow"
        }
    }

    #[test]
    fn round_trips_a_request() {
        let coord = Coordinator::start(Arc::new(Echo), &ServeConfig::default());
        let client = coord.client();
        let r = client.infer(vec![7.0, 0.0]).unwrap();
        assert_eq!(r.class, 7);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.ops.lut_evals, 1);
    }

    #[test]
    fn serves_many_requests_from_many_threads() {
        let coord = Coordinator::start(
            Arc::new(Echo),
            &ServeConfig { max_batch: 8, max_wait_us: 200, workers: 2, queue_cap: 256 },
        );
        let mut joins = Vec::new();
        for t in 0..4 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..50 {
                    let v = ((t * 50 + i) % 10) as f32;
                    let r = client.infer_blocking(vec![v]).unwrap();
                    assert_eq!(r.class, v as usize, "wrong response routing");
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 200);
        assert_eq!(snap.rejected, 0);
        // batching actually happened (mean batch > 1 under load) OR the
        // load was too light — accept either but require all batches <= 8
        assert!(snap.mean_batch >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        let coord = Coordinator::start(
            Arc::new(Slow),
            &ServeConfig { max_batch: 1, max_wait_us: 10, workers: 1, queue_cap: 2 },
        );
        let client = coord.client();
        let mut rejected = 0;
        let mut joins = Vec::new();
        for _ in 0..8 {
            let c = client.clone();
            joins.push(std::thread::spawn(move || c.infer(vec![1.0]).is_err()));
        }
        for j in joins {
            if j.join().unwrap() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected some rejections under saturation");
        let snap = coord.shutdown();
        assert_eq!(snap.rejected as usize, rejected);
        assert_eq!(snap.completed as usize + rejected, 8);
    }

    #[test]
    fn lut_backend_batched_matches_per_sample() {
        use crate::engine::plan::{AffineMode, EnginePlan};
        use crate::engine::Compiler;
        use crate::nn::Model;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(44);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let images: Vec<Vec<f32>> =
            (0..6).map(|_| (0..784).map(|_| rng.f32()).collect()).collect();
        // UFCS: the trait entry point the coordinator workers use
        let outs = Backend::infer_batch(&lut, &images);
        assert_eq!(outs.len(), images.len());
        let mut total = Counters::default();
        for (s, out) in outs.iter().enumerate() {
            let single = lut.infer(&images[s]);
            assert_eq!(out.class, single.class, "class diverges at {s}");
            assert_eq!(out.logits, single.logits, "logits diverge at {s}");
            // per-request counters are EXACT, not batch-to-first-sample
            assert_eq!(out.counters, single.counters, "counters diverge at {s}");
            total += single.counters;
        }
        let mut agg = Counters::default();
        for o in &outs {
            agg += o.counters;
        }
        assert_eq!(agg, total);
        agg.assert_multiplier_less();
    }

    /// Backend stamping its installed version: class == stamp.
    struct VersionEcho(usize);

    impl Backend for VersionEcho {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|_| InferOutput {
                    class: self.0,
                    logits: vec![self.0 as f32],
                    counters: Counters::default(),
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "version-echo"
        }
    }

    #[test]
    fn swap_installs_new_version_for_subsequent_requests() {
        let coord = Coordinator::start(Arc::new(VersionEcho(1)), &ServeConfig::default());
        let client = coord.client();
        let r = client.infer_blocking(vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (1, 1));
        assert_eq!(coord.version(), 1);
        let v2 = coord.swap(Arc::new(VersionEcho(2)));
        assert_eq!(v2, 2);
        assert_eq!(coord.version(), 2);
        // quiesced pipeline: the next batch must run on the new backend
        let r = client.infer_blocking(vec![0.0]).unwrap();
        assert_eq!((r.class, r.version), (2, 2));
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.swaps, 1);
    }

    #[test]
    fn swap_loses_no_requests_under_load() {
        let coord = Coordinator::start(
            Arc::new(VersionEcho(1)),
            &ServeConfig { max_batch: 8, max_wait_us: 100, workers: 2, queue_cap: 512 },
        );
        let mut joins = Vec::new();
        for _ in 0..4 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                for _ in 0..60 {
                    let r = client.infer_blocking(vec![0.0]).unwrap();
                    // exact attribution: the stamped class IS the
                    // version the coordinator reports
                    assert_eq!(r.class as u64, r.version, "mixed-version response");
                    seen.push(r.version);
                }
                seen
            }));
        }
        for v in 2..=3usize {
            std::thread::sleep(std::time::Duration::from_millis(2));
            coord.swap(Arc::new(VersionEcho(v)));
        }
        let mut versions = Vec::new();
        for j in joins {
            versions.extend(j.join().unwrap());
        }
        assert_eq!(versions.len(), 240, "a request was lost or duplicated");
        assert!(versions.iter().all(|&v| (1..=3).contains(&v)));
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 240);
        assert_eq!(snap.swaps, 2);
    }

    #[test]
    fn shutdown_joins_cleanly_with_no_traffic() {
        let coord = Coordinator::start(Arc::new(Echo), &ServeConfig::default());
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn responses_route_to_correct_callers() {
        // interleave many distinct values; every caller must get its own
        let coord = Coordinator::start(
            Arc::new(Echo),
            &ServeConfig { max_batch: 16, max_wait_us: 500, workers: 1, queue_cap: 64 },
        );
        let client = coord.client();
        let results: Vec<(usize, usize)> = (0..32)
            .map(|i| {
                let r = client.infer_blocking(vec![(i % 10) as f32]).unwrap();
                (i % 10, r.class)
            })
            .collect();
        for (want, got) in results {
            assert_eq!(want, got);
        }
        coord.shutdown();
    }
}
