//! Serving coordinator (Layer 3): a single-node request router with a
//! dynamic batcher, a worker pool and bounded-queue backpressure —
//! serving the multiplier-less engine the way an edge deployment would
//! (paper §Concluding remarks: sensor-level LUT inference).
//!
//! Topology:
//!
//! ```text
//! Client::infer ──► bounded request queue ──► batcher thread
//!                                              │ (max_batch / max_wait)
//!                                              ▼
//!                                        batch queue ──► N worker threads
//!                                                          │ Backend::infer_batch
//!                                                          ▼
//!                                               per-request response channel
//! ```
//!
//! Invariants (tested, incl. property tests in `rust/tests/`):
//! * no request is lost or duplicated — every submitted request gets
//!   exactly one response (or an explicit rejection at submit time);
//! * batches never exceed `max_batch`;
//! * FIFO order is preserved through the batcher (single-worker config
//!   preserves it end-to-end);
//! * the engine op counters aggregated in metrics show zero multiplies.

pub mod batcher;
pub mod metrics;
pub mod router;

use crate::engine::counters::Counters;
use crate::engine::scratch::Scratch;
use crate::engine::{BatchInference, LutModel};
use batcher::{next_batch, BatchPolicy};
use metrics::Metrics;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Inference backend abstraction: the LUT engine, the PJRT reference
/// model, or a test double.
pub trait Backend: Send + Sync + 'static {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput>;

    /// Batched entry point with a worker-owned [`Scratch`]. Backends
    /// with a true batched path (the LUT engine) override this to run
    /// allocation-free; the default ignores the scratch and falls back
    /// to [`Backend::infer_batch`].
    fn infer_batch_scratch(
        &self,
        images: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Vec<InferOutput> {
        let _ = scratch;
        self.infer_batch(images)
    }

    fn name(&self) -> &'static str;
}

/// One inference result from a backend.
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub class: usize,
    pub logits: Vec<f32>,
    pub counters: Counters,
}

impl Backend for LutModel {
    fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
        let mut scratch = Scratch::new();
        self.infer_batch_scratch(images, &mut scratch)
    }

    /// The real batched path: images are staged contiguously in the
    /// scratch, one `LutModel::infer_batch_into` call executes every
    /// stage batch-at-a-time over the table arenas, and `max_batch > 1`
    /// buys actual throughput instead of a serial loop.
    fn infer_batch_scratch(
        &self,
        images: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Vec<InferOutput> {
        if images.is_empty() {
            return Vec::new();
        }
        let features = images[0].len();
        if images.iter().any(|img| img.len() != features) {
            // heterogeneous rows cannot be batched; serve per sample
            return images
                .iter()
                .map(|img| {
                    let inf = self.infer(img);
                    InferOutput {
                        class: inf.class,
                        logits: inf.logits,
                        counters: inf.counters,
                    }
                })
                .collect();
        }
        let batch = images.len();
        scratch.input.clear();
        for img in images {
            scratch.input.extend_from_slice(img);
        }
        // split the input staging out of the scratch so the stage
        // runner can borrow the remaining buffers mutably
        let input = std::mem::take(&mut scratch.input);
        let mut out = BatchInference::default();
        self.infer_batch_into(&input, batch, scratch, &mut out);
        scratch.input = input;
        let nclass = out.logits.len() / batch;
        (0..batch)
            .map(|s| InferOutput {
                class: out.classes[s],
                logits: out.logits[s * nclass..(s + 1) * nclass].to_vec(),
                // exact per-request attribution: the engine's stage
                // pipeline lands every op on the counter row of the
                // sample that incurred it (tenant billing stays exact
                // under dynamic batching)
                counters: out.per_sample[s],
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "lut-engine"
    }
}

/// A queued request (or the shutdown sentinel).
enum Request {
    Infer {
        image: Vec<f32>,
        enqueued: Instant,
        resp: SyncSender<Response>,
    },
    /// Drains the queue up to this point, then stops the pipeline.
    Shutdown,
}

/// A served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub logits: Vec<f32>,
    /// Time spent waiting for batch-mates + in the queue.
    pub queue_us: u64,
    /// Total latency submit -> response send.
    pub total_us: u64,
}

/// Submission error: the queue is full (backpressure) or the
/// coordinator has shut down.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "request queue full"),
            SubmitError::ShutDown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Submit and wait for the response. Applies backpressure: fails
    /// fast with `QueueFull` instead of blocking when saturated.
    pub fn infer(&self, image: Vec<f32>) -> Result<Response, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request::Infer { image, enqueued: Instant::now(), resp: rtx };
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.record_rejection();
                return Err(SubmitError::QueueFull);
            }
            Err(TrySendError::Disconnected(_)) => return Err(SubmitError::ShutDown),
        }
        rrx.recv().map_err(|_| SubmitError::ShutDown)
    }

    /// Blocking submit (no fail-fast), still bounded by the queue.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request::Infer { image, enqueued: Instant::now(), resp: rtx };
        self.tx.send(req).map_err(|_| SubmitError::ShutDown)?;
        rrx.recv().map_err(|_| SubmitError::ShutDown)
    }

    pub fn metrics(&self) -> metrics::Snapshot {
        self.metrics.snapshot()
    }
}

/// The running coordinator; call [`Coordinator::shutdown`] to drain and
/// join all threads (safe even while client clones are still alive —
/// their subsequent submits fail with `ShutDown`).
pub struct Coordinator {
    client: Client,
    handles: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start with the given backend and serving config.
    pub fn start(backend: Arc<dyn Backend>, cfg: &crate::config::ServeConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        let (req_tx, req_rx) = sync_channel::<Request>(cfg.queue_cap);
        let (batch_tx, batch_rx) =
            sync_channel::<Vec<WorkItem>>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let policy = BatchPolicy::new(cfg.max_batch, cfg.max_wait_us);
        let mut handles = Vec::new();

        // batcher thread
        {
            let metrics = metrics.clone();
            handles.push(std::thread::spawn(move || {
                batcher_loop(req_rx, batch_tx, policy, metrics);
            }));
        }
        // worker pool
        for _ in 0..cfg.workers {
            let backend = backend.clone();
            let metrics = metrics.clone();
            let batch_rx = batch_rx.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(batch_rx, backend, metrics);
            }));
        }

        Coordinator { client: Client { tx: req_tx, metrics }, handles }
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Graceful shutdown: requests queued before this call are served,
    /// then the pipeline stops and all threads are joined.
    pub fn shutdown(mut self) -> metrics::Snapshot {
        let metrics = self.client.metrics.clone();
        // blocking send: guarantees the sentinel lands even under load
        let _ = self.client.tx.send(Request::Shutdown);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        metrics.snapshot()
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    tx: SyncSender<Vec<WorkItem>>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    'outer: while let Some(batch) = next_batch(&rx, policy) {
        let mut items = Vec::with_capacity(batch.len());
        let mut stop = false;
        for req in batch {
            match req {
                Request::Infer { image, enqueued, resp } => {
                    items.push((image, enqueued, resp))
                }
                Request::Shutdown => {
                    stop = true;
                    break;
                }
            }
        }
        if !items.is_empty() {
            metrics.record_batch(items.len());
            if tx.send(items).is_err() {
                break 'outer;
            }
        }
        if stop {
            break 'outer;
        }
    }
    // tx drops here; workers drain remaining batches and exit
}

type WorkItem = (Vec<f32>, Instant, SyncSender<Response>);

fn worker_loop(
    rx: Arc<Mutex<Receiver<Vec<WorkItem>>>>,
    backend: Arc<dyn Backend>,
    metrics: Arc<Metrics>,
) {
    // worker-owned scratch: all batched-engine intermediates live here
    // and are reused for the lifetime of the worker (steady-state
    // serving allocates nothing inside the engine)
    let mut scratch = Scratch::new();
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { break };
        let start = Instant::now();
        // split payloads from bookkeeping without copying image data
        let mut images = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for (img, enqueued, resp) in batch {
            images.push(img);
            meta.push((enqueued, resp));
        }
        let outputs = backend.infer_batch_scratch(&images, &mut scratch);
        debug_assert_eq!(outputs.len(), meta.len());
        for ((enqueued, resp), out) in meta.into_iter().zip(outputs) {
            let queue_us = (start - enqueued).as_micros() as u64;
            let total_us = enqueued.elapsed().as_micros() as u64;
            metrics.record_request(queue_us as f64, total_us as f64, out.counters);
            let _ = resp.send(Response {
                class: out.class,
                logits: out.logits,
                queue_us,
                total_us,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;

    /// Echo backend: class = image[0] as usize.
    struct Echo;

    impl Backend for Echo {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            images
                .iter()
                .map(|img| InferOutput {
                    class: img[0] as usize,
                    logits: vec![img[0]],
                    counters: Counters { lut_evals: 1, ..Default::default() },
                })
                .collect()
        }

        fn name(&self) -> &'static str {
            "echo"
        }
    }

    /// Slow backend for backpressure tests.
    struct Slow;

    impl Backend for Slow {
        fn infer_batch(&self, images: &[Vec<f32>]) -> Vec<InferOutput> {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Echo.infer_batch(images)
        }

        fn name(&self) -> &'static str {
            "slow"
        }
    }

    #[test]
    fn round_trips_a_request() {
        let coord = Coordinator::start(Arc::new(Echo), &ServeConfig::default());
        let client = coord.client();
        let r = client.infer(vec![7.0, 0.0]).unwrap();
        assert_eq!(r.class, 7);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.ops.lut_evals, 1);
    }

    #[test]
    fn serves_many_requests_from_many_threads() {
        let coord = Coordinator::start(
            Arc::new(Echo),
            &ServeConfig { max_batch: 8, max_wait_us: 200, workers: 2, queue_cap: 256 },
        );
        let mut joins = Vec::new();
        for t in 0..4 {
            let client = coord.client();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..50 {
                    let v = ((t * 50 + i) % 10) as f32;
                    let r = client.infer_blocking(vec![v]).unwrap();
                    assert_eq!(r.class, v as usize, "wrong response routing");
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 200);
        assert_eq!(snap.rejected, 0);
        // batching actually happened (mean batch > 1 under load) OR the
        // load was too light — accept either but require all batches <= 8
        assert!(snap.mean_batch >= 1.0);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        let coord = Coordinator::start(
            Arc::new(Slow),
            &ServeConfig { max_batch: 1, max_wait_us: 10, workers: 1, queue_cap: 2 },
        );
        let client = coord.client();
        let mut rejected = 0;
        let mut joins = Vec::new();
        for _ in 0..8 {
            let c = client.clone();
            joins.push(std::thread::spawn(move || c.infer(vec![1.0]).is_err()));
        }
        for j in joins {
            if j.join().unwrap() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected some rejections under saturation");
        let snap = coord.shutdown();
        assert_eq!(snap.rejected as usize, rejected);
        assert_eq!(snap.completed as usize + rejected, 8);
    }

    #[test]
    fn lut_backend_batched_matches_per_sample() {
        use crate::engine::plan::{AffineMode, EnginePlan};
        use crate::engine::Compiler;
        use crate::nn::Model;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(44);
        let model = Model::linear(
            Tensor::randn(&[10, 784], 0.05, &mut rng),
            Tensor::randn(&[10], 0.02, &mut rng),
        );
        let plan = EnginePlan {
            affine: vec![AffineMode::BitplaneFixed { bits: 3, m: 8, range_exp: 0 }],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let lut = Compiler::new(&model).plan(&plan).build().unwrap();
        let images: Vec<Vec<f32>> =
            (0..6).map(|_| (0..784).map(|_| rng.f32()).collect()).collect();
        // UFCS: the trait entry point the coordinator workers use
        let outs = Backend::infer_batch(&lut, &images);
        assert_eq!(outs.len(), images.len());
        let mut total = Counters::default();
        for (s, out) in outs.iter().enumerate() {
            let single = lut.infer(&images[s]);
            assert_eq!(out.class, single.class, "class diverges at {s}");
            assert_eq!(out.logits, single.logits, "logits diverge at {s}");
            // per-request counters are EXACT, not batch-to-first-sample
            assert_eq!(out.counters, single.counters, "counters diverge at {s}");
            total += single.counters;
        }
        let mut agg = Counters::default();
        for o in &outs {
            agg += o.counters;
        }
        assert_eq!(agg, total);
        agg.assert_multiplier_less();
    }

    #[test]
    fn shutdown_joins_cleanly_with_no_traffic() {
        let coord = Coordinator::start(Arc::new(Echo), &ServeConfig::default());
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 0);
    }

    #[test]
    fn responses_route_to_correct_callers() {
        // interleave many distinct values; every caller must get its own
        let coord = Coordinator::start(
            Arc::new(Echo),
            &ServeConfig { max_batch: 16, max_wait_us: 500, workers: 1, queue_cap: 64 },
        );
        let client = coord.client();
        let results: Vec<(usize, usize)> = (0..32)
            .map(|i| {
                let r = client.infer_blocking(vec![(i % 10) as f32]).unwrap();
                (i % 10, r.class)
            })
            .collect();
        for (want, got) in results {
            assert_eq!(want, got);
        }
        coord.shutdown();
    }
}
