//! Serving metrics: latency percentiles, throughput, queue rejections,
//! batch-size distribution and aggregate engine op counters (so a serve
//! run can report "x lookups, y shift-adds, 0 multiplies" end-to-end).
//! Per-model [`Snapshot`]s roll up into a [`FleetSnapshot`] when the
//! registry serves several models.

use crate::engine::counters::Counters;
use crate::util::percentile;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shared metrics sink. Cheap to update from workers; snapshot on demand.
pub struct Metrics {
    started: Instant,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub swaps: AtomicU64,
    /// Requests shed with `DeadlineExceeded`.
    pub deadline_shed: AtomicU64,
    /// Requests failed with `WorkerPanicked`.
    pub panicked: AtomicU64,
    /// Worker threads restarted by the panic supervisor.
    pub worker_restarts: AtomicU64,
    batch_items: AtomicU64,
    ops: Mutex<Counters>,
    /// total latency in µs, and per-request samples for percentiles
    latency_us: Mutex<Vec<f64>>,
    queue_us: Mutex<Vec<f64>>,
    /// Per-artifact-version latency sub-histograms, so a hot-swap's
    /// before/after distributions stay separable in one run.
    versions: Mutex<BTreeMap<u64, VersionAgg>>,
}

/// Accumulator behind one artifact version's sub-histogram.
#[derive(Debug, Default)]
struct VersionAgg {
    requests: u64,
    lat_us: Vec<f64>,
}

/// Point-in-time latency summary for one artifact version of a model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct VersionLatency {
    /// Requests completed while this version was installed.
    pub requests: u64,
    /// Median end-to-end latency in µs for this version's requests.
    pub p50_us: f64,
    /// 99th-percentile end-to-end latency in µs for this version.
    pub p99_us: f64,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    /// Hot-swaps installed over the pipeline's lifetime.
    pub swaps: u64,
    /// Requests shed with a typed `DeadlineExceeded`.
    pub deadline_shed: u64,
    /// Requests failed with a typed `WorkerPanicked`.
    pub panicked: u64,
    /// Worker threads restarted by the panic supervisor.
    pub worker_restarts: u64,
    pub mean_batch: f64,
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub queue_p95_us: f64,
    pub ops: Counters,
    /// Latency sub-histograms keyed by the artifact version that served
    /// each request — distinct pre-/post-swap distributions survive a
    /// hot-swap instead of blurring into one histogram.
    pub versions: BTreeMap<u64, VersionLatency>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            deadline_shed: AtomicU64::new(0),
            panicked: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            ops: Mutex::new(Counters::default()),
            latency_us: Mutex::new(Vec::new()),
            queue_us: Mutex::new(Vec::new()),
            versions: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    const MAX_SAMPLES: usize = 100_000;
    /// Per-version sample cap — bounded even if one version serves the
    /// whole run.
    const MAX_VERSION_SAMPLES: usize = 50_000;

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_request(&self, queue_us: f64, total_us: f64, version: u64, ops: Counters) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        {
            let mut l = self.latency_us.lock().unwrap();
            if l.len() < Self::MAX_SAMPLES {
                l.push(total_us);
            }
        }
        {
            let mut q = self.queue_us.lock().unwrap();
            if q.len() < Self::MAX_SAMPLES {
                q.push(queue_us);
            }
        }
        {
            let mut v = self.versions.lock().unwrap();
            let agg = v.entry(version).or_default();
            agg.requests += 1;
            if agg.lat_us.len() < Self::MAX_VERSION_SAMPLES {
                agg.lat_us.push(total_us);
            }
        }
        *self.ops.lock().unwrap() += ops;
    }

    pub fn record_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap(&self) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_shed(&self) {
        self.deadline_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_panicked(&self, requests: u64) {
        self.panicked.fetch_add(requests, Ordering::Relaxed);
    }

    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let lat = self.latency_us.lock().unwrap().clone();
        let q = self.queue_us.lock().unwrap().clone();
        let versions = {
            let v = self.versions.lock().unwrap();
            v.iter()
                .map(|(ver, agg)| {
                    (
                        *ver,
                        VersionLatency {
                            requests: agg.requests,
                            p50_us: percentile(&agg.lat_us, 50.0),
                            p99_us: percentile(&agg.lat_us, 99.0),
                        },
                    )
                })
                .collect()
        };
        let elapsed = self.started.elapsed().as_secs_f64();
        Snapshot {
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            swaps: self.swaps.load(Ordering::Relaxed),
            deadline_shed: self.deadline_shed.load(Ordering::Relaxed),
            panicked: self.panicked.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            mean_batch: if batches > 0 { items as f64 / batches as f64 } else { 0.0 },
            elapsed_s: elapsed,
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
            latency_p50_us: percentile(&lat, 50.0),
            latency_p95_us: percentile(&lat, 95.0),
            latency_p99_us: percentile(&lat, 99.0),
            queue_p95_us: percentile(&q, 95.0),
            ops: *self.ops.lock().unwrap(),
            versions,
        }
    }
}

/// One model's snapshot plus its registry identity (installed version
/// and backend name) at snapshot time.
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    /// Monotonic backend version installed when the snapshot was taken
    /// (1 for the initially registered backend).
    pub version: u64,
    /// `Backend::name` of the installed backend.
    pub backend: String,
    /// Degraded = the panic supervisor latched `degrade_after`
    /// consecutive worker panics (cleared by the next swap).
    pub degraded: bool,
    pub stats: Snapshot,
}

/// Per-model snapshots rolled up across the registry, plus fleet-level
/// totals derived from them.
#[derive(Debug, Clone, Default)]
pub struct FleetSnapshot {
    pub models: BTreeMap<String, ModelSnapshot>,
    /// Socket-ingress accounting, present only when a network serving
    /// tier (`serve --listen`) fronted the registry for this run.
    pub net: Option<crate::net::NetSnapshot>,
}

impl FleetSnapshot {
    pub fn completed(&self) -> u64 {
        self.models.values().map(|m| m.stats.completed).sum()
    }

    pub fn rejected(&self) -> u64 {
        self.models.values().map(|m| m.stats.rejected).sum()
    }

    pub fn swaps(&self) -> u64 {
        self.models.values().map(|m| m.stats.swaps).sum()
    }

    pub fn deadline_shed(&self) -> u64 {
        self.models.values().map(|m| m.stats.deadline_shed).sum()
    }

    pub fn panicked(&self) -> u64 {
        self.models.values().map(|m| m.stats.panicked).sum()
    }

    /// Names of models currently marked Degraded, name-sorted.
    pub fn degraded(&self) -> Vec<&str> {
        self.models
            .iter()
            .filter(|(_, m)| m.degraded)
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// Aggregate op mix across every model.
    pub fn ops(&self) -> Counters {
        let mut total = Counters::default();
        for m in self.models.values() {
            total += m.stats.ops;
        }
        total
    }

    /// The multiplier-less invariant must hold **per model**, not just
    /// in aggregate — a multiply in one tenant cannot hide behind
    /// another tenant's clean counters.
    pub fn assert_multiplier_less(&self) {
        for (name, m) in &self.models {
            assert_eq!(m.stats.ops.mults, 0, "model '{name}' recorded multiplies");
        }
    }
}

impl std::fmt::Display for FleetSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (name, m) in &self.models {
            writeln!(
                f,
                "[{name} v{} · {}{}]",
                m.version,
                m.backend,
                if m.degraded { " · DEGRADED" } else { "" }
            )?;
            writeln!(f, "{}", m.stats)?;
        }
        write!(
            f,
            "fleet: {} models | {} ok, {} rejected, {} swaps | ops {}",
            self.models.len(),
            self.completed(),
            self.rejected(),
            self.swaps(),
            self.ops()
        )?;
        let degraded = self.degraded();
        if !degraded.is_empty() {
            write!(f, "\nfleet: DEGRADED models: {degraded:?}")?;
        }
        if let Some(net) = &self.net {
            write!(f, "\n{net}")?;
        }
        Ok(())
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: {} ok, {} rejected | batches: {} (mean {:.1})",
            self.completed, self.rejected, self.batches, self.mean_batch
        )?;
        if self.deadline_shed > 0 || self.panicked > 0 || self.worker_restarts > 0 {
            writeln!(
                f,
                "faults: {} deadline-shed, {} panic-failed | {} worker restarts",
                self.deadline_shed, self.panicked, self.worker_restarts
            )?;
        }
        writeln!(
            f,
            "latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0} | queue p95 {:.0}",
            self.latency_p50_us, self.latency_p95_us, self.latency_p99_us, self.queue_p95_us
        )?;
        // one sub-histogram line per artifact version once a swap has
        // split traffic across versions
        if self.versions.len() > 1 {
            for (ver, v) in &self.versions {
                writeln!(
                    f,
                    "  v{ver}: {} reqs | p50 {:.0}µs p99 {:.0}µs",
                    v.requests, v.p50_us, v.p99_us
                )?;
            }
        }
        writeln!(f, "throughput: {:.1} req/s", self.throughput_rps)?;
        write!(f, "engine ops: {}", self.ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_batch(2);
        for i in 0..6 {
            m.record_request(
                10.0,
                100.0 + i as f64,
                1,
                Counters { lut_evals: 5, ..Default::default() },
            );
        }
        m.record_rejection();
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert_eq!(s.ops.lut_evals, 30);
        assert!(s.latency_p50_us >= 100.0);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.latency_p99_us, 0.0);
    }

    #[test]
    fn display_contains_key_fields() {
        let m = Metrics::default();
        m.record_request(1.0, 2.0, 1, Counters::default());
        let text = format!("{}", m.snapshot());
        assert!(text.contains("mults=0"));
        assert!(text.contains("throughput"));
    }

    #[test]
    fn per_version_sub_histograms_stay_distinct_across_a_swap() {
        let m = Metrics::default();
        // v1 serves slow requests, then a swap installs a faster v2
        for _ in 0..8 {
            m.record_request(1.0, 900.0, 1, Counters::default());
        }
        m.record_swap();
        for _ in 0..8 {
            m.record_request(1.0, 40.0, 2, Counters::default());
        }
        let s = m.snapshot();
        assert_eq!(s.versions.len(), 2);
        assert_eq!(s.versions[&1].requests, 8);
        assert_eq!(s.versions[&2].requests, 8);
        assert!(s.versions[&1].p50_us > s.versions[&2].p50_us * 10.0, "{:?}", s.versions);
        let text = format!("{s}");
        assert!(text.contains("v1: 8 reqs"), "{text}");
        assert!(text.contains("v2: 8 reqs"), "{text}");

        // a single-version run keeps the display free of per-version noise
        let single = Metrics::default();
        single.record_request(1.0, 2.0, 1, Counters::default());
        let text = format!("{}", single.snapshot());
        assert!(!text.contains("v1:"), "{text}");
    }

    #[test]
    fn fleet_rollup_sums_models() {
        let mk = |n: u64| {
            let m = Metrics::default();
            for _ in 0..n {
                m.record_request(1.0, 2.0, 2, Counters { lut_evals: 3, ..Default::default() });
            }
            m.record_swap();
            ModelSnapshot {
                version: 2,
                backend: "echo".into(),
                degraded: false,
                stats: m.snapshot(),
            }
        };
        let mut fleet = FleetSnapshot::default();
        fleet.models.insert("a".into(), mk(4));
        fleet.models.insert("b".into(), mk(6));
        assert_eq!(fleet.completed(), 10);
        assert_eq!(fleet.swaps(), 2);
        assert_eq!(fleet.ops().lut_evals, 30);
        fleet.assert_multiplier_less();
        let text = format!("{fleet}");
        assert!(text.contains("[a v2 · echo]"), "{text}");
        assert!(text.contains("fleet: 2 models"), "{text}");
    }

    #[test]
    fn fleet_display_folds_in_net_snapshot_when_present() {
        let mut fleet = FleetSnapshot::default();
        assert!(!format!("{fleet}").contains("net:"), "no net tier, no net section");
        fleet.net = Some(crate::net::NetSnapshot {
            connections_accepted: 3,
            frames_in: 12,
            frames_out: 12,
            ..Default::default()
        });
        let text = format!("{fleet}");
        assert!(text.contains("net: 3 conns"), "{text}");
        assert!(text.contains("admission:"), "{text}");
    }

    #[test]
    #[should_panic(expected = "recorded multiplies")]
    fn fleet_multiplier_invariant_is_per_model() {
        let m = Metrics::default();
        m.record_request(1.0, 2.0, 1, Counters { mults: 1, ..Default::default() });
        let mut fleet = FleetSnapshot::default();
        fleet.models.insert(
            "dirty".into(),
            ModelSnapshot {
                version: 1,
                backend: "x".into(),
                degraded: false,
                stats: m.snapshot(),
            },
        );
        fleet.assert_multiplier_less();
    }

    #[test]
    fn fault_counters_and_degraded_banner_surface() {
        let m = Metrics::default();
        m.record_request(1.0, 2.0, 1, Counters::default());
        // healthy pipeline: no fault line in the snapshot display
        assert!(!format!("{}", m.snapshot()).contains("faults:"));
        m.record_deadline_shed();
        m.record_deadline_shed();
        m.record_panicked(3);
        m.record_worker_restart();
        let s = m.snapshot();
        assert_eq!((s.deadline_shed, s.panicked, s.worker_restarts), (2, 3, 1));
        let text = format!("{s}");
        assert!(text.contains("2 deadline-shed"), "{text}");
        assert!(text.contains("3 panic-failed"), "{text}");

        let mut fleet = FleetSnapshot::default();
        fleet.models.insert(
            "sick".into(),
            ModelSnapshot { version: 1, backend: "x".into(), degraded: true, stats: s },
        );
        assert_eq!(fleet.deadline_shed(), 2);
        assert_eq!(fleet.panicked(), 3);
        assert_eq!(fleet.degraded(), vec!["sick"]);
        let text = format!("{fleet}");
        assert!(text.contains("[sick v1 · x · DEGRADED]"), "{text}");
        assert!(text.contains("DEGRADED models: [\"sick\"]"), "{text}");
    }
}
