//! Serving metrics: latency percentiles, throughput, queue rejections,
//! batch-size distribution and aggregate engine op counters (so a serve
//! run can report "x lookups, y shift-adds, 0 multiplies" end-to-end).

use crate::engine::counters::Counters;
use crate::util::percentile;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shared metrics sink. Cheap to update from workers; snapshot on demand.
pub struct Metrics {
    started: Instant,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    batch_items: AtomicU64,
    ops: Mutex<Counters>,
    /// total latency in µs, and per-request samples for percentiles
    latency_us: Mutex<Vec<f64>>,
    queue_us: Mutex<Vec<f64>>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub queue_p95_us: f64,
    pub ops: Counters,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            ops: Mutex::new(Counters::default()),
            latency_us: Mutex::new(Vec::new()),
            queue_us: Mutex::new(Vec::new()),
        }
    }
}

impl Metrics {
    const MAX_SAMPLES: usize = 100_000;

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn record_request(&self, queue_us: f64, total_us: f64, ops: Counters) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        {
            let mut l = self.latency_us.lock().unwrap();
            if l.len() < Self::MAX_SAMPLES {
                l.push(total_us);
            }
        }
        {
            let mut q = self.queue_us.lock().unwrap();
            if q.len() < Self::MAX_SAMPLES {
                q.push(queue_us);
            }
        }
        *self.ops.lock().unwrap() += ops;
    }

    pub fn record_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let lat = self.latency_us.lock().unwrap().clone();
        let q = self.queue_us.lock().unwrap().clone();
        let elapsed = self.started.elapsed().as_secs_f64();
        Snapshot {
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches > 0 { items as f64 / batches as f64 } else { 0.0 },
            elapsed_s: elapsed,
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
            latency_p50_us: percentile(&lat, 50.0),
            latency_p95_us: percentile(&lat, 95.0),
            latency_p99_us: percentile(&lat, 99.0),
            queue_p95_us: percentile(&q, 95.0),
            ops: *self.ops.lock().unwrap(),
        }
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: {} ok, {} rejected | batches: {} (mean {:.1})",
            self.completed, self.rejected, self.batches, self.mean_batch
        )?;
        writeln!(
            f,
            "latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0} | queue p95 {:.0}",
            self.latency_p50_us, self.latency_p95_us, self.latency_p99_us, self.queue_p95_us
        )?;
        writeln!(f, "throughput: {:.1} req/s", self.throughput_rps)?;
        write!(f, "engine ops: {}", self.ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_batch(2);
        for i in 0..6 {
            m.record_request(
                10.0,
                100.0 + i as f64,
                Counters { lut_evals: 5, ..Default::default() },
            );
        }
        m.record_rejection();
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert_eq!(s.ops.lut_evals, 30);
        assert!(s.latency_p50_us >= 100.0);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.latency_p99_us, 0.0);
    }

    #[test]
    fn display_contains_key_fields() {
        let m = Metrics::default();
        m.record_request(1.0, 2.0, Counters::default());
        let text = format!("{}", m.snapshot());
        assert!(text.contains("mults=0"));
        assert!(text.contains("throughput"));
    }
}
