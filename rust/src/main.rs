//! `tablenet` — CLI launcher for the TableNet reproduction.
//!
//! Subcommands:
//!   gen-data          generate + cache the synthetic corpora (IDX files)
//!   train             in-Rust SGD training (linear / mlp)
//!   compile           compile weights + plan into a .ltm artifact
//!   inspect           dump a .ltm artifact (plan, stages, table sizes)
//!   eval              accuracy: LUT engine vs reference, with op counters
//!   sweep-bits        Fig 4 / Fig 6 accuracy-vs-input-bits sweep
//!   sweep-partitions  Fig 5 / 7 / 8 size-vs-ops tradeoff tables
//!   plan              planner tables + paper in-text config check
//!   serve             multi-model registry serving (artifact-first,
//!                     pure-push; optional dataset-driven load + mid-run
//!                     hot swaps; --listen adds the socket serving tier)
//!   client            wire-protocol load generator for `serve --listen`
//!   ref-check         PJRT reference artifact vs in-Rust forward

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::data::synth::Kind;
use tablenet::data::{load_or_generate, Dataset};
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::{Compiler, LutModel};
use tablenet::harness;
use tablenet::nn::{weights, Arch, Model};
use tablenet::planner;
use tablenet::tensor::Tensor;
use tablenet::train::{train_dense, TrainConfig};
use tablenet::util::{fmt_bits, fmt_ops};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "gen-data" => gen_data(args),
        "train" => train(args),
        "compile" => compile(args),
        "inspect" => inspect(args),
        "eval" => eval(args),
        "sweep-bits" => sweep_bits(args),
        "sweep-partitions" => sweep_partitions(args),
        "plan" => plan(args),
        "serve" => serve(args),
        "client" => client_cmd(args),
        "ref-check" => ref_check(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "tablenet — multiplier-less LUT inference (TableNet reproduction)\n\n\
         usage: tablenet <cmd> [--flags]\n\n\
         commands:\n\
         \x20 gen-data         --dir data/synth --train 4000 --test 1000 --seed 7\n\
         \x20 train            --arch linear|mlp --dataset mnist|fashion --steps N --out w.bin\n\
         \x20 compile          --arch A --weights w.bin [--plan plan.json] --out model.ltm\n\
         \x20                  [--no-fuse]  (skip the stage-folding optimizer: keep the naive\n\
         \x20                   1:1 lowering instead of folding elementwise chains into banks)\n\
         \x20 inspect          model.ltm   (fused banks print as e.g. dense-float+relu-int+to-half)\n\
         \x20 eval             --arch A --weights w.bin --dataset D [--plan plan.json] [--artifact model.ltm] [--n 500]\n\
         \x20 sweep-bits       --arch linear --weights w.bin --dataset D [--csv-out f.csv]\n\
         \x20 sweep-partitions --arch linear|mlp|cnn [--weights w.bin --dataset D]\n\
         \x20 plan             [--arch A]\n\
         \x20 serve            --artifact name=model.ltm [--artifact n2=m2.ltm ...] [--fleet fleet.json]\n\
         \x20                  [--swap name=new.ltm] --requests 2000 [--clients 4] [--max-batch 32]\n\
         \x20                  [--dir data/synth]  (pure-push from artifacts alone when --dir is omitted)\n\
         \x20                  [--watch-dir deploy/] [--watch-interval-ms 200] [--client-delay-ms 0]\n\
         \x20                  [--deadline-us 0] [--degrade-after 3] [--fault-plan seed=7,panic_prob=0.02]\n\
         \x20                  (--watch-dir: auto-register new .ltm files by stem and hot-swap\n\
         \x20                   models whose file content changes — config-free rolling deploys;\n\
         \x20                   failed deploys retry with capped exponential backoff)\n\
         \x20                  (--deadline-us: shed requests older than the deadline; --degrade-after:\n\
         \x20                   mark a model Degraded after N consecutive worker panics; --fault-plan:\n\
         \x20                   deterministic chaos — injected latency / worker panics, see faults.rs)\n\
         \x20                  [--listen ADDR] [--net-threads N] [--admission-budget ROWS]\n\
         \x20                  [--admission-weight W] [--auth-token SECRET | --insecure-no-auth]\n\
         \x20                  [--max-conns N] [--frame-rate-limit F/S] [--row-rate-limit R/S]\n\
         \x20                  [--drain-grace-ms 5000] [--drain] [--watch-retire-on-delete]\n\
         \x20                  (--listen: also serve the LTN1 wire protocol on ADDR with a\n\
         \x20                   thread-per-core reactor tier; --requests then counts rows answered\n\
         \x20                   over the wire, 0 = serve until SIGTERM/SIGINT; --admission-budget\n\
         \x20                   caps aggregate in-flight rows across all models, split by\n\
         \x20                   per-model --admission-weight)\n\
         \x20                  (exposed binds require --auth-token unless --insecure-no-auth;\n\
         \x20                   every exit is a graceful drain: GoAway on each connection,\n\
         \x20                   in-flight rows finish within --drain-grace-ms, ledger balanced;\n\
         \x20                   --drain drains immediately — a deterministic stand-in for SIGTERM;\n\
         \x20                   --watch-retire-on-delete retires a model when its watched .ltm\n\
         \x20                   file is deleted)\n\
         \x20 client           --addr HOST:PORT --model NAME [--requests 1000] [--connections 2]\n\
         \x20                  [--rows-per-frame 16] [--features 784] [--retry-budget N]\n\
         \x20                  [--auth-token SECRET] [--client-id ID]\n\
         \x20                  (load-generate against a serve --listen tier; sheds are typed and\n\
         \x20                   tolerated, any LOST or DUPLICATE row exits non-zero;\n\
         \x20                   --retry-budget: reconnect across drops/restarts/drains with\n\
         \x20                   idempotency-keyed requests — acknowledged rows stay exactly-once)\n\
         \x20 ref-check        --arch A --weights w.bin --hlo artifacts/linear_ref_b1.hlo.txt"
    );
}

fn data_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("dir", "data/synth"))
}

fn dataset(args: &Args) -> Result<Dataset> {
    let kind = Kind::parse(args.get_or("dataset", "mnist"))
        .ok_or_else(|| anyhow!("unknown dataset (mnist|fashion)"))?;
    let n_train = args.get_usize("train", 4000);
    let n_test = args.get_usize("test", 1000);
    load_or_generate(&data_dir(args), kind, n_train, n_test, args.get_u64("seed", 7))
}

fn arch(args: &Args) -> Result<Arch> {
    Arch::parse(args.get_or("arch", "linear"))
        .ok_or_else(|| anyhow!("unknown arch (linear|mlp|cnn)"))
}

fn load_model(args: &Args) -> Result<Model> {
    let a = arch(args)?;
    let path = PathBuf::from(
        args.get("weights")
            .map(str::to_string)
            .unwrap_or_else(|| format!("artifacts/weights_{}.bin", a.name())),
    );
    weights::load_model(a, &path).with_context(|| {
        format!(
            "loading {} (run `make artifacts` or `tablenet train`)",
            path.display()
        )
    })
}

fn plan_from_args(args: &Args, a: Arch) -> Result<EnginePlan> {
    match args.get("plan") {
        None => Ok(EnginePlan::default_for(a)),
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let j = tablenet::config::json::Json::parse(&text)
                .map_err(|e| anyhow!("{path}: {e}"))?;
            tablenet::config::plan_from_json(&j)
        }
    }
}

fn gen_data(args: &Args) -> Result<()> {
    let dir = data_dir(args);
    let n_train = args.get_usize("train", 4000);
    let n_test = args.get_usize("test", 1000);
    let seed = args.get_u64("seed", 7);
    for kind in [Kind::Digits, Kind::Fashion] {
        let ds = load_or_generate(&dir, kind, n_train, n_test, seed)?;
        println!(
            "{}: train {} / test {} samples in {}",
            kind.name(),
            ds.train.len(),
            ds.test.len(),
            dir.display()
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let a = arch(args)?;
    let ds = dataset(args)?;
    let widths: Vec<usize> = match a {
        Arch::Linear => vec![784, 10],
        Arch::Mlp => vec![784, 1024, 512, 10],
        Arch::Cnn => bail!("CNN training runs in JAX: `make artifacts`"),
    };
    let cfg = TrainConfig {
        steps: args.get_usize("steps", if a == Arch::Linear { 3000 } else { 800 }),
        lr: args.get_f64("lr", 0.2) as f32,
        batch: args.get_usize("batch", 100),
        seed: args.get_u64("seed", 0x7AB1E7),
        input_bits: args.get("input-bits").and_then(|v| v.parse().ok()),
        weight_decay: args.get_f64("weight-decay", 1e-4) as f32,
        log_every: args.get_usize("log-every", 200),
    };
    eprintln!("training {} on {} ({} steps)...", a.name(), ds.kind.name(), cfg.steps);
    let model = train_dense(&ds.train, &widths, &cfg);
    let x = Tensor::new(&[ds.test.len(), 784], ds.test.images.clone());
    println!("test accuracy: {:.2}%", model.accuracy(&x, &ds.test.labels) * 100.0);
    if let Some(out) = args.get("out") {
        let mut map = weights::WeightMap::new();
        for (i, layer) in model
            .layers
            .iter()
            .filter_map(|l| match l {
                tablenet::nn::Layer::Dense { w, b } => Some((w, b)),
                _ => None,
            })
            .enumerate()
        {
            map.insert(format!("fc{}.w", i + 1), layer.0.clone());
            map.insert(format!("fc{}.b", i + 1), layer.1.clone());
        }
        weights::save(Path::new(out), &map)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Compile weights + plan into a servable `.ltm` artifact.
fn compile(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let plan = plan_from_args(args, model.arch)?;
    let lut = Compiler::new(&model)
        .plan(&plan)
        .fuse(!args.has("no-fuse"))
        .build()
        .map_err(|e| anyhow!("plan not materialisable: {e}"))?;
    let out = PathBuf::from(
        args.get("out")
            .map(str::to_string)
            .unwrap_or_else(|| format!("artifacts/model_{}.ltm", model.arch.name())),
    );
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    lut.save(&out)?;
    let (chains, folded) = lut.stages().iter().filter_map(|s| s.fused_chain()).fold(
        (0usize, 0usize),
        |(c, f), chain| (c + 1, f + chain.len()),
    );
    println!(
        "wrote {} ({} stages, {} of tables at r_o={})",
        out.display(),
        lut.num_stages(),
        fmt_bits(lut.size_bits()),
        lut.plan().r_o
    );
    if args.has("no-fuse") {
        println!("  fusion: disabled (--no-fuse), naive 1:1 lowering");
    } else if chains > 0 {
        println!(
            "  fusion: {folded} elementwise stage{} folded into {chains} bank{}",
            if folded == 1 { "" } else { "s" },
            if chains == 1 { "" } else { "s" }
        );
    } else {
        println!("  fusion: on, no foldable elementwise chains");
    }
    Ok(())
}

/// Build the engine either from a `.ltm` artifact (no weights needed)
/// or by compiling weights under the requested plan. `model` lets a
/// caller that already loaded the weights (eval's reference line)
/// avoid a second load.
fn engine_from_args(args: &Args, model: Option<&Model>) -> Result<LutModel> {
    if let Some(path) = args.get("artifact") {
        let lut = LutModel::load(Path::new(path))?;
        println!(
            "loaded artifact {path} ({} stages, {}, {})",
            lut.num_stages(),
            fmt_bits(lut.size_bits()),
            storage_note(&lut)
        );
        return Ok(lut);
    }
    let owned;
    let model = match model {
        Some(m) => m,
        None => {
            owned = load_model(args)?;
            &owned
        }
    };
    let plan = plan_from_args(args, model.arch)?;
    Compiler::new(model)
        .plan(&plan)
        .fuse(!args.has("no-fuse"))
        .build()
        .map_err(|e| anyhow!("plan not materialisable: {e}"))
}

fn eval(args: &Args) -> Result<()> {
    let ds = dataset(args)?;
    let n = args.get_usize("n", 500);
    let test = ds.test.head(n);

    // weights are required without --artifact; with it they are
    // optional (reference-accuracy line only). Loaded exactly once.
    let artifact = args.get("artifact");
    let model = match load_model(args) {
        Ok(m) => Some(m),
        Err(e) if artifact.is_some() => {
            eprintln!("note: skipping the reference line ({e:#})");
            None
        }
        Err(e) => return Err(e),
    };
    if let Some(model) = &model {
        let flat = match model.arch {
            Arch::Cnn => Tensor::new(&[test.len(), 28, 28, 1], test.images.clone()),
            _ => Tensor::new(&[test.len(), 784], test.images.clone()),
        };
        let ref_acc = model.accuracy(&flat, &test.labels);
        println!("reference (f32, multiply-full): {:.2}%", ref_acc * 100.0);
    }

    let lut = engine_from_args(args, model.as_ref())?;
    let (acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
    ctr.assert_multiplier_less();
    println!(
        "LUT engine: {:.2}%  | size {}  | per-inference {}",
        acc * 100.0,
        fmt_bits(lut.size_bits()),
        ctr
    );
    Ok(())
}

fn sweep_bits(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    if model.arch != Arch::Linear {
        bail!("sweep-bits reproduces Figs 4/6 (linear classifier)");
    }
    let ds = dataset(args)?;
    let test = ds.test.head(args.get_usize("n", 1000));
    let rows = harness::bits_sweep(&model, &test, &[1, 2, 3, 4, 5, 6, 7, 8]);
    harness::print_bits_sweep(
        &format!("Fig 4/6: accuracy vs input bits ({})", ds.kind.name()),
        &rows,
    );
    if let Some(out) = args.get("csv-out") {
        std::fs::write(out, harness::bits_csv(&rows))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn sweep_partitions(args: &Args) -> Result<()> {
    let a = arch(args)?;
    let pts = match a {
        Arch::Linear => planner::sweep::linear_tradeoff(args.get_u32("bits", 3)),
        Arch::Mlp => planner::sweep::mlp_tradeoff(),
        Arch::Cnn => planner::sweep::cnn_tradeoff(),
    };
    // measure on the engine when weights are available
    let mut rows = if let Ok(model) = load_model(args) {
        let ds = dataset(args)?;
        let test = ds.test.head(args.get_usize("n", 200));
        harness::tradeoff_rows(&model, &test, pts, args.get_usize("measure", 4))
    } else {
        pts.into_iter()
            .map(|point| harness::TradeoffRow {
                point,
                measured_acc: None,
                measured_evals: None,
                measured_ops: None,
            })
            .collect()
    };
    harness::print_tradeoff(&format!("Fig 5/7/8 tradeoff: {}", a.name()), &mut rows);
    if let Some(out) = args.get("csv-out") {
        std::fs::write(out, harness::tradeoff_csv(&rows))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn plan(args: &Args) -> Result<()> {
    println!("== paper in-text configuration check ==");
    println!("{:<30} {:>16} {:>16}", "quantity", "paper", "computed");
    for (name, paper, computed) in harness::intext_report() {
        println!("{name:<30} {paper:>16} {computed:>16}");
    }
    if let Some(a) = args.get("arch").and_then(Arch::parse) {
        let geoms = planner::arch_geometry(a);
        let pt = planner::evaluate_plan(&geoms, &EnginePlan::default_for(a));
        println!(
            "\ndefault plan for {}: {} LUTs, {}, {} adds, ref {} MACs",
            a.name(),
            pt.num_luts,
            fmt_bits(pt.size_bits),
            fmt_ops(pt.ops),
            fmt_ops(pt.ref_macs)
        );
    }
    Ok(())
}

/// One model's request pool for the load generator: rows to submit,
/// labels when the load is dataset-driven (None in pure-push mode).
struct RequestPool {
    rows: Vec<Vec<f32>>,
    labels: Option<Vec<usize>>,
}

/// Deterministic per-model request rows for pure-push load.
fn synth_rows(features: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = tablenet::util::Rng::new(seed);
    (0..256).map(|_| (0..features).map(|_| rng.f32()).collect()).collect()
}

/// FNV-1a of a model name — folded into the request-pool seed so every
/// model gets distinct but reproducible rows.
fn name_seed(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// Storage banner fragment: how a model's tables are resident.
fn storage_note(lut: &tablenet::engine::LutModel) -> &'static str {
    let s = lut.storage_summary();
    if s.banks > 0 && s.borrowed == s.banks {
        "zero-copy mmap"
    } else if s.borrowed > 0 {
        "partly mmap-borrowed"
    } else {
        "owned copy"
    }
}

fn serve(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::sync::RwLock;
    use std::time::Duration;
    use tablenet::coordinator::registry::watcher::{DirWatcher, WatchEvent, WatcherOptions};
    use tablenet::coordinator::registry::ModelRegistry;

    let fleet = tablenet::config::FleetConfig::from_args(args)?;
    fleet.validate()?;
    let n_requests = args.get_usize("requests", 2000);
    let clients = args.get_usize("clients", 4).max(1);
    let client_delay = Duration::from_millis(args.get_u64("client-delay-ms", 0));
    let watch_dir = args.get("watch-dir").map(PathBuf::from);
    let seed = args.get_u64("seed", 0x5E17E);
    let features_flag = Some(args.get_usize("features", 0)).filter(|&f| f > 0);
    // --listen switches serve into network mode: no in-process push
    // clients, requests arrive as wire frames, and --requests counts
    // rows answered over the wire before the drain (0 = serve until a
    // drain signal). The auth posture is validated before anything
    // binds: an exposed listener needs --auth-token or an explicit
    // --insecure-no-auth.
    let edge = tablenet::config::NetEdgeConfig::from_args(args);
    edge.validate()?;
    let net_mode = edge.listen.is_some();
    // the shared cross-model admission controller exists in both modes
    // (push mode never consults it, so its pure-push behavior is
    // untouched); budget 0 = meter but never reject
    let admission = Arc::new(tablenet::net::AdmissionController::new(edge.admission_budget));

    // dataset-driven load only when asked for; the default is
    // pure-push — raw request rows synthesized from the artifact's own
    // input geometry, no --dir, no weights
    let data = if args.has("dir") { Some(dataset(args)?) } else { None };

    // deterministic chaos: --fault-plan arms every model's worker with
    // the same seeded injector (latency, panics). Injected panics are
    // rehearsals, not bugs — silence their default stderr report so a
    // chaos run's output stays readable.
    let registry = match args.get("fault-plan") {
        None => ModelRegistry::new(),
        Some(spec) => {
            let plan = tablenet::coordinator::faults::FaultPlan::parse(spec)
                .map_err(|e| anyhow!("--fault-plan: {e}"))?;
            if plan.is_noop() {
                ModelRegistry::new()
            } else {
                println!("fault injection ON: {plan}");
                tablenet::coordinator::faults::silence_injected_panics();
                ModelRegistry::with_faults(Arc::new(
                    tablenet::coordinator::faults::FaultInjector::new(plan),
                ))
            }
        }
    };
    // the load generator's request pools; RwLock because --watch-dir
    // deploys add models (and pools) while clients are running. The
    // version counter bumps on every pool change so client threads can
    // serve from a local lock-free snapshot and re-read the map only
    // when a deploy actually changed it (one relaxed atomic load per
    // request on the steady-state path, no lock, no clone).
    let pools: Arc<RwLock<BTreeMap<String, Arc<RequestPool>>>> =
        Arc::new(RwLock::new(BTreeMap::new()));
    let pools_version = Arc::new(std::sync::atomic::AtomicU64::new(1));
    // dataset rows are identical for every model: build the pool once
    // and share it (pure-push pools stay per-model — each follows its
    // own artifact's input geometry)
    let data_pool: Option<Arc<RequestPool>> = data.as_ref().map(|ds| {
        Arc::new(RequestPool {
            rows: (0..ds.test.len()).map(|i| ds.test.image(i).to_vec()).collect(),
            labels: Some(ds.test.labels.clone()),
        })
    });
    let make_pool = |name: &str, features: Option<usize>| -> Result<Arc<RequestPool>> {
        match &data_pool {
            Some(p) => {
                // a width-mismatched artifact must fail HERE with a
                // clear error, not assert inside a worker mid-batch
                let row_w = p.rows.first().map(Vec::len).unwrap_or(0);
                if let Some(f) = features {
                    if f != row_w {
                        bail!(
                            "model '{name}' expects {f} input features but \
                             --dir rows have {row_w}"
                        );
                    }
                }
                Ok(p.clone())
            }
            None => {
                let features = features.or(features_flag).ok_or_else(|| {
                    anyhow!("[{name}] input width unknown; pass --features N")
                })?;
                Ok(Arc::new(RequestPool {
                    rows: synth_rows(features, seed ^ name_seed(name)),
                    labels: None,
                }))
            }
        }
    };
    let add_model =
        |name: &str, lut: tablenet::engine::LutModel, cfg: &ServeConfig| -> Result<()> {
            println!(
                "[{name}] {} stages, {} of tables ({}), batching {cfg:?}",
                lut.num_stages(),
                fmt_bits(lut.size_bits()),
                storage_note(&lut),
            );
            if net_mode {
                // socket traffic needs no request pool; what it needs
                // is the model's lane weight in the shared admission
                // controller
                admission.set_weight(name, cfg.admission_weight as u64);
            } else {
                let pool = make_pool(name, lut.input_features())?;
                pools.write().unwrap().insert(name.to_string(), pool);
                pools_version.fetch_add(1, std::sync::atomic::Ordering::Release);
            }
            registry
                .register(name, Arc::new(lut), cfg)
                .map_err(|e| anyhow!("registering '{name}': {e}"))
        };

    if fleet.models.is_empty() && watch_dir.is_none() {
        // legacy path: no artifacts — compile weights under the plan
        let name = arch(args)?.name().to_string();
        let lut = engine_from_args(args, None)?;
        add_model(&name, lut, &fleet.defaults)?;
    } else {
        for (name, spec) in &fleet.models {
            let lut = tablenet::engine::LutModel::load(&spec.artifact)
                .with_context(|| format!("model '{name}'"))?;
            println!("loaded artifact {} as '{name}'", spec.artifact.display());
            add_model(name, lut, &fleet.effective(name))?;
        }
    }
    let names: Vec<String> = registry.client().models();
    println!("eval kernel: {}", tablenet::lut::kernel::describe());
    if net_mode {
        println!(
            "serving {} model(s) {:?} | network mode, {}",
            names.len(),
            names,
            if n_requests == 0 {
                "draining on SIGTERM/SIGINT".to_string()
            } else {
                format!("draining after {n_requests} rows")
            },
        );
    } else {
        println!(
            "serving {} model(s) {:?} | {n_requests} requests, {clients} clients{}",
            names.len(),
            names,
            if data.is_some() { " (dataset-driven)" } else { " (pure-push)" }
        );
    }

    // mid-run rolling deployments: --swap name=path installs a new
    // version once half the load has been attempted. The NAME is
    // validated up front — a typo must fail before any traffic is
    // served — but the artifact itself is loaded AT SWAP TIME and
    // quarantined: a corrupt file, a width mismatch or a candidate
    // that fails the golden-batch self-check is rejected, the
    // incumbent version keeps serving the rest of the run, and the
    // process exits non-zero naming the failure once the load drains.
    let mut swaps: Vec<(String, std::path::PathBuf)> = Vec::new();
    for spec in args.get_all("swap") {
        let (name, path) = tablenet::config::parse_artifact_spec(spec)?;
        if registry.serve_config(&name).is_none() {
            bail!("--swap target '{name}' is not a registered model");
        }
        swaps.push((name, path));
    }

    // the deploy watcher starts AFTER static registration and swap
    // resolution: watch-dir deploys ride on top of the static fleet.
    // Its event hook prints each action and gives newly-registered
    // models a request pool so the load generator drives them too.
    let watcher = match &watch_dir {
        None => None,
        Some(dir) => {
            // fail fast on a typo'd path: an empty-but-valid dir is a
            // legitimate "wait for the first deploy" state, but a dir
            // that does not exist would hang the load loop forever
            if !dir.is_dir() {
                bail!("--watch-dir {} is not a directory", dir.display());
            }
            let interval = args.get_u64("watch-interval-ms", 200).max(10);
            println!(
                "watching {} for .ltm deploys (poll every {interval}ms)",
                dir.display()
            );
            let pools_w = pools.clone();
            let pools_version_w = pools_version.clone();
            let data_pool_w = data_pool.clone();
            let registry_w = registry.clone();
            let admission_w = admission.clone();
            Some(DirWatcher::start(
                registry.clone(),
                dir.clone(),
                WatcherOptions {
                    serve_cfg: fleet.defaults.clone(),
                    poll: Duration::from_millis(interval),
                    retire_on_delete: args.switch("watch-retire-on-delete"),
                    ..WatcherOptions::default()
                },
                move |ev| {
                    println!("[watch] {ev}");
                    let (name, features) = match ev {
                        WatchEvent::Registered { name, features, .. } => (name, *features),
                        WatchEvent::Swapped { name, features, .. } => (name, *features),
                        WatchEvent::Reconfigured { name, .. } => (name, None),
                        WatchEvent::Failed { .. } => return,
                        WatchEvent::Retired { name } => {
                            // stop driving a retired model; the
                            // registry entry is already gone
                            if !net_mode {
                                let mut pools = pools_w.write().unwrap();
                                if pools.remove(name).is_some() {
                                    pools_version_w
                                        .fetch_add(1, std::sync::atomic::Ordering::Release);
                                }
                            }
                            return;
                        }
                    };
                    if net_mode {
                        // no request pools to maintain for socket
                        // traffic — pick up the deployed stem's
                        // (possibly sidecar-pinned) admission weight
                        if let Some(cfg) = registry_w.serve_config(name) {
                            admission_w.set_weight(name, cfg.admission_weight as u64);
                        }
                        return;
                    }
                    if matches!(ev, WatchEvent::Reconfigured { .. }) {
                        // same artifact content, new pipeline config:
                        // existing request pools stay valid as-is
                        return;
                    }
                    let mut pools = pools_w.write().unwrap();
                    if let Some(existing) = pools.get(name) {
                        // swap of a model already under load: keep the
                        // pool only while its row width still fits the
                        // new backend — stale-width rows would assert
                        // inside a worker mid-batch (the static --swap
                        // path rejects this at resolve time)
                        let row_w = existing.rows.first().map(Vec::len).unwrap_or(0);
                        match features {
                            Some(f) if f != row_w => {
                                pools.remove(name);
                                pools_version_w
                                    .fetch_add(1, std::sync::atomic::Ordering::Release);
                                println!(
                                    "[watch] '{name}' now expects {f} features (pool \
                                     rows have {row_w}); rebuilding its request pool"
                                );
                                // fall through: rebuild below (pure-push)
                                // or stop driving it (dataset rows can't
                                // be resized)
                            }
                            _ => return,
                        }
                    }
                    let pool = match &data_pool_w {
                        Some(p) => {
                            let row_w = p.rows.first().map(Vec::len).unwrap_or(0);
                            match features {
                                Some(f) if f != row_w => {
                                    println!(
                                        "[watch] '{name}' expects {f} features but --dir \
                                         rows have {row_w}; serving it without load"
                                    );
                                    return;
                                }
                                _ => p.clone(),
                            }
                        }
                        None => match features.or(features_flag) {
                            Some(f) => Arc::new(RequestPool {
                                rows: synth_rows(f, seed ^ name_seed(name)),
                                labels: None,
                            }),
                            None => {
                                println!(
                                    "[watch] '{name}' input width unknown; serving it \
                                     without load (pass --features N)"
                                );
                                return;
                            }
                        },
                    };
                    pools.insert(name.clone(), pool);
                    pools_version_w.fetch_add(1, std::sync::atomic::Ordering::Release);
                },
            ))
        }
    };

    // mid-run swap executor shared by both modes. The width guard only
    // applies when this run drives the model from a request pool (push
    // mode); network rows carry their own width and are validated by
    // the pipeline itself.
    let run_swaps = |swap_failures: &mut Vec<String>| {
        for (name, path) in &swaps {
            let outcome = tablenet::engine::LutModel::load(path)
                .with_context(|| format!("swap target for '{name}'"))
                .and_then(|lut| {
                    let row_w = pools
                        .read()
                        .unwrap()
                        .get(name)
                        .and_then(|p| p.rows.first().map(Vec::len))
                        .unwrap_or(0);
                    if let Some(f) = lut.input_features() {
                        if row_w > 0 && f != row_w {
                            bail!(
                                "swap for '{name}': artifact expects {f} input features \
                                 but this run's request rows have {row_w}"
                            );
                        }
                    }
                    registry
                        .swap_quarantined(name, Arc::new(lut))
                        .map_err(|e| anyhow!("{e}"))
                });
            match outcome {
                Ok(v) => {
                    println!("hot-swapped '{name}' -> version {v} ({})", path.display());
                }
                Err(e) => {
                    eprintln!("[swap] {e:#} — incumbent '{name}' keeps serving");
                    swap_failures.push(format!("{e:#}"));
                }
            }
        }
    };

    let start = std::time::Instant::now();

    if let Some(addr) = edge.listen.as_deref() {
        #[cfg(not(unix))]
        {
            let _ = addr;
            bail!("--listen requires a unix platform (epoll/kqueue serving tier)");
        }
        #[cfg(unix)]
        {
            use tablenet::net::{
                drain_signal_received, install_drain_signal_handler, NetServer, NetServerOptions,
            };
            // latch SIGTERM/SIGINT into a drain flag BEFORE the
            // listener binds, so a kill during startup still drains
            install_drain_signal_handler();
            let server = NetServer::start(
                addr,
                registry.client(),
                admission.clone(),
                NetServerOptions {
                    threads: edge.net_threads,
                    auth_token: edge.auth_token.clone(),
                    max_conns: edge.max_conns,
                    frame_rate_limit: edge.frame_rate_limit,
                    row_rate_limit: edge.row_rate_limit,
                    drain_grace_ms: edge.drain_grace_ms,
                    ..NetServerOptions::default()
                },
            )
            .map_err(|e| anyhow!("--listen {addr}: {e}"))?;
            let budget = admission.budget();
            println!(
                "listening on {} | {} net threads | admission budget {} | auth {}",
                server.local_addr(),
                server.threads(),
                if budget == 0 { "unlimited".to_string() } else { format!("{budget} rows") },
                if edge.auth_token.is_some() { "required" } else { "off" },
            );
            // rows_done counts every row answered over the wire —
            // served, shed or refused — so the drain threshold is
            // reached even under pure overload. Every exit path goes
            // through the same graceful GoAway drain.
            let mut swap_failures: Vec<String> = Vec::new();
            let mut swaps_left = !swaps.is_empty();
            let drain_cause = if args.switch("drain") {
                "drain requested on the command line".to_string()
            } else {
                loop {
                    if drain_signal_received() {
                        break "drain signal (SIGTERM/SIGINT)".to_string();
                    }
                    let done = server.rows_done();
                    if n_requests > 0 && done >= n_requests as u64 {
                        break format!("row target {n_requests} reached");
                    }
                    if swaps_left && n_requests > 0 && done >= (n_requests / 2) as u64 {
                        run_swaps(&mut swap_failures);
                        swaps_left = false;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            };
            if swaps_left {
                run_swaps(&mut swap_failures);
            }
            println!(
                "draining: {drain_cause} ({} connection(s) open, grace {}ms)",
                server.active_connections(),
                edge.drain_grace_ms
            );
            server.begin_drain(&drain_cause);
            let elapsed = start.elapsed().as_secs_f64();
            let net_snap = server.shutdown();
            if let Some(w) = watcher {
                let stats = w.stop();
                println!(
                    "watcher: {} scans, {} registered, {} swapped, {} reconfigured, \
                     {} rejected, {} retries, {} retired",
                    stats.scans,
                    stats.registered,
                    stats.swapped,
                    stats.reconfigured,
                    stats.failed,
                    stats.retries,
                    stats.retired
                );
            }
            let mut fleet_snap = registry.shutdown();
            net_snap.assert_accounted();
            println!(
                "net accounting: exact ({} rows answered over the wire: {} ok, \
                 {} admission-rejected; every admitted row has exactly one verdict)",
                net_snap.rows_done,
                net_snap.rows_ok(),
                net_snap.rows_admission_rejected(),
            );
            let rows_done = net_snap.rows_done;
            fleet_snap.net = Some(net_snap);
            println!("{fleet_snap}");
            println!(
                "served {rows_done} rows over the wire in {elapsed:.2}s ({:.1} rows/s)",
                rows_done as f64 / elapsed
            );
            fleet_snap.assert_multiplier_less();
            if !swap_failures.is_empty() {
                bail!(
                    "{} mid-run swap(s) rejected (incumbent versions kept serving): {}",
                    swap_failures.len(),
                    swap_failures.join(" | ")
                );
            }
            return Ok(());
        }
    }

    // attempts counts every request a client has ISSUED (served or
    // shed) — the --swap trigger keys off it, so rolling deploys still
    // fire at mid-load even when faults shed part of the traffic
    let attempts = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = registry.client();
        let pools = pools.clone();
        let pools_version = pools_version.clone();
        let attempts = attempts.clone();
        let per_client = n_requests / clients;
        joins.push(std::thread::spawn(move || {
            use tablenet::coordinator::router::RouteError;
            use tablenet::coordinator::ServeError;
            let mut served = 0usize;
            let mut shed = 0usize;
            let mut correct = 0usize;
            let mut labeled = 0usize;
            let mut i = 0usize;
            // local lock-free snapshot of the pools, re-read only when
            // a deploy bumped the version — the steady-state request
            // path costs one relaxed atomic load, no lock, no clones
            let mut local: Vec<(String, Arc<RequestPool>)> = Vec::new();
            let mut seen_version = 0u64;
            while i < per_client {
                let version = pools_version.load(std::sync::atomic::Ordering::Acquire);
                if version != seen_version {
                    local = pools
                        .read()
                        .unwrap()
                        .iter()
                        .map(|(n, p)| (n.clone(), p.clone()))
                        .collect();
                    seen_version = version;
                }
                if local.is_empty() {
                    // with --watch-dir the fleet may start empty — wait
                    // for the first deploy instead of exiting unloaded
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
                let k = c * per_client + i;
                let (name, pool) = &local[k % local.len()];
                let idx = k % pool.rows.len();
                attempts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                match client.infer(name, pool.rows[idx].clone()) {
                    Ok(resp) => {
                        served += 1;
                        if let Some(labels) = &pool.labels {
                            labeled += 1;
                            if resp.class == labels[idx] {
                                correct += 1;
                            }
                        }
                    }
                    // shed / failed requests surface as typed errors
                    // and the client MOVES ON — degraded service, not
                    // an aborted load run. Only a shut-down fleet ends
                    // the loop early.
                    Err(RouteError::Submit(ServeError::ShutDown)) => break,
                    Err(_) => shed += 1,
                }
                if !client_delay.is_zero() {
                    std::thread::sleep(client_delay);
                }
                i += 1;
            }
            (served, shed, correct, labeled)
        }));
    }

    let mut swap_failures: Vec<String> = Vec::new();
    if !swaps.is_empty() {
        // wait until roughly half the load has been attempted, then roll
        let planned = (n_requests / clients) * clients;
        while attempts.load(std::sync::atomic::Ordering::Relaxed) < (planned / 2) as u64 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        run_swaps(&mut swap_failures);
    }

    let (mut served, mut shed, mut correct, mut labeled) = (0usize, 0usize, 0usize, 0usize);
    for j in joins {
        let (s, sh, c, l) = j.join().unwrap();
        served += s;
        shed += sh;
        correct += c;
        labeled += l;
    }
    let elapsed = start.elapsed().as_secs_f64();
    if let Some(w) = watcher {
        let stats = w.stop();
        println!(
            "watcher: {} scans, {} registered, {} swapped, {} reconfigured, {} rejected, \
             {} retries, {} retired",
            stats.scans,
            stats.registered,
            stats.swapped,
            stats.reconfigured,
            stats.failed,
            stats.retries,
            stats.retired
        );
    }
    let fleet_snap = registry.shutdown();
    println!("{fleet_snap}");
    print!(
        "served {served} requests in {elapsed:.2}s ({:.1} req/s)",
        served as f64 / elapsed
    );
    if shed > 0 {
        print!(", {shed} shed");
    }
    if labeled > 0 {
        print!(", accuracy {:.2}%", 100.0 * correct as f64 / labeled as f64);
    }
    println!();
    fleet_snap.assert_multiplier_less();
    // a rejected mid-run swap is a deploy failure the operator must
    // see in the exit code — but only AFTER the load has drained and
    // the incumbent-serving evidence (snapshot above) is printed
    if !swap_failures.is_empty() {
        bail!(
            "{} mid-run swap(s) rejected (incumbent versions kept serving): {}",
            swap_failures.len(),
            swap_failures.join(" | ")
        );
    }
    Ok(())
}

/// Wire-protocol load generator: drive a `serve --listen` tier over C
/// concurrent connections and tally every row's typed outcome. Shed
/// rows (queue-full, deadline, admission-rejected, rate-limited) are
/// degraded service, not failures; a LOST row — sent but never
/// answered — or a DUPLICATE acknowledgement is a protocol violation
/// and exits non-zero.
///
/// With `--retry-budget` (or `--auth-token`) the load runs through the
/// idempotency-keyed [`ReconnectingClient`]: dropped connections,
/// server restarts and GoAway drains are survived by retrying under
/// the same key, so acknowledged rows stay exactly-once end to end.
fn client_cmd(args: &Args) -> Result<()> {
    use std::time::Instant;
    use tablenet::net::{Frame, NetClient, ReconnectingClient, RetryPolicy, RetryStats, Status};

    let addr = args.get("addr").map(str::to_string).ok_or_else(|| {
        anyhow!(
            "usage: tablenet client --addr HOST:PORT --model NAME [--requests ROWS] \
             [--connections C] [--rows-per-frame R] [--features F] [--retry-budget N] \
             [--auth-token SECRET] [--client-id ID]"
        )
    })?;
    let model = args.get_or("model", "digits").to_string();
    let total_rows = args.get_usize("requests", 1000).max(1);
    let conns = args.get_usize("connections", 2).max(1);
    let rows_per_frame = args.get_usize("rows-per-frame", 16).clamp(1, 4096);
    let features = args.get_usize("features", 784).max(1);
    let seed = args.get_u64("seed", 0xC11E);
    // resilient mode is opt-in via either flag; auth implies it because
    // only the reconnecting client sends the Hello handshake
    let resilient = args.get("retry-budget").is_some() || args.get("auth-token").is_some();
    let retry_budget = args.get_u64("retry-budget", 8);
    let token = args.get_or("auth-token", "").to_string();
    let client_id = args.get_u64("client-id", seed | 1);

    println!(
        "client: {total_rows} rows -> '{model}' @ {addr} | {conns} connection(s), \
         {rows_per_frame} rows/frame, {features} features{}",
        if resilient {
            format!(" | reconnecting, retry budget {retry_budget}")
        } else {
            String::new()
        }
    );
    let start = Instant::now();
    let mut joins = Vec::new();
    for c in 0..conns {
        // spread the total across connections, remainder to the first
        let share = total_rows / conns + usize::from(c < total_rows % conns);
        let addr = addr.clone();
        let model = model.clone();
        let token = token.clone();
        joins.push(std::thread::spawn(move || {
            let mut counts = [0u64; Status::COUNT];
            let mut rtts: Vec<f64> = Vec::new();
            let mut rng = tablenet::util::Rng::new(seed ^ (c as u64 + 1));
            let mut lost = 0u64;
            let mut dups = 0u64;
            let mut left = share;
            if resilient {
                let policy = RetryPolicy {
                    budget: retry_budget,
                    seed: seed ^ (c as u64).wrapping_mul(0x9e37_79b9),
                    ..RetryPolicy::default()
                };
                // distinct per-connection client id: each connection is
                // its own idempotency-key namespace in the replay cache
                let mut cl =
                    ReconnectingClient::new(&addr, client_id.wrapping_add(c as u64), &token, policy);
                while left > 0 {
                    let rows = left.min(rows_per_frame);
                    let data: Vec<f32> = (0..rows * features).map(|_| rng.f32()).collect();
                    let t0 = Instant::now();
                    match cl.infer(&model, features as u32, &data) {
                        Ok(reply) => {
                            rtts.push(t0.elapsed().as_secs_f64() * 1e6);
                            for row in reply.rows.iter().take(rows) {
                                counts[row.status as usize] += 1;
                            }
                            // a short reply drops rows on the floor; an
                            // over-long one double-acknowledges — both
                            // are violations, neither passes silently
                            lost += rows.saturating_sub(reply.rows.len()) as u64;
                            dups += reply.rows.len().saturating_sub(rows) as u64;
                            left -= rows;
                        }
                        Err(e) => {
                            // budget exhausted: everything unanswered
                            // on this connection is lost
                            eprintln!("[conn {c}] {e}");
                            let st = cl.stats();
                            return (counts, rtts, lost + left as u64, dups, st);
                        }
                    }
                }
                (counts, rtts, lost, dups, cl.stats())
            } else {
                let mut cl = match NetClient::connect_retry(&addr, 2_000) {
                    Ok(cl) => cl,
                    Err(e) => {
                        eprintln!("[conn {c}] connect {addr}: {e}");
                        return (counts, rtts, share as u64, 0, RetryStats::default());
                    }
                };
                while left > 0 {
                    let rows = left.min(rows_per_frame);
                    let data: Vec<f32> = (0..rows * features).map(|_| rng.f32()).collect();
                    let t0 = Instant::now();
                    // a GoAway can interleave ahead of the reply during
                    // a drain: note it and keep reading — re-sending
                    // here would double-submit (no idempotency key)
                    let exchange = (|| -> std::io::Result<Frame> {
                        cl.send(&model, features as u32, &data)?;
                        loop {
                            match cl.read_frame()? {
                                Frame::GoAway(ga) => eprintln!(
                                    "[conn {c}] server draining ({}, grace {}ms); \
                                     re-run with --retry-budget to ride through",
                                    ga.reason, ga.grace_ms
                                ),
                                f => return Ok(f),
                            }
                        }
                    })();
                    match exchange {
                        Ok(Frame::Reply(reply)) => {
                            rtts.push(t0.elapsed().as_secs_f64() * 1e6);
                            for row in reply.rows.iter().take(rows) {
                                counts[row.status as usize] += 1;
                            }
                            lost += rows.saturating_sub(reply.rows.len()) as u64;
                            dups += reply.rows.len().saturating_sub(rows) as u64;
                            left -= rows;
                        }
                        Ok(Frame::Error(err)) => {
                            rtts.push(t0.elapsed().as_secs_f64() * 1e6);
                            counts[err.status as usize] += rows as u64;
                            left -= rows;
                        }
                        Ok(_) => {
                            eprintln!("[conn {c}] protocol violation: unexpected frame kind");
                            return (counts, rtts, lost + left as u64, dups, RetryStats::default());
                        }
                        Err(e) => {
                            // io failure mid-stream: everything not yet
                            // answered on this connection is lost
                            eprintln!("[conn {c}] {e}");
                            return (counts, rtts, lost + left as u64, dups, RetryStats::default());
                        }
                    }
                }
                (counts, rtts, lost, dups, RetryStats::default())
            }
        }));
    }

    let mut counts = [0u64; Status::COUNT];
    let mut rtts: Vec<f64> = Vec::new();
    let mut lost = 0u64;
    let mut dups = 0u64;
    let mut retry = RetryStats::default();
    for j in joins {
        let (c, r, l, d, st) = j.join().unwrap();
        for (total, part) in counts.iter_mut().zip(c) {
            *total += part;
        }
        rtts.extend(r);
        lost += l;
        dups += d;
        retry.connects += st.connects;
        retry.retries += st.retries;
        retry.budget_denied += st.budget_denied;
        retry.goaways_seen += st.goaways_seen;
    }
    let elapsed = start.elapsed().as_secs_f64();
    let answered: u64 = counts.iter().sum();
    print!(
        "client: {answered} rows answered in {elapsed:.2}s ({:.0} rows/s)",
        answered as f64 / elapsed.max(1e-9)
    );
    if !rtts.is_empty() {
        print!(
            " | frame RTT p50 {:.0}us p99 {:.0}us",
            tablenet::util::percentile(&rtts, 50.0),
            tablenet::util::percentile(&rtts, 99.0)
        );
    }
    println!();
    println!(
        "  ok {} | queue-full {} | deadline-shed {} | panicked {} | shut-down {} | \
         unknown-model {} | admission-rejected {} | malformed {} | auth-failed {} | \
         rate-limited {} | too-many-conns {} | lost {lost} | duplicates {dups}",
        counts[Status::Ok as usize],
        counts[Status::QueueFull as usize],
        counts[Status::DeadlineExceeded as usize],
        counts[Status::WorkerPanicked as usize],
        counts[Status::ShutDown as usize],
        counts[Status::UnknownModel as usize],
        counts[Status::AdmissionRejected as usize],
        counts[Status::Malformed as usize],
        counts[Status::AuthFailed as usize],
        counts[Status::RateLimited as usize],
        counts[Status::TooManyConnections as usize],
    );
    if resilient {
        println!(
            "  retry: {} connect(s), {} retried, {} budget-denied, {} goaway(s) seen",
            retry.connects, retry.retries, retry.budget_denied, retry.goaways_seen
        );
    }
    if lost > 0 {
        bail!("{lost} row(s) lost: sent but never answered");
    }
    if dups > 0 {
        bail!("{dups} duplicate row acknowledgement(s): exactly-once violated");
    }
    Ok(())
}

/// Dump a `.ltm` artifact: container version, embedded plan, stage
/// kinds, per-stage table sizes and total bytes — through the same
/// parse path the serving registry loads with.
fn inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("artifact"))
        .ok_or_else(|| anyhow!("usage: tablenet inspect model.ltm"))?;
    let info = tablenet::engine::artifact::inspect(Path::new(path))?;
    println!("artifact {path}");
    println!(
        "  container version : {} ({})",
        info.version,
        if info.version >= 2 {
            "zero-copy layout: 64B-aligned arenas, per-stage checksums"
        } else {
            "legacy packed layout, loads via the copying path"
        }
    );
    println!(
        "  mapped            : {}",
        if info.mapped { "yes (arenas may borrow in place)" } else { "no" }
    );
    println!("  total bytes       : {}", info.total_bytes);
    println!("  eval kernel       : {}", tablenet::lut::kernel::describe());
    println!(
        "  tables            : {} ({} bits)",
        fmt_bits(info.size_bits),
        info.size_bits
    );
    println!(
        "  input features    : {}",
        info.input_features
            .map(|f| f.to_string())
            .unwrap_or_else(|| "unknown".to_string())
    );
    let (banks, borrowed): (usize, usize) = info.stages.iter().fold((0, 0), |(b, z), s| {
        match s.storage {
            Some(r) => (b + 1, z + r.borrowed as usize),
            None => (b, z),
        }
    });
    println!(
        "  storage           : {borrowed}/{banks} table banks borrowed zero-copy{}",
        if banks > 0 && borrowed == banks { " (served in place from the mapping)" } else { "" }
    );
    let folded: usize = info.stages.iter().map(|s| s.fused.len()).sum();
    println!(
        "  stages            : {}{}",
        info.stages.len(),
        if folded > 0 {
            format!(" ({folded} elementwise folded into bank epilogues)")
        } else {
            String::new()
        }
    );
    for (i, s) in info.stages.iter().enumerate() {
        let checksum = s
            .checksum
            .map(|c| format!("{c:#018x}"))
            .unwrap_or_else(|| "-".to_string());
        let storage = match s.storage {
            Some(r) => format!(
                "{} {}",
                if r.narrow { "i32" } else { "i64" },
                if r.borrowed { "borrowed(mmap)" } else { "owned" }
            ),
            None => "-".to_string(),
        };
        println!(
            "    [{i:2}] {:<28} payload {:>12} B @ {:#010x}  fnv {checksum}  \
             tables {:<12} {storage}",
            s.display_name(),
            s.payload_bytes,
            s.offset,
            fmt_bits(s.size_bits),
        );
    }
    let plan = tablenet::config::json::Json::parse(&info.plan_json)
        .map_err(|e| anyhow!("embedded plan: {e}"))?;
    println!("  plan:");
    for line in plan.to_string_pretty().lines() {
        println!("    {line}");
    }
    Ok(())
}

fn ref_check(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let a = model.arch;
    let batch = args.get_usize("batch", 1);
    let hlo = PathBuf::from(args.get("hlo").map(str::to_string).unwrap_or_else(|| {
        tablenet::runtime::ref_hlo_path(Path::new("artifacts"), a, batch)
            .display()
            .to_string()
    }));
    let features: usize = model.input_shape.iter().product();
    let pjrt = tablenet::runtime::PjrtModel::load(&hlo, batch, features, 10)?;
    println!("PJRT platform: {}", pjrt.platform());
    let ds = dataset(args)?;
    let n = args.get_usize("n", 32);
    let mut max_diff = 0f32;
    let mut agree = 0usize;
    for i in 0..n {
        let img = ds.test.image(i).to_vec();
        let pj = pjrt.infer_padded(&[img.clone()])?;
        let shape: Vec<usize> = std::iter::once(1usize)
            .chain(model.input_shape.iter().copied())
            .collect();
        let rust_out = model.forward(&Tensor::new(&shape, img));
        for (x, y) in pj[0].iter().zip(rust_out.data()) {
            max_diff = max_diff.max((x - y).abs());
        }
        let pj_class = pj[0]
            .iter()
            .enumerate()
            .max_by(|u, v| u.1.partial_cmp(v.1).unwrap())
            .unwrap()
            .0;
        if pj_class == rust_out.argmax_rows()[0] {
            agree += 1;
        }
    }
    println!(
        "PJRT vs rust forward over {n} samples: max |Δlogit| = {max_diff:.2e}, argmax agreement {agree}/{n}"
    );
    anyhow::ensure!(agree == n, "prediction mismatch between PJRT and rust reference");
    Ok(())
}
