//! `tablenet` — CLI launcher for the TableNet reproduction.
//!
//! Subcommands:
//!   gen-data          generate + cache the synthetic corpora (IDX files)
//!   train             in-Rust SGD training (linear / mlp)
//!   compile           compile weights + plan into a .ltm artifact
//!   eval              accuracy: LUT engine vs reference, with op counters
//!   sweep-bits        Fig 4 / Fig 6 accuracy-vs-input-bits sweep
//!   sweep-partitions  Fig 5 / 7 / 8 size-vs-ops tradeoff tables
//!   plan              planner tables + paper in-text config check
//!   serve             run the serving coordinator under synthetic load
//!   ref-check         PJRT reference artifact vs in-Rust forward

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use tablenet::config::cli::Args;
use tablenet::config::ServeConfig;
use tablenet::data::synth::Kind;
use tablenet::data::{load_or_generate, Dataset};
use tablenet::engine::plan::EnginePlan;
use tablenet::engine::{Compiler, LutModel};
use tablenet::harness;
use tablenet::nn::{weights, Arch, Model};
use tablenet::planner;
use tablenet::tensor::Tensor;
use tablenet::train::{train_dense, TrainConfig};
use tablenet::util::{fmt_bits, fmt_ops};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "gen-data" => gen_data(args),
        "train" => train(args),
        "compile" => compile(args),
        "eval" => eval(args),
        "sweep-bits" => sweep_bits(args),
        "sweep-partitions" => sweep_partitions(args),
        "plan" => plan(args),
        "serve" => serve(args),
        "ref-check" => ref_check(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "tablenet — multiplier-less LUT inference (TableNet reproduction)\n\n\
         usage: tablenet <cmd> [--flags]\n\n\
         commands:\n\
         \x20 gen-data         --dir data/synth --train 4000 --test 1000 --seed 7\n\
         \x20 train            --arch linear|mlp --dataset mnist|fashion --steps N --out w.bin\n\
         \x20 compile          --arch A --weights w.bin [--plan plan.json] --out model.ltm\n\
         \x20 eval             --arch A --weights w.bin --dataset D [--plan plan.json] [--artifact model.ltm] [--n 500]\n\
         \x20 sweep-bits       --arch linear --weights w.bin --dataset D [--csv-out f.csv]\n\
         \x20 sweep-partitions --arch linear|mlp|cnn [--weights w.bin --dataset D]\n\
         \x20 plan             [--arch A]\n\
         \x20 serve            --arch A --weights w.bin [--artifact model.ltm] --requests 2000 [--max-batch 32]\n\
         \x20 ref-check        --arch A --weights w.bin --hlo artifacts/linear_ref_b1.hlo.txt"
    );
}

fn data_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("dir", "data/synth"))
}

fn dataset(args: &Args) -> Result<Dataset> {
    let kind = Kind::parse(args.get_or("dataset", "mnist"))
        .ok_or_else(|| anyhow!("unknown dataset (mnist|fashion)"))?;
    let n_train = args.get_usize("train", 4000);
    let n_test = args.get_usize("test", 1000);
    load_or_generate(&data_dir(args), kind, n_train, n_test, args.get_u64("seed", 7))
}

fn arch(args: &Args) -> Result<Arch> {
    Arch::parse(args.get_or("arch", "linear"))
        .ok_or_else(|| anyhow!("unknown arch (linear|mlp|cnn)"))
}

fn load_model(args: &Args) -> Result<Model> {
    let a = arch(args)?;
    let path = PathBuf::from(
        args.get("weights")
            .map(str::to_string)
            .unwrap_or_else(|| format!("artifacts/weights_{}.bin", a.name())),
    );
    weights::load_model(a, &path).with_context(|| {
        format!(
            "loading {} (run `make artifacts` or `tablenet train`)",
            path.display()
        )
    })
}

fn plan_from_args(args: &Args, a: Arch) -> Result<EnginePlan> {
    match args.get("plan") {
        None => Ok(EnginePlan::default_for(a)),
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let j = tablenet::config::json::Json::parse(&text)
                .map_err(|e| anyhow!("{path}: {e}"))?;
            tablenet::config::plan_from_json(&j)
        }
    }
}

fn gen_data(args: &Args) -> Result<()> {
    let dir = data_dir(args);
    let n_train = args.get_usize("train", 4000);
    let n_test = args.get_usize("test", 1000);
    let seed = args.get_u64("seed", 7);
    for kind in [Kind::Digits, Kind::Fashion] {
        let ds = load_or_generate(&dir, kind, n_train, n_test, seed)?;
        println!(
            "{}: train {} / test {} samples in {}",
            kind.name(),
            ds.train.len(),
            ds.test.len(),
            dir.display()
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let a = arch(args)?;
    let ds = dataset(args)?;
    let widths: Vec<usize> = match a {
        Arch::Linear => vec![784, 10],
        Arch::Mlp => vec![784, 1024, 512, 10],
        Arch::Cnn => bail!("CNN training runs in JAX: `make artifacts`"),
    };
    let cfg = TrainConfig {
        steps: args.get_usize("steps", if a == Arch::Linear { 3000 } else { 800 }),
        lr: args.get_f64("lr", 0.2) as f32,
        batch: args.get_usize("batch", 100),
        seed: args.get_u64("seed", 0x7AB1E7),
        input_bits: args.get("input-bits").and_then(|v| v.parse().ok()),
        weight_decay: args.get_f64("weight-decay", 1e-4) as f32,
        log_every: args.get_usize("log-every", 200),
    };
    eprintln!("training {} on {} ({} steps)...", a.name(), ds.kind.name(), cfg.steps);
    let model = train_dense(&ds.train, &widths, &cfg);
    let x = Tensor::new(&[ds.test.len(), 784], ds.test.images.clone());
    println!("test accuracy: {:.2}%", model.accuracy(&x, &ds.test.labels) * 100.0);
    if let Some(out) = args.get("out") {
        let mut map = weights::WeightMap::new();
        for (i, layer) in model
            .layers
            .iter()
            .filter_map(|l| match l {
                tablenet::nn::Layer::Dense { w, b } => Some((w, b)),
                _ => None,
            })
            .enumerate()
        {
            map.insert(format!("fc{}.w", i + 1), layer.0.clone());
            map.insert(format!("fc{}.b", i + 1), layer.1.clone());
        }
        weights::save(Path::new(out), &map)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Compile weights + plan into a servable `.ltm` artifact.
fn compile(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let plan = plan_from_args(args, model.arch)?;
    let lut = Compiler::new(&model)
        .plan(&plan)
        .build()
        .map_err(|e| anyhow!("plan not materialisable: {e}"))?;
    let out = PathBuf::from(
        args.get("out")
            .map(str::to_string)
            .unwrap_or_else(|| format!("artifacts/model_{}.ltm", model.arch.name())),
    );
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    lut.save(&out)?;
    println!(
        "wrote {} ({} stages, {} of tables at r_o={})",
        out.display(),
        lut.num_stages(),
        fmt_bits(lut.size_bits()),
        lut.plan().r_o
    );
    Ok(())
}

/// Build the engine either from a `.ltm` artifact (no weights needed)
/// or by compiling weights under the requested plan. `model` lets a
/// caller that already loaded the weights (eval's reference line)
/// avoid a second load.
fn engine_from_args(args: &Args, model: Option<&Model>) -> Result<LutModel> {
    if let Some(path) = args.get("artifact") {
        let lut = LutModel::load(Path::new(path))?;
        println!(
            "loaded artifact {path} ({} stages, {})",
            lut.num_stages(),
            fmt_bits(lut.size_bits())
        );
        return Ok(lut);
    }
    let owned;
    let model = match model {
        Some(m) => m,
        None => {
            owned = load_model(args)?;
            &owned
        }
    };
    let plan = plan_from_args(args, model.arch)?;
    Compiler::new(model)
        .plan(&plan)
        .build()
        .map_err(|e| anyhow!("plan not materialisable: {e}"))
}

fn eval(args: &Args) -> Result<()> {
    let ds = dataset(args)?;
    let n = args.get_usize("n", 500);
    let test = ds.test.head(n);

    // weights are required without --artifact; with it they are
    // optional (reference-accuracy line only). Loaded exactly once.
    let artifact = args.get("artifact");
    let model = match load_model(args) {
        Ok(m) => Some(m),
        Err(e) if artifact.is_some() => {
            eprintln!("note: skipping the reference line ({e:#})");
            None
        }
        Err(e) => return Err(e),
    };
    if let Some(model) = &model {
        let flat = match model.arch {
            Arch::Cnn => Tensor::new(&[test.len(), 28, 28, 1], test.images.clone()),
            _ => Tensor::new(&[test.len(), 784], test.images.clone()),
        };
        let ref_acc = model.accuracy(&flat, &test.labels);
        println!("reference (f32, multiply-full): {:.2}%", ref_acc * 100.0);
    }

    let lut = engine_from_args(args, model.as_ref())?;
    let (acc, ctr) = lut.accuracy(&test.images, 784, &test.labels);
    ctr.assert_multiplier_less();
    println!(
        "LUT engine: {:.2}%  | size {}  | per-inference {}",
        acc * 100.0,
        fmt_bits(lut.size_bits()),
        ctr
    );
    Ok(())
}

fn sweep_bits(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    if model.arch != Arch::Linear {
        bail!("sweep-bits reproduces Figs 4/6 (linear classifier)");
    }
    let ds = dataset(args)?;
    let test = ds.test.head(args.get_usize("n", 1000));
    let rows = harness::bits_sweep(&model, &test, &[1, 2, 3, 4, 5, 6, 7, 8]);
    harness::print_bits_sweep(
        &format!("Fig 4/6: accuracy vs input bits ({})", ds.kind.name()),
        &rows,
    );
    if let Some(out) = args.get("csv-out") {
        std::fs::write(out, harness::bits_csv(&rows))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn sweep_partitions(args: &Args) -> Result<()> {
    let a = arch(args)?;
    let pts = match a {
        Arch::Linear => planner::sweep::linear_tradeoff(args.get_u32("bits", 3)),
        Arch::Mlp => planner::sweep::mlp_tradeoff(),
        Arch::Cnn => planner::sweep::cnn_tradeoff(),
    };
    // measure on the engine when weights are available
    let mut rows = if let Ok(model) = load_model(args) {
        let ds = dataset(args)?;
        let test = ds.test.head(args.get_usize("n", 200));
        harness::tradeoff_rows(&model, &test, pts, args.get_usize("measure", 4))
    } else {
        pts.into_iter()
            .map(|point| harness::TradeoffRow {
                point,
                measured_acc: None,
                measured_evals: None,
                measured_ops: None,
            })
            .collect()
    };
    harness::print_tradeoff(&format!("Fig 5/7/8 tradeoff: {}", a.name()), &mut rows);
    if let Some(out) = args.get("csv-out") {
        std::fs::write(out, harness::tradeoff_csv(&rows))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn plan(args: &Args) -> Result<()> {
    println!("== paper in-text configuration check ==");
    println!("{:<30} {:>16} {:>16}", "quantity", "paper", "computed");
    for (name, paper, computed) in harness::intext_report() {
        println!("{name:<30} {paper:>16} {computed:>16}");
    }
    if let Some(a) = args.get("arch").and_then(Arch::parse) {
        let geoms = planner::arch_geometry(a);
        let pt = planner::evaluate_plan(&geoms, &EnginePlan::default_for(a));
        println!(
            "\ndefault plan for {}: {} LUTs, {}, {} adds, ref {} MACs",
            a.name(),
            pt.num_luts,
            fmt_bits(pt.size_bits),
            fmt_ops(pt.ops),
            fmt_ops(pt.ref_macs)
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let lut = engine_from_args(args, None)?;
    let cfg = ServeConfig::default().override_with(args);
    cfg.validate()?;
    let ds = dataset(args)?;
    let n_requests = args.get_usize("requests", 2000);
    let clients = args.get_usize("clients", 4).max(1);
    println!(
        "serving the LUT engine ({}, {} stages) with {:?}",
        fmt_bits(lut.size_bits()),
        lut.num_stages(),
        cfg
    );

    let coord = tablenet::coordinator::Coordinator::start(Arc::new(lut), &cfg);
    let test = Arc::new(ds.test);
    let start = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = coord.client();
        let test = test.clone();
        let per_client = n_requests / clients;
        joins.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            let mut served = 0usize;
            for i in 0..per_client {
                let idx = (c * per_client + i) % test.len();
                match client.infer_blocking(test.image(idx).to_vec()) {
                    Ok(resp) => {
                        served += 1;
                        if resp.class == test.labels[idx] {
                            correct += 1;
                        }
                    }
                    Err(_) => break,
                }
            }
            (served, correct)
        }));
    }
    let mut served = 0;
    let mut correct = 0;
    for j in joins {
        let (s, c) = j.join().unwrap();
        served += s;
        correct += c;
    }
    let elapsed = start.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    println!("{snap}");
    println!(
        "served {served} requests in {elapsed:.2}s ({:.1} req/s), accuracy {:.2}%",
        served as f64 / elapsed,
        100.0 * correct as f64 / served.max(1) as f64
    );
    Ok(())
}

fn ref_check(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let a = model.arch;
    let batch = args.get_usize("batch", 1);
    let hlo = PathBuf::from(args.get("hlo").map(str::to_string).unwrap_or_else(|| {
        tablenet::runtime::ref_hlo_path(Path::new("artifacts"), a, batch)
            .display()
            .to_string()
    }));
    let features: usize = model.input_shape.iter().product();
    let pjrt = tablenet::runtime::PjrtModel::load(&hlo, batch, features, 10)?;
    println!("PJRT platform: {}", pjrt.platform());
    let ds = dataset(args)?;
    let n = args.get_usize("n", 32);
    let mut max_diff = 0f32;
    let mut agree = 0usize;
    for i in 0..n {
        let img = ds.test.image(i).to_vec();
        let pj = pjrt.infer_padded(&[img.clone()])?;
        let shape: Vec<usize> = std::iter::once(1usize)
            .chain(model.input_shape.iter().copied())
            .collect();
        let rust_out = model.forward(&Tensor::new(&shape, img));
        for (x, y) in pj[0].iter().zip(rust_out.data()) {
            max_diff = max_diff.max((x - y).abs());
        }
        let pj_class = pj[0]
            .iter()
            .enumerate()
            .max_by(|u, v| u.1.partial_cmp(v.1).unwrap())
            .unwrap()
            .0;
        if pj_class == rust_out.argmax_rows()[0] {
            agree += 1;
        }
    }
    println!(
        "PJRT vs rust forward over {n} samples: max |Δlogit| = {max_diff:.2e}, argmax agreement {agree}/{n}"
    );
    anyhow::ensure!(agree == n, "prediction mismatch between PJRT and rust reference");
    Ok(())
}
