//! # TableNet
//!
//! A multiplier-less implementation of neural networks for inferencing,
//! reproducing Wu, "TableNet: a multiplier-less implementation of neural
//! networks for inferencing" (2019).
//!
//! The crate is organised as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the Rust serving runtime: LUT
//!   construction, the multiplier-less inference engine, the partition
//!   planner / cost model, a hot-swappable multi-model registry (per-
//!   model dynamic batching pipelines behind one router), and the
//!   experiment harness that regenerates every figure of the paper.
//! * **Layer 2 (`python/compile/model.py`)** — JAX model definitions
//!   (linear / MLP / LeNet CNN) with quantization-aware training; lowered
//!   once to HLO text and executed from Rust via PJRT (`runtime`).
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   bitplane-LUT matmul hot-spot, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: `make artifacts` exports HLO
//! text + trained weights, and the Rust binary is self-contained after.

pub mod bytes;
pub mod tensor;
pub mod quant;
pub mod lut;
pub mod nn;
pub mod engine;
pub mod planner;
pub mod data;
pub mod train;
pub mod coordinator;
pub mod runtime;
pub mod harness;
pub mod net;
pub mod config;
pub mod util;
