//! Configuration sweeps for each paper figure: enumerate the partition /
//! precision space, evaluate costs, return points ready for the
//! harness/bench layer to print or dump to CSV.

use super::{arch_geometry, evaluate_plan, PlanPoint};
use crate::engine::plan::{AffineMode, EnginePlan};
use crate::nn::Arch;

/// Chunk sizes that divide 784 (the linear/MLP input) — the natural
/// partition ladder for Figs. 5.
pub const DIVISORS_784: &[usize] = &[1, 2, 4, 7, 8, 14, 16, 28, 49, 56, 98, 112, 196];

/// Fig. 5 sweep: linear classifier, fixed 3-bit input (the accuracy
/// plateau from Fig. 4), bitplane and whole-code indexing across chunk
/// sizes. Same costs apply to MNIST and Fashion-MNIST (the figure plots
/// both datasets on one tradeoff curve).
pub fn linear_tradeoff(bits: u32) -> Vec<PlanPoint> {
    let geoms = arch_geometry(Arch::Linear);
    let mut pts = Vec::new();
    for &m in DIVISORS_784 {
        for mode in [
            AffineMode::BitplaneFixed { bits, m, range_exp: 0 },
            AffineMode::WholeFixed { bits, m, range_exp: 0 },
        ] {
            // skip absurd whole-code chunks (beyond u64 sizes)
            if let AffineMode::WholeFixed { .. } = mode {
                if m as u64 * bits as u64 > 48 {
                    continue;
                }
            }
            let plan = EnginePlan {
                affine: vec![mode],
                fallback: AffineMode::Float { planes: 11, m: 1 },
                r_o: 16,
            };
            let pt = evaluate_plan(&geoms, &plan);
            // keep the figure's axis meaningful: drop configs beyond a
            // pebibyte (the paper's plot spans KiB..GiB)
            if pt.size_bits < 1u64 << 53 {
                pts.push(pt);
            }
        }
    }
    pts
}

/// Fig. 7 sweep: MLP with 8-bit fixed input layer and binary16 inner
/// layers; varies the inner chunk size m (whole-code vs bitplaned) and
/// the first-layer chunking.
pub fn mlp_tradeoff() -> Vec<PlanPoint> {
    let geoms = arch_geometry(Arch::Mlp);
    let mut pts = Vec::new();
    // all-float plans (the paper's bitplaned family): chunk size per
    // layer; index bits = 6m, keep within u64 sizes
    for &m_in in &[1usize, 2, 3, 4] {
        for &m1 in &[1usize, 2, 4] {
            let plan = EnginePlan {
                affine: vec![
                    AffineMode::Float { planes: 11, m: m1 },
                    AffineMode::Float { planes: 11, m: m_in },
                    AffineMode::Float { planes: 11, m: m_in },
                ],
                fallback: AffineMode::Float { planes: 11, m: 1 },
                r_o: 16,
            };
            pts.push(evaluate_plan(&geoms, &plan));
        }
    }
    // fixed-8-bit first layer (the paper's input-encoding ablation)
    for &m_in in &[1usize, 2] {
        for &m1 in &[1usize, 2, 4, 7] {
            let plan = EnginePlan {
                affine: vec![
                    AffineMode::WholeFixed { bits: 8, m: m1, range_exp: 0 },
                    AffineMode::Float { planes: 11, m: m_in },
                    AffineMode::Float { planes: 11, m: m_in },
                ],
                fallback: AffineMode::Float { planes: 11, m: 1 },
                r_o: 16,
            };
            pts.push(evaluate_plan(&geoms, &plan));
        }
    }
    // the paper's whole-16-bit configuration (impractically large)
    for &r_i in &[15u32, 16] {
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::WholeFixed { bits: r_i, m: 1, range_exp: 0 },
                AffineMode::WholeFixed { bits: r_i, m: 1, range_exp: 0 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        pts.push(evaluate_plan(&geoms, &plan));
    }
    pts
}

/// Fig. 8 sweep: LeNet CNN; spatial blocks for conv1, float planes for
/// the rest, plus whole-code variants for the dense tail.
pub fn cnn_tradeoff() -> Vec<PlanPoint> {
    let geoms = arch_geometry(Arch::Cnn);
    let mut pts = Vec::new();
    for &mc in &[1usize, 2, 4] {
        for &md in &[1usize, 2, 3] {
            let plan = EnginePlan {
                affine: vec![
                    AffineMode::BitplaneFixed { bits: 8, m: mc, range_exp: 0 },
                    AffineMode::Float { planes: 11, m: 1 },
                    AffineMode::Float { planes: 11, m: md },
                    AffineMode::Float { planes: 11, m: md },
                ],
                fallback: AffineMode::Float { planes: 11, m: 1 },
                r_o: 16,
            };
            pts.push(evaluate_plan(&geoms, &plan));
        }
    }
    // whole-code dense tail (the paper's 12.26 GiB-class config)
    for &r_i in &[15u32] {
        let plan = EnginePlan {
            affine: vec![
                AffineMode::BitplaneFixed { bits: 8, m: 2, range_exp: 0 },
                AffineMode::Float { planes: 11, m: 1 },
                AffineMode::WholeFixed { bits: r_i, m: 1, range_exp: 0 },
                AffineMode::WholeFixed { bits: r_i, m: 1, range_exp: 0 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        pts.push(evaluate_plan(&geoms, &plan));
    }
    pts
}

/// Input-bits ladder for Figs. 4 and 6 (accuracy sweeps pair these with
/// measured accuracy from the engine; cost side only here).
pub fn bits_ladder() -> Vec<u32> {
    (1..=8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::pareto;

    #[test]
    fn linear_sweep_covers_paper_points() {
        let pts = linear_tradeoff(3);
        // must contain the 56-LUT/17.5MiB and 784-LUT/30.6KiB configs
        assert!(pts.iter().any(|p| p.num_luts == 56
            && (p.size_bits as f64 / (8.0 * 1024.0 * 1024.0) - 17.5).abs() < 0.01));
        assert!(pts
            .iter()
            .any(|p| p.num_luts == 784 && p.size_bits == 784 * 2 * 10 * 16));
    }

    #[test]
    fn linear_sweep_has_nontrivial_pareto() {
        let pts = linear_tradeoff(3);
        let front = pareto(&pts);
        assert!(front.len() >= 4, "frontier too small: {}", front.len());
    }

    #[test]
    fn mlp_sweep_includes_paper_configs() {
        let pts = mlp_tradeoff();
        // bitplaned config: 2320 LUTs, 162.6 MiB, 14,652,918 adds
        assert!(pts.iter().any(|p| p.num_luts == 2320 && p.ops == 14_652_918));
        // whole-code 15-bit config: 1,330,678 adds
        assert!(pts.iter().any(|p| p.ops == 1_330_678));
    }

    #[test]
    fn cnn_sweep_spans_orders_of_magnitude() {
        let pts = cnn_tradeoff();
        let min = pts.iter().map(|p| p.size_bits).min().unwrap();
        let max = pts.iter().map(|p| p.size_bits).max().unwrap();
        assert!(max / min.max(1) > 100, "sweep too narrow: {min}..{max}");
    }

    #[test]
    fn cnn_sweep_contains_400mib_class_config() {
        // paper: "total LUT size is 400 MiB" for all-single-element float
        let pts = cnn_tradeoff();
        let close = pts
            .iter()
            .map(|p| p.size_bits as f64 / (8.0 * 1024.0 * 1024.0))
            .filter(|mib| (*mib - 400.0).abs() < 200.0)
            .count();
        assert!(close >= 1);
    }
}
