//! Partition planner: sweeps LUT configurations per architecture,
//! evaluates the paper's cost formulas, and extracts the Pareto frontier
//! of total-LUT-size vs operation-count — the machinery behind Figs. 5,
//! 7 and 8 and the planner behind `tablenet plan`.

pub mod sweep;

use crate::engine::plan::{AffineMode, EnginePlan};
use crate::lut::cost::{conv_cost, dense_cost};
use crate::nn::Arch;

/// One evaluated configuration: the plan plus its aggregate costs.
#[derive(Debug, Clone)]
pub struct PlanPoint {
    pub plan: EnginePlan,
    /// Human-readable config label, e.g. "plane r3 m14".
    pub label: String,
    pub num_luts: u64,
    pub size_bits: u64,
    pub lut_evals: u64,
    /// Paper convention: (n·k − 1)·p summed over layers.
    pub ops: u64,
    /// n·(k−1)·p convention (paper Fig. 5 text).
    pub ops_exclusive: u64,
    /// n·k·p convention.
    pub ops_inclusive: u64,
    pub ref_macs: u64,
    /// Whether every table fits the materialisation cap (the engine can
    /// actually run it, vs planner-only accounting).
    pub materialisable: bool,
}

/// Layer geometry for cost evaluation.
#[derive(Debug, Clone, Copy)]
pub enum LayerGeom {
    Dense { q: u64, p: u64 },
    Conv { h: u64, w: u64, cin: u64, cout: u64, r: u64 },
}

/// The affine-layer geometries of each paper architecture.
pub fn arch_geometry(arch: Arch) -> Vec<LayerGeom> {
    match arch {
        Arch::Linear => vec![LayerGeom::Dense { q: 784, p: 10 }],
        Arch::Mlp => vec![
            LayerGeom::Dense { q: 784, p: 1024 },
            LayerGeom::Dense { q: 1024, p: 512 },
            LayerGeom::Dense { q: 512, p: 10 },
        ],
        Arch::Cnn => vec![
            LayerGeom::Conv { h: 28, w: 28, cin: 1, cout: 32, r: 2 },
            LayerGeom::Conv { h: 14, w: 14, cin: 32, cout: 64, r: 2 },
            LayerGeom::Dense { q: 3136, p: 1024 },
            LayerGeom::Dense { q: 1024, p: 10 },
        ],
    }
}

/// Aggregate the costs of a full plan over an architecture's geometry.
pub fn evaluate_plan(geoms: &[LayerGeom], plan: &EnginePlan) -> PlanPoint {
    let mut num_luts = 0u64;
    let mut size_bits = 0u64;
    let mut lut_evals = 0u64;
    let mut ops = 0u64;
    let mut ops_ex = 0u64;
    let mut ops_in = 0u64;
    let mut ref_macs = 0u64;
    let mut materialisable = true;
    let mut labels = Vec::new();
    for (i, geom) in geoms.iter().enumerate() {
        let mode = plan.affine.get(i).unwrap_or(&plan.fallback);
        let im = mode.index_mode();
        match *geom {
            LayerGeom::Dense { q, p } => {
                let c = dense_cost(q, p, mode.m() as u64, im, plan.r_o);
                num_luts += c.num_luts;
                size_bits = size_bits.saturating_add(c.size_bits);
                lut_evals += c.lut_evals;
                ops += c.adds;
                ops_ex += c.adds_exclusive;
                ops_in += c.adds_inclusive;
                ref_macs += c.ref_macs;
                let idx_bits = mode.m() as u64 * im.index_bits_per_elem() as u64;
                let rows = if idx_bits >= 63 { u64::MAX } else { 1u64 << idx_bits };
                if rows.saturating_mul(p).saturating_mul(8)
                    > crate::lut::MAX_TABLE_BYTES as u64
                {
                    materialisable = false;
                }
            }
            LayerGeom::Conv { h, w, cin, cout, r } => {
                let c = conv_cost(h, w, cin, cout, r, mode.m() as u64, im, plan.r_o);
                num_luts += c.num_luts;
                size_bits = size_bits.saturating_add(c.size_bits);
                lut_evals += c.lut_evals;
                ops += c.adds;
                ops_ex += c.adds;
                ops_in += c.adds;
                ref_macs += c.ref_macs;
                let a = (mode.m() * mode.m()) as u64;
                let idx_bits = a * im.index_bits_per_elem() as u64;
                let patch = (mode.m() as u64 + 2 * r).pow(2) * cout;
                let rows = if idx_bits >= 63 { u64::MAX } else { 1u64 << idx_bits };
                if rows.saturating_mul(patch).saturating_mul(8)
                    > crate::lut::MAX_TABLE_BYTES as u64
                {
                    materialisable = false;
                }
            }
        }
        labels.push(mode_label(mode));
    }
    PlanPoint {
        plan: plan.clone(),
        label: labels.join(" | "),
        num_luts,
        size_bits,
        lut_evals,
        ops,
        ops_exclusive: ops_ex,
        ops_inclusive: ops_in,
        ref_macs,
        materialisable,
    }
}

fn mode_label(m: &AffineMode) -> String {
    match *m {
        AffineMode::WholeFixed { bits, m, .. } => format!("whole r{bits} m{m}"),
        AffineMode::BitplaneFixed { bits, m, .. } => format!("plane r{bits} m{m}"),
        AffineMode::Float { planes, m } => format!("f16 x{planes} m{m}"),
    }
}

/// Extract the Pareto frontier (strictly decreasing ops as size grows);
/// result sorted by size ascending, as the paper's figure captions say
/// ("sorted according to total LUT size").
pub fn pareto(points: &[PlanPoint]) -> Vec<PlanPoint> {
    let mut sorted: Vec<&PlanPoint> = points.iter().collect();
    sorted.sort_by_key(|p| (p.size_bits, p.ops));
    let mut out: Vec<PlanPoint> = Vec::new();
    let mut best_ops = u64::MAX;
    for p in sorted {
        if p.ops < best_ops {
            best_ops = p.ops;
            out.push(p.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_default_point_matches_paper() {
        let geoms = arch_geometry(Arch::Linear);
        let pt = evaluate_plan(&geoms, &EnginePlan::linear_default());
        assert_eq!(pt.num_luts, 56);
        assert_eq!(pt.lut_evals, 168);
        let mib = pt.size_bits as f64 / (8.0 * 1024.0 * 1024.0);
        assert!((mib - 17.5).abs() < 0.01, "{mib}");
        assert_eq!(pt.ops_exclusive, 1650);
        assert!(pt.materialisable);
    }

    #[test]
    fn mlp_default_matches_paper_lut_count() {
        let geoms = arch_geometry(Arch::Mlp);
        let pt = evaluate_plan(&geoms, &EnginePlan::mlp_default());
        assert_eq!(pt.num_luts, 2320);
        assert_eq!(pt.ref_macs, 1_332_224);
    }

    #[test]
    fn cnn_geometry_macs() {
        let geoms = arch_geometry(Arch::Cnn);
        let pt = evaluate_plan(&geoms, &EnginePlan::cnn_default());
        // conv1 28²·25·32 = 627,200; conv2 14²·25·32·64 = 10,035,200;
        // fc1 3136·1024 = 3,211,264; fc2 10,240 → 13.88M ('same'
        // padding counted densely; the paper quotes ≈12.9M)
        assert_eq!(pt.ref_macs, 13_883_904);
        assert!(pt.materialisable);
    }

    #[test]
    fn pareto_is_monotone() {
        let geoms = arch_geometry(Arch::Linear);
        let pts: Vec<PlanPoint> = [1usize, 2, 4, 7, 14, 28, 56]
            .iter()
            .map(|&m| {
                let mut plan = EnginePlan::linear_default();
                plan.affine[0] =
                    AffineMode::BitplaneFixed { bits: 3, m, range_exp: 0 };
                evaluate_plan(&geoms, &plan)
            })
            .collect();
        let front = pareto(&pts);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].size_bits >= w[0].size_bits);
            assert!(w[1].ops < w[0].ops);
        }
    }

    #[test]
    fn bigger_chunks_cost_more_memory_fewer_ops() {
        let geoms = arch_geometry(Arch::Linear);
        let mut small = EnginePlan::linear_default();
        small.affine[0] = AffineMode::BitplaneFixed { bits: 3, m: 2, range_exp: 0 };
        let mut big = EnginePlan::linear_default();
        big.affine[0] = AffineMode::BitplaneFixed { bits: 3, m: 16, range_exp: 0 };
        let ps = evaluate_plan(&geoms, &small);
        let pb = evaluate_plan(&geoms, &big);
        assert!(pb.size_bits > ps.size_bits);
        assert!(pb.ops < ps.ops);
    }

    #[test]
    fn mlp_whole_code_reproduces_32_7_gib() {
        let geoms = arch_geometry(Arch::Mlp);
        let plan = EnginePlan {
            affine: vec![
                AffineMode::WholeFixed { bits: 8, m: 1, range_exp: 0 },
                AffineMode::WholeFixed { bits: 15, m: 1, range_exp: 0 },
                AffineMode::WholeFixed { bits: 15, m: 1, range_exp: 0 },
            ],
            fallback: AffineMode::Float { planes: 11, m: 1 },
            r_o: 16,
        };
        let pt = evaluate_plan(&geoms, &plan);
        let gib = pt.size_bits as f64 / (8.0 * 1024.0 * 1024.0 * 1024.0);
        assert!((gib - 32.7).abs() < 0.8, "{gib} GiB");
        assert_eq!(pt.num_luts, 2320);
        assert_eq!(pt.ops, 1_330_678);
    }
}
