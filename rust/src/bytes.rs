//! Read-only byte buffers behind the `.ltm` artifact loader: either an
//! owned heap buffer or a memory-mapped file.
//!
//! The v2 artifact format 64-byte-aligns every table-arena entry block
//! in the file, so a mapped artifact can be served *in place*: the
//! arenas borrow their entries straight out of the mapping instead of
//! copying them onto the heap (see [`crate::lut::arena`]). Table
//! payloads thus never touch the heap on load — zero copies, zero
//! allocations proportional to bank size. The load still *reads* the
//! file once (the per-stage checksums are verified sequentially, at
//! page-cache/disk streaming bandwidth), so a rolling deploy swap
//! costs one sequential scan instead of scan + decode + allocate +
//! memcpy; after that, requests hit the tables in place.
//!
//! The vendored crate set has no `memmap2`, so the mapping is a ~40
//! line `mmap`/`munmap` FFI against the libc the binary already links.
//! Platforms without it (non-unix) transparently fall back to the
//! owned-read path — everything still works, just with the copy.

use std::io::Read;
use std::path::Path;

/// A read-only mapped file region. Pages are faulted in on demand;
/// the mapping is unmapped on drop.
#[cfg(unix)]
pub struct MappedFile {
    ptr: *const u8,
    len: usize,
}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;
    pub type CInt = i32;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: CInt,
            flags: CInt,
            fd: CInt,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> CInt;
    }
    pub const PROT_READ: CInt = 1;
    pub const MAP_PRIVATE: CInt = 2;
}

#[cfg(unix)]
impl MappedFile {
    /// Map `file` read-only in its entirety (`len` must be the file's
    /// current size, > 0).
    pub fn map(file: &std::fs::File, len: usize) -> std::io::Result<MappedFile> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "cannot map an empty file",
            ));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            return Err(std::io::Error::last_os_error());
        }
        Ok(MappedFile { ptr: ptr as *const u8, len })
    }

    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: the mapping is PROT_READ/MAP_PRIVATE over `len` bytes
        // and stays valid until drop. A concurrent truncate of the
        // backing file could SIGBUS any file-mapping reader; deploys
        // write artifacts atomically (write + rename or whole-file
        // overwrite), matching every mmap-serving system's contract.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

#[cfg(unix)]
impl Drop for MappedFile {
    fn drop(&mut self) {
        unsafe {
            sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
        }
    }
}

// SAFETY: the region is immutable (PROT_READ) and owned by this handle.
#[cfg(unix)]
unsafe impl Send for MappedFile {}
#[cfg(unix)]
unsafe impl Sync for MappedFile {}

/// Backing bytes of a loaded artifact: a plain heap buffer, or a file
/// mapping that arenas may borrow from zero-copy. `Deref`s to `[u8]`
/// either way, so parsing code never branches on the variant.
pub enum ArtifactBytes {
    Owned(Vec<u8>),
    #[cfg(unix)]
    Mapped(MappedFile),
}

impl ArtifactBytes {
    /// Open `path`, preferring a read-only mapping; falls back to an
    /// owned read when mapping is unavailable (non-unix, empty file,
    /// or an `mmap` failure). Rejects files larger than `cap` before
    /// touching their contents.
    pub fn open(path: &Path, cap: u64) -> std::io::Result<ArtifactBytes> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len > cap {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("file is {len} bytes — larger than the {cap} byte cap"),
            ));
        }
        #[cfg(unix)]
        if len > 0 {
            if let Ok(m) = MappedFile::map(&file, len as usize) {
                return Ok(ArtifactBytes::Mapped(m));
            }
        }
        let mut buf = Vec::with_capacity(len as usize);
        let mut file = file;
        file.read_to_end(&mut buf)?;
        Ok(ArtifactBytes::Owned(buf))
    }

    /// True when the bytes are a live file mapping (the zero-copy
    /// borrow substrate).
    pub fn is_mapped(&self) -> bool {
        match self {
            ArtifactBytes::Owned(_) => false,
            #[cfg(unix)]
            ArtifactBytes::Mapped(_) => true,
        }
    }

    /// True when `slice` lies entirely within this buffer — the guard
    /// the arena loader checks before borrowing a sub-slice against
    /// this owner's lifetime.
    pub fn contains(&self, slice: &[u8]) -> bool {
        let base = self.as_ref().as_ptr() as usize;
        let end = base + self.as_ref().len();
        let s = slice.as_ptr() as usize;
        s >= base && s + slice.len() <= end
    }
}

impl AsRef<[u8]> for ArtifactBytes {
    fn as_ref(&self) -> &[u8] {
        match self {
            ArtifactBytes::Owned(v) => v,
            #[cfg(unix)]
            ArtifactBytes::Mapped(m) => m.as_slice(),
        }
    }
}

impl std::ops::Deref for ArtifactBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("tablenet_bytes_{name}"));
        std::fs::write(&p, contents).unwrap();
        p
    }

    #[test]
    fn open_maps_and_reads_back_exactly() {
        let data: Vec<u8> = (0..8192u32).map(|i| (i * 7) as u8).collect();
        let p = tmp("roundtrip", &data);
        let b = ArtifactBytes::open(&p, 1 << 20).unwrap();
        assert_eq!(&b[..], &data[..]);
        #[cfg(unix)]
        assert!(b.is_mapped(), "unix open should map");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_falls_back_to_owned() {
        let p = tmp("empty", b"");
        let b = ArtifactBytes::open(&p, 1 << 20).unwrap();
        assert!(!b.is_mapped());
        assert!(b.is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cap_is_enforced_before_reading() {
        let p = tmp("cap", &[0u8; 100]);
        assert!(ArtifactBytes::open(&p, 99).is_err());
        assert!(ArtifactBytes::open(&p, 100).is_ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn contains_checks_pointer_range() {
        let b = ArtifactBytes::Owned(vec![1u8; 64]);
        assert!(b.contains(&b[10..20]));
        assert!(b.contains(&b[..]));
        let other = [0u8; 16];
        assert!(!b.contains(&other));
    }

    #[cfg(unix)]
    #[test]
    fn mapping_survives_file_unlink() {
        // a deployed model must keep serving after its artifact file is
        // replaced/unlinked (standard rolling-deploy pattern)
        let data = vec![0xABu8; 4096];
        let p = tmp("unlink", &data);
        let b = ArtifactBytes::open(&p, 1 << 20).unwrap();
        std::fs::remove_file(&p).unwrap();
        assert_eq!(&b[..], &data[..]);
    }
}
