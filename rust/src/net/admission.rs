//! Shared cross-model admission control: one token budget capping the
//! aggregate number of rows in flight across every model behind the
//! serving tier, with per-model weights deciding how the budget splits
//! under contention.
//!
//! Why a *shared* budget: table-based inference is memory-bound, and
//! LUT working-set pressure compounds across co-resident models — N
//! per-model queues each sized for a model alone will happily admit
//! N models' worth of traffic and thrash the cache together. The
//! admission controller meters total in-flight rows *before* they
//! reach any per-model queue.
//!
//! Semantics: with budget `B`, lane weight `w` and total registered
//! weight `W`, a frame of `r` rows for a model is admitted iff after
//! admission
//!
//! * total in-flight rows `<= B` (aggregate cap), and
//! * the model's in-flight rows `* W <= B * w` (weighted fair share).
//!
//! Both checks are taken optimistically on atomics and undone on
//! rejection, so the fast path is two `fetch_add`s and no lock. A
//! budget of `0` disables both checks (metering continues, for
//! metrics). Rejections surface to clients as
//! [`Status::AdmissionRejected`](crate::net::proto::Status) — a
//! queue-full-class typed error, distinct from per-model
//! [`QueueFull`](crate::coordinator::ServeError) so operators can tell
//! "this model is slow" from "the box is full".

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

#[derive(Debug, Default)]
struct Lane {
    weight: AtomicU64,
    in_flight: AtomicU64,
    admitted_rows: AtomicU64,
    rejected_rows: AtomicU64,
}

/// The shared admission controller. Cheap to clone via `Arc`; all
/// methods take `&self`.
#[derive(Debug)]
pub struct AdmissionController {
    budget: u64,
    total_weight: AtomicU64,
    in_flight: AtomicU64,
    lanes: RwLock<BTreeMap<String, Arc<Lane>>>,
}

impl AdmissionController {
    /// A controller capping aggregate in-flight rows at `budget`
    /// (`0` = unlimited: count, never reject).
    pub fn new(budget: u64) -> AdmissionController {
        AdmissionController {
            budget,
            total_weight: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            lanes: RwLock::new(BTreeMap::new()),
        }
    }

    /// The configured aggregate budget (0 = unlimited).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Rows currently admitted and not yet released.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    fn lane(&self, model: &str) -> Arc<Lane> {
        if let Some(lane) = self.lanes.read().unwrap_or_else(|e| e.into_inner()).get(model) {
            return lane.clone();
        }
        let mut lanes = self.lanes.write().unwrap_or_else(|e| e.into_inner());
        lanes
            .entry(model.to_string())
            .or_insert_with(|| {
                self.total_weight.fetch_add(1, Ordering::Relaxed);
                Arc::new(Lane {
                    weight: AtomicU64::new(1),
                    ..Lane::default()
                })
            })
            .clone()
    }

    /// Set `model`'s queue weight (creates the lane if needed).
    /// Weights are relative: a weight-3 lane gets 3x the fair share of
    /// a weight-1 lane under contention. Zero is clamped to 1.
    pub fn set_weight(&self, model: &str, weight: u64) {
        let weight = weight.max(1);
        let lane = self.lane(model);
        // swap under the lane map's write lock so total_weight stays
        // consistent with the sum of lane weights
        let _guard = self.lanes.write().unwrap_or_else(|e| e.into_inner());
        let old = lane.weight.swap(weight, Ordering::Relaxed);
        if weight >= old {
            self.total_weight.fetch_add(weight - old, Ordering::Relaxed);
        } else {
            self.total_weight.fetch_sub(old - weight, Ordering::Relaxed);
        }
    }

    /// Try to admit `rows` rows for `model`. On `true` the caller owns
    /// the tokens and must [`release`](Self::release) them once the
    /// rows' verdicts are collected; on `false` nothing is held.
    pub fn try_admit(&self, model: &str, rows: u64) -> bool {
        let lane = self.lane(model);
        let total = self.in_flight.fetch_add(rows, Ordering::Relaxed) + rows;
        let mine = lane.in_flight.fetch_add(rows, Ordering::Relaxed) + rows;
        if self.budget > 0 {
            let w = lane.weight.load(Ordering::Relaxed);
            let total_w = self.total_weight.load(Ordering::Relaxed).max(1);
            // aggregate cap, then weighted fair share (B*w/W), both
            // evaluated multiplier-free of floating point
            if total > self.budget || mine * total_w > self.budget * w {
                self.in_flight.fetch_sub(rows, Ordering::Relaxed);
                lane.in_flight.fetch_sub(rows, Ordering::Relaxed);
                lane.rejected_rows.fetch_add(rows, Ordering::Relaxed);
                return false;
            }
        }
        lane.admitted_rows.fetch_add(rows, Ordering::Relaxed);
        true
    }

    /// Return `rows` previously admitted tokens for `model`.
    pub fn release(&self, model: &str, rows: u64) {
        let lane = self.lane(model);
        self.in_flight.fetch_sub(rows, Ordering::Relaxed);
        lane.in_flight.fetch_sub(rows, Ordering::Relaxed);
    }

    /// Point-in-time snapshot of the budget and every lane.
    pub fn snapshot(&self) -> AdmissionSnapshot {
        let lanes = self.lanes.read().unwrap_or_else(|e| e.into_inner());
        AdmissionSnapshot {
            budget: self.budget,
            in_flight: self.in_flight.load(Ordering::Relaxed),
            lanes: lanes
                .iter()
                .map(|(name, lane)| {
                    (
                        name.clone(),
                        LaneSnapshot {
                            weight: lane.weight.load(Ordering::Relaxed),
                            in_flight: lane.in_flight.load(Ordering::Relaxed),
                            admitted_rows: lane.admitted_rows.load(Ordering::Relaxed),
                            rejected_rows: lane.rejected_rows.load(Ordering::Relaxed),
                        },
                    )
                })
                .collect(),
        }
    }
}

/// A token bucket: `capacity` tokens that refill continuously at
/// `refill_per_sec`, consumed in whole-token units. This is the one
/// rate/budget primitive the net tier layers *in front of* the row
/// [`AdmissionController`]: the server arms one bucket per connection
/// for frames and one for rows (burst = one second of the configured
/// rate), and the reconnecting client uses a bucket as its retry
/// budget (reconnect attempts spend tokens; an empty bucket turns a
/// flaky link into a typed terminal failure instead of an infinite
/// retry loop).
///
/// Time is supplied by the caller through [`TokenBucket::take`]'s
/// `elapsed` argument, which keeps the bucket deterministic under
/// test and free of hidden clock reads; [`TokenBucket::take_now`] is
/// the wall-clock convenience used by serving code.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    capacity: f64,
    tokens: f64,
    refill_per_sec: f64,
    last: std::time::Instant,
}

impl TokenBucket {
    /// A bucket starting full at `capacity`, refilling at
    /// `refill_per_sec` (0 = a pure budget that never refills).
    pub fn new(capacity: u64, refill_per_sec: f64) -> TokenBucket {
        TokenBucket {
            capacity: capacity as f64,
            tokens: capacity as f64,
            refill_per_sec: refill_per_sec.max(0.0),
            last: std::time::Instant::now(),
        }
    }

    /// Credit `elapsed` seconds of refill, then try to spend `n`
    /// tokens. Returns `true` when the bucket held them; on `false`
    /// nothing is spent (all-or-nothing, so one oversized frame cannot
    /// starve the bucket to a permanently negative balance).
    pub fn take(&mut self, n: u64, elapsed: std::time::Duration) -> bool {
        self.tokens =
            (self.tokens + elapsed.as_secs_f64() * self.refill_per_sec).min(self.capacity);
        if self.tokens >= n as f64 {
            self.tokens -= n as f64;
            true
        } else {
            false
        }
    }

    /// [`take`](Self::take) against the wall clock since the previous
    /// call.
    pub fn take_now(&mut self, n: u64) -> bool {
        let now = std::time::Instant::now();
        let elapsed = now.duration_since(self.last);
        self.last = now;
        self.take(n, elapsed)
    }

    /// Whole tokens currently available (no refill applied).
    pub fn available(&self) -> u64 {
        self.tokens as u64
    }
}

/// Frozen view of one lane's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneSnapshot {
    /// Relative queue weight.
    pub weight: u64,
    /// Rows admitted and not yet released at snapshot time.
    pub in_flight: u64,
    /// Total rows ever admitted.
    pub admitted_rows: u64,
    /// Total rows ever rejected by the budget.
    pub rejected_rows: u64,
}

/// Frozen view of the whole controller.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AdmissionSnapshot {
    /// Aggregate budget (0 = unlimited).
    pub budget: u64,
    /// Rows in flight at snapshot time.
    pub in_flight: u64,
    /// Per-model lanes.
    pub lanes: BTreeMap<String, LaneSnapshot>,
}

impl std::fmt::Display for AdmissionSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.budget == 0 {
            write!(f, "admission: unlimited, {} in flight", self.in_flight)?;
        } else {
            write!(f, "admission: budget {} rows, {} in flight", self.budget, self.in_flight)?;
        }
        for (name, lane) in &self.lanes {
            write!(
                f,
                " | {name} w={} {} admitted / {} rejected",
                lane.weight, lane.admitted_rows, lane.rejected_rows
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_budget_admits_everything_but_still_meters() {
        let ac = AdmissionController::new(0);
        for _ in 0..100 {
            assert!(ac.try_admit("m", 64));
        }
        assert_eq!(ac.in_flight(), 6400);
        ac.release("m", 6400);
        assert_eq!(ac.in_flight(), 0);
        let snap = ac.snapshot();
        assert_eq!(snap.lanes["m"].admitted_rows, 6400);
        assert_eq!(snap.lanes["m"].rejected_rows, 0);
    }

    #[test]
    fn aggregate_budget_caps_in_flight_rows() {
        let ac = AdmissionController::new(100);
        assert!(ac.try_admit("m", 60));
        assert!(ac.try_admit("m", 40));
        assert!(!ac.try_admit("m", 1), "budget exhausted");
        ac.release("m", 40);
        assert!(ac.try_admit("m", 40), "released tokens are reusable");
        let snap = ac.snapshot();
        assert_eq!(snap.in_flight, 100);
        assert_eq!(snap.lanes["m"].rejected_rows, 1);
    }

    #[test]
    fn weights_skew_acceptance_under_contention() {
        // two models, weight 3 vs 1: fair shares of a 100-row budget
        // are 75 and 25
        let ac = AdmissionController::new(100);
        ac.set_weight("heavy", 3);
        ac.set_weight("light", 1);
        assert!(ac.try_admit("heavy", 75));
        assert!(!ac.try_admit("heavy", 1), "heavy is at its 3/4 share");
        assert!(ac.try_admit("light", 25));
        assert!(!ac.try_admit("light", 1), "light is at its 1/4 share");

        // identical offered load, weighted acceptance: heavy keeps 3x
        // the rows in flight that light does
        let snap = ac.snapshot();
        assert_eq!(snap.lanes["heavy"].in_flight, 75);
        assert_eq!(snap.lanes["light"].in_flight, 25);
        assert_eq!(snap.in_flight, 100);
    }

    #[test]
    fn equal_weights_split_the_budget_evenly() {
        let ac = AdmissionController::new(64);
        ac.set_weight("a", 1);
        ac.set_weight("b", 1);
        assert!(ac.try_admit("a", 32));
        assert!(!ac.try_admit("a", 1), "a capped at half");
        assert!(ac.try_admit("b", 32));
        assert!(!ac.try_admit("b", 1), "b capped at half");
    }

    #[test]
    fn reweighting_a_live_lane_moves_its_share() {
        let ac = AdmissionController::new(80);
        ac.set_weight("a", 1);
        ac.set_weight("b", 1);
        assert!(ac.try_admit("a", 40));
        assert!(!ac.try_admit("a", 1));
        // demote a to 1/4 share: existing in-flight rows keep their
        // tokens, but nothing more is admitted until it drains below
        // the new share
        ac.set_weight("b", 3);
        assert!(!ac.try_admit("a", 1));
        ac.release("a", 30);
        assert!(ac.try_admit("a", 10), "back under the new 20-row share");
        assert!(ac.try_admit("b", 60), "b's share grew to 3/4");
    }

    #[test]
    fn unknown_lane_defaults_to_weight_one() {
        let ac = AdmissionController::new(10);
        assert!(ac.try_admit("implicit", 10));
        assert_eq!(ac.snapshot().lanes["implicit"].weight, 1);
    }

    #[test]
    fn token_bucket_budget_and_refill_are_deterministic() {
        use std::time::Duration;
        // pure budget: no refill, 5 tokens, all-or-nothing spend
        let mut b = TokenBucket::new(5, 0.0);
        assert!(b.take(3, Duration::ZERO));
        assert!(!b.take(3, Duration::ZERO), "only 2 left; nothing spent");
        assert_eq!(b.available(), 2);
        assert!(b.take(2, Duration::ZERO));
        assert!(!b.take(1, Duration::from_secs(3600)), "rate 0 never refills");

        // refilling bucket: 10/s, capacity 10 (one-second burst)
        let mut b = TokenBucket::new(10, 10.0);
        assert!(b.take(10, Duration::ZERO), "full burst goes through");
        assert!(!b.take(1, Duration::ZERO));
        assert!(b.take(5, Duration::from_millis(500)), "half a second buys 5");
        assert!(!b.take(1, Duration::ZERO));
        // refill clamps at capacity: a long idle gap is not a mega-burst
        assert!(b.take(10, Duration::from_secs(100)));
        assert!(!b.take(1, Duration::ZERO));
    }

    #[test]
    fn concurrent_admits_never_exceed_budget() {
        let ac = Arc::new(AdmissionController::new(50));
        let peak = Arc::new(AtomicU64::new(0));
        let mut joins = Vec::new();
        for t in 0..8 {
            let ac = ac.clone();
            let peak = peak.clone();
            joins.push(std::thread::spawn(move || {
                let model = if t % 2 == 0 { "a" } else { "b" };
                for _ in 0..2000 {
                    if ac.try_admit(model, 5) {
                        peak.fetch_max(ac.in_flight(), Ordering::Relaxed);
                        ac.release(model, 5);
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(ac.in_flight(), 0, "every admit was released");
        // optimistic fetch_add can transiently overshoot by the in-
        // flight adds of racing rejected frames, but admitted rows
        // alone never exceed the budget; with 8 threads x 5 rows the
        // observable peak stays within budget + 7*5 overshoot
        assert!(peak.load(Ordering::Relaxed) <= 50 + 35, "peak {peak:?}");
    }
}
