//! Layer 4 — the network serving tier: a dependency-free socket front
//! for the model registry, so multiplier-less inference is reachable
//! from outside the process.
//!
//! ```text
//!   clients ──TCP──▶ reactors (thread-per-core, epoll/kqueue)
//!                        │  parse LTN1 frames, shared admission budget
//!                        ▼
//!                 dispatchers ──▶ FleetClient ──▶ per-model pipelines
//!                                 (registry)      (batcher + workers)
//! ```
//!
//! * [`proto`] — the `LTN1` length-prefixed binary protocol: frame
//!   codec, typed wire status codes, incremental deframer.
//! * [`poll`] — epoll/kqueue readiness polling behind a tiny FFI shim
//!   (no tokio/mio), unix only.
//! * [`admission`] — shared cross-model token budget with per-model
//!   queue weights, metering aggregate in-flight rows.
//! * [`metrics`] — per-connection and per-model ingress accounting,
//!   folded into [`FleetSnapshot`](crate::coordinator::metrics::FleetSnapshot).
//! * [`server`] — thread-per-core acceptor/reactor tier (unix only):
//!   Hello auth, per-connection rate limits, GoAway graceful drain,
//!   cross-connection replay cache for idempotency keys.
//! * [`client`] — blocking [`NetClient`] plus the budgeted
//!   [`ReconnectingClient`] behind `tablenet client`.
//!
//! Everything downstream of the dispatcher is the exact same code path
//! in-process push clients use, so swaps, deadlines, panic isolation
//! and the accounting invariant are identical for socket traffic.

pub mod admission;
pub mod client;
pub mod metrics;
#[cfg(unix)]
pub mod poll;
pub mod proto;
#[cfg(unix)]
pub mod server;

pub use admission::{AdmissionController, AdmissionSnapshot, LaneSnapshot, TokenBucket};
pub use client::{NetClient, ReconnectingClient, RetryPolicy, RetryStats};
pub use metrics::{ConnIngress, ModelIngress, NetMetrics, NetSnapshot, WireVersionStats};
pub use proto::{
    ErrorReply, Frame, GoAway, Hello, InferReply, InferRequest, RowReply, Status, WireError,
};
#[cfg(unix)]
pub use server::{
    drain_signal_received, install_drain_signal_handler, NetServer, NetServerOptions,
};
