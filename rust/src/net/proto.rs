//! Length-prefixed binary wire protocol for the network serving tier.
//!
//! Every frame on the wire is a `u32` little-endian length prefix (the
//! payload size in bytes, excluding the prefix itself) followed by the
//! payload. A payload starts with a fixed header — the 4-byte magic
//! `b"LTN1"`, a `u8` protocol version (currently [`VERSION`]) and a
//! `u8` frame kind — and continues with the kind-specific body:
//!
//! ```text
//! frame    := len:u32le payload[len]
//! payload  := magic[4]="LTN1" version:u8 kind:u8 body
//! request  := model_len:u16le model[model_len] rows:u16le features:u32le
//!             data: rows*features * f32le                     (kind 0x01)
//! reply    := rows:u16le row*rows                             (kind 0x02)
//! row      := status:u8 class:u16le version:u64le nlogits:u16le
//!             logits: nlogits * f32le          (nlogits = 0 on error rows)
//! error    := status:u8 msg_len:u16le msg[msg_len]            (kind 0x03)
//! ```
//!
//! Versioning rules: a magic mismatch or a version other than
//! [`VERSION`] is a protocol error — the server answers with a typed
//! [`Status::Malformed`] error frame and closes the connection (fails
//! closed). Unknown frame kinds and any limit violation
//! ([`MAX_FRAME_BYTES`], [`MAX_ROWS_PER_FRAME`], [`MAX_MODEL_NAME`],
//! [`MAX_FEATURES`]) are treated the same way. Additions within a
//! version must be purely appended frame kinds; anything that changes
//! the layout of an existing kind bumps the version byte.
//!
//! Error frames carry failures that void a whole request frame (unknown
//! model, admission rejection, malformed input, shutdown); per-row
//! pipeline verdicts (queue-full, deadline, panic) ride inside a normal
//! reply frame as per-row status bytes, so one frame can mix served and
//! shed rows.

use crate::coordinator::ServeError;

/// Frame magic: the first four payload bytes of every frame.
pub const MAGIC: [u8; 4] = *b"LTN1";
/// Current protocol version (the fifth payload byte).
pub const VERSION: u8 = 1;

/// Hard cap on a single frame payload (16 MiB). A length prefix above
/// this is rejected before any allocation happens.
pub const MAX_FRAME_BYTES: usize = 1 << 24;
/// Hard cap on rows per request frame.
pub const MAX_ROWS_PER_FRAME: usize = 4096;
/// Hard cap on the model-name field.
pub const MAX_MODEL_NAME: usize = 256;
/// Hard cap on the per-row feature count.
pub const MAX_FEATURES: usize = 1 << 20;

const KIND_REQUEST: u8 = 0x01;
const KIND_REPLY: u8 = 0x02;
const KIND_ERROR: u8 = 0x03;

/// Wire status codes: `0` is success, everything else is a typed
/// failure mapping [`ServeError`] (and the net tier's own rejection
/// modes) onto one byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    /// Row served; logits follow.
    Ok = 0,
    /// Pipeline ingress queue full (per-model backpressure).
    QueueFull = 1,
    /// Deadline exceeded before or during batching.
    DeadlineExceeded = 2,
    /// The worker executing the batch panicked; row shed, not lost.
    WorkerPanicked = 3,
    /// Pipeline (or the whole server) is draining.
    ShutDown = 4,
    /// No model under the requested name.
    UnknownModel = 5,
    /// The shared cross-model admission budget rejected the frame.
    AdmissionRejected = 6,
    /// The frame violated the protocol; the connection is closed.
    Malformed = 7,
}

impl Status {
    /// Decode a wire status byte.
    pub fn from_u8(v: u8) -> Option<Status> {
        Some(match v {
            0 => Status::Ok,
            1 => Status::QueueFull,
            2 => Status::DeadlineExceeded,
            3 => Status::WorkerPanicked,
            4 => Status::ShutDown,
            5 => Status::UnknownModel,
            6 => Status::AdmissionRejected,
            7 => Status::Malformed,
            _ => return None,
        })
    }

    /// True for the backpressure family: the request was refused to
    /// protect capacity (retry later), as opposed to being wrong.
    /// Covers both per-model queue rejection and the shared admission
    /// budget.
    pub fn is_queue_full_class(self) -> bool {
        matches!(self, Status::QueueFull | Status::AdmissionRejected)
    }

    /// Map a pipeline [`ServeError`] onto its wire status.
    pub fn from_serve_error(e: &ServeError) -> Status {
        match e {
            ServeError::QueueFull => Status::QueueFull,
            ServeError::DeadlineExceeded { .. } => Status::DeadlineExceeded,
            ServeError::WorkerPanicked => Status::WorkerPanicked,
            ServeError::ShutDown => Status::ShutDown,
        }
    }
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Status::Ok => "ok",
            Status::QueueFull => "queue-full",
            Status::DeadlineExceeded => "deadline-exceeded",
            Status::WorkerPanicked => "worker-panicked",
            Status::ShutDown => "shutting-down",
            Status::UnknownModel => "unknown-model",
            Status::AdmissionRejected => "admission-rejected",
            Status::Malformed => "malformed",
        };
        f.write_str(s)
    }
}

/// A client request: `rows` feature vectors for one model, flattened
/// row-major into `data` (`data.len() == rows * features`).
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Registry name of the target model.
    pub model: String,
    /// Per-row feature count.
    pub features: u32,
    /// Row-major feature data, `rows * features` values.
    pub data: Vec<f32>,
}

impl InferRequest {
    /// Number of rows carried by this request.
    pub fn rows(&self) -> usize {
        if self.features == 0 { 0 } else { self.data.len() / self.features as usize }
    }
}

/// One row's verdict inside a reply frame.
#[derive(Debug, Clone, PartialEq)]
pub struct RowReply {
    /// Row outcome; logits are empty unless `Ok`.
    pub status: Status,
    /// Argmax class (0 on error rows).
    pub class: u16,
    /// Backend version that served the row (0 on error rows).
    pub version: u64,
    /// Raw logits (empty on error rows).
    pub logits: Vec<f32>,
}

impl RowReply {
    /// A shed/error row carrying only its status.
    pub fn error(status: Status) -> RowReply {
        RowReply { status, class: 0, version: 0, logits: Vec::new() }
    }
}

/// A reply frame: per-row verdicts, in request row order.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReply {
    /// One entry per request row, in order.
    pub rows: Vec<RowReply>,
}

/// A frame-level typed error: the whole request frame was refused.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReply {
    /// Why the frame was refused.
    pub status: Status,
    /// Human-readable detail.
    pub message: String,
}

/// Any decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server inference request.
    Request(InferRequest),
    /// Server → client per-row verdicts.
    Reply(InferReply),
    /// Server → client frame-level typed error.
    Error(ErrorReply),
}

/// Protocol decode failure. `Truncated` only occurs when decoding a
/// supposedly complete payload (the deframer never hands out partial
/// frames), so it always means a corrupt length prefix or body.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// First four payload bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version byte other than [`VERSION`].
    UnsupportedVersion(u8),
    /// Unknown frame kind byte.
    UnknownKind(u8),
    /// Payload ended before the structure it declared.
    Truncated {
        /// Bytes the structure needed.
        need: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// Frame or field exceeded a protocol limit.
    Oversized {
        /// What was oversized.
        what: &'static str,
        /// Declared size.
        len: usize,
        /// Protocol cap.
        cap: usize,
    },
    /// Structurally invalid field contents.
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::UnsupportedVersion(v) => {
                write!(f, "unsupported protocol version {v} (speak v{VERSION})")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k:#04x}"),
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Oversized { what, len, cap } => {
                write!(f, "oversized {what}: {len} > cap {cap}")
            }
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

// ---- encoding -------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn begin_payload(out: &mut Vec<u8>, kind: u8) -> usize {
    let at = out.len();
    put_u32(out, 0); // length prefix, patched by finish_payload
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind);
    at
}

fn finish_payload(out: &mut Vec<u8>, at: usize) {
    let len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&len.to_le_bytes());
}

/// Append `frame` to `out` as a complete length-prefixed wire frame.
pub fn encode_frame(frame: &Frame, out: &mut Vec<u8>) {
    match frame {
        Frame::Request(req) => {
            let at = begin_payload(out, KIND_REQUEST);
            put_u16(out, req.model.len() as u16);
            out.extend_from_slice(req.model.as_bytes());
            put_u16(out, req.rows() as u16);
            put_u32(out, req.features);
            for v in &req.data {
                put_u32(out, v.to_bits());
            }
            finish_payload(out, at);
        }
        Frame::Reply(rep) => {
            let at = begin_payload(out, KIND_REPLY);
            put_u16(out, rep.rows.len() as u16);
            for row in &rep.rows {
                out.push(row.status as u8);
                put_u16(out, row.class);
                put_u64(out, row.version);
                put_u16(out, row.logits.len() as u16);
                for v in &row.logits {
                    put_u32(out, v.to_bits());
                }
            }
            finish_payload(out, at);
        }
        Frame::Error(err) => {
            let at = begin_payload(out, KIND_ERROR);
            out.push(err.status as u8);
            let msg = err.message.as_bytes();
            let take = msg.len().min(u16::MAX as usize);
            put_u16(out, take as u16);
            out.extend_from_slice(&msg[..take]);
            finish_payload(out, at);
        }
    }
}

// ---- decoding -------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let b = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in b.chunks_exact(4) {
            out.push(f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        }
        Ok(out)
    }
}

/// Decode one complete frame payload (everything after the length
/// prefix). Enforces magic, version, kind and all protocol limits.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let magic = c.take(4)?;
    if magic != MAGIC {
        return Err(WireError::BadMagic([magic[0], magic[1], magic[2], magic[3]]));
    }
    let version = c.u8()?;
    if version != VERSION {
        return Err(WireError::UnsupportedVersion(version));
    }
    match c.u8()? {
        KIND_REQUEST => {
            let model_len = c.u16()? as usize;
            if model_len > MAX_MODEL_NAME {
                return Err(WireError::Oversized {
                    what: "model name",
                    len: model_len,
                    cap: MAX_MODEL_NAME,
                });
            }
            let model = std::str::from_utf8(c.take(model_len)?)
                .map_err(|_| WireError::Malformed("model name is not utf-8".into()))?
                .to_string();
            let rows = c.u16()? as usize;
            if rows > MAX_ROWS_PER_FRAME {
                return Err(WireError::Oversized {
                    what: "row count",
                    len: rows,
                    cap: MAX_ROWS_PER_FRAME,
                });
            }
            if rows == 0 {
                return Err(WireError::Malformed("request carries zero rows".into()));
            }
            let features = c.u32()?;
            if features as usize > MAX_FEATURES {
                return Err(WireError::Oversized {
                    what: "feature count",
                    len: features as usize,
                    cap: MAX_FEATURES,
                });
            }
            if features == 0 {
                return Err(WireError::Malformed("request declares zero features".into()));
            }
            let data = c.f32s(rows * features as usize)?;
            expect_end(&c)?;
            Ok(Frame::Request(InferRequest { model, features, data }))
        }
        KIND_REPLY => {
            let rows = c.u16()? as usize;
            if rows > MAX_ROWS_PER_FRAME {
                return Err(WireError::Oversized {
                    what: "row count",
                    len: rows,
                    cap: MAX_ROWS_PER_FRAME,
                });
            }
            let mut out = Vec::with_capacity(rows);
            for _ in 0..rows {
                let status = decode_status(c.u8()?)?;
                let class = c.u16()?;
                let version = c.u64()?;
                let nlogits = c.u16()? as usize;
                let logits = c.f32s(nlogits)?;
                out.push(RowReply { status, class, version, logits });
            }
            expect_end(&c)?;
            Ok(Frame::Reply(InferReply { rows: out }))
        }
        KIND_ERROR => {
            let status = decode_status(c.u8()?)?;
            let msg_len = c.u16()? as usize;
            let message = String::from_utf8_lossy(c.take(msg_len)?).into_owned();
            expect_end(&c)?;
            Ok(Frame::Error(ErrorReply { status, message }))
        }
        k => Err(WireError::UnknownKind(k)),
    }
}

fn decode_status(v: u8) -> Result<Status, WireError> {
    Status::from_u8(v).ok_or_else(|| WireError::Malformed(format!("unknown status byte {v}")))
}

fn expect_end(c: &Cursor<'_>) -> Result<(), WireError> {
    if c.pos != c.buf.len() {
        return Err(WireError::Malformed(format!(
            "{} trailing bytes after frame body",
            c.buf.len() - c.pos
        )));
    }
    Ok(())
}

// ---- incremental deframing ------------------------------------------------

/// Incremental deframer over a byte stream: feed arbitrary chunks with
/// [`Deframer::extend`], pull complete payloads with
/// [`Deframer::next_payload`]. An oversized length prefix is reported
/// before any payload allocation.
#[derive(Debug)]
pub struct Deframer {
    buf: Vec<u8>,
    max_frame: usize,
}

impl Default for Deframer {
    fn default() -> Self {
        Deframer::new(MAX_FRAME_BYTES)
    }
}

impl Deframer {
    /// A deframer enforcing `max_frame` as the payload-size cap.
    pub fn new(max_frame: usize) -> Deframer {
        Deframer { buf: Vec::new(), max_frame }
    }

    /// Feed raw bytes read off the stream.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (incomplete frame tail).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Pop the next complete payload, if one is buffered. `Ok(None)`
    /// means "need more bytes"; `Err` means the stream is poisoned and
    /// the connection must be failed closed.
    pub fn next_payload(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
        if len > self.max_frame {
            return Err(WireError::Oversized {
                what: "frame payload",
                len,
                cap: self.max_frame,
            });
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let payload = self.buf[4..4 + len].to_vec();
        self.buf.drain(..4 + len);
        Ok(Some(payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut wire = Vec::new();
        encode_frame(frame, &mut wire);
        let mut d = Deframer::default();
        d.extend(&wire);
        let payload = d.next_payload().expect("clean stream").expect("complete frame");
        assert_eq!(d.buffered(), 0, "no leftover bytes after one frame");
        decode_payload(&payload).expect("decodes")
    }

    fn arb_request(rng: &mut Rng) -> Frame {
        let rows = 1 + rng.below(5);
        let features = 1 + rng.below(16) as u32;
        let name_len = 1 + rng.below(12);
        let model: String =
            (0..name_len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
        let data: Vec<f32> =
            (0..rows * features as usize).map(|_| rng.f32() * 4.0 - 2.0).collect();
        Frame::Request(InferRequest { model, features, data })
    }

    fn arb_reply(rng: &mut Rng) -> Frame {
        let rows = (0..rng.below(6))
            .map(|_| {
                let status = Status::from_u8(rng.below(8) as u8).unwrap();
                if status == Status::Ok {
                    let n = rng.below(12);
                    RowReply {
                        status,
                        class: rng.below(1000) as u16,
                        version: rng.next_u64() % 1_000_000,
                        logits: (0..n).map(|_| rng.f32() * 10.0 - 5.0).collect(),
                    }
                } else {
                    RowReply::error(status)
                }
            })
            .collect();
        Frame::Reply(InferReply { rows })
    }

    #[test]
    fn request_roundtrip_property() {
        let mut rng = Rng::new(0x1a51);
        for case in 0..300 {
            let frame = arb_request(&mut rng);
            assert_eq!(roundtrip(&frame), frame, "case {case}");
        }
    }

    #[test]
    fn reply_and_error_roundtrip_property() {
        let mut rng = Rng::new(0x2b52);
        for case in 0..300 {
            let frame = arb_reply(&mut rng);
            assert_eq!(roundtrip(&frame), frame, "case {case}");
            let err = Frame::Error(ErrorReply {
                status: Status::from_u8(1 + rng.below(7) as u8).unwrap(),
                message: format!("case {case} detail"),
            });
            assert_eq!(roundtrip(&err), err);
        }
    }

    #[test]
    fn deframer_handles_byte_at_a_time_delivery() {
        let frame = Frame::Request(InferRequest {
            model: "m".into(),
            features: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        encode_frame(&frame, &mut wire);
        let mut d = Deframer::default();
        let mut seen = 0;
        for b in &wire {
            d.extend(std::slice::from_ref(b));
            while let Some(p) = d.next_payload().unwrap() {
                assert_eq!(decode_payload(&p).unwrap(), frame);
                seen += 1;
            }
        }
        assert_eq!(seen, 2);
    }

    #[test]
    fn bad_magic_version_and_kind_rejected() {
        let frame = Frame::Request(InferRequest {
            model: "m".into(),
            features: 1,
            data: vec![0.5],
        });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        let payload = wire[4..].to_vec();

        let mut bad = payload.clone();
        bad[0] = b'X';
        assert!(matches!(decode_payload(&bad), Err(WireError::BadMagic(_))));

        let mut bad = payload.clone();
        bad[4] = 9;
        assert!(matches!(decode_payload(&bad), Err(WireError::UnsupportedVersion(9))));

        let mut bad = payload.clone();
        bad[5] = 0x7f;
        assert!(matches!(decode_payload(&bad), Err(WireError::UnknownKind(0x7f))));
    }

    #[test]
    fn truncated_and_trailing_bytes_rejected() {
        let frame = Frame::Request(InferRequest {
            model: "digits".into(),
            features: 4,
            data: vec![0.0; 8],
        });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        let payload = &wire[4..];
        for cut in 6..payload.len() {
            let got = decode_payload(&payload[..cut]);
            assert!(got.is_err(), "truncation at {cut} must not decode");
        }
        let mut padded = payload.to_vec();
        padded.push(0);
        assert!(matches!(decode_payload(&padded), Err(WireError::Malformed(_))));
    }

    #[test]
    fn oversized_length_prefix_rejected_without_buffering() {
        let mut d = Deframer::default();
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        d.extend(&huge);
        assert!(matches!(d.next_payload(), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn limit_violations_rejected() {
        // row count over cap
        let mut payload = Vec::new();
        payload.extend_from_slice(&MAGIC);
        payload.push(VERSION);
        payload.push(KIND_REQUEST);
        payload.extend_from_slice(&1u16.to_le_bytes());
        payload.push(b'm');
        payload.extend_from_slice(&(MAX_ROWS_PER_FRAME as u16 + 1).to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes());
        assert!(matches!(decode_payload(&payload), Err(WireError::Oversized { .. })));

        // zero rows is structurally meaningless
        let req = InferRequest { model: "m".into(), features: 3, data: Vec::new() };
        let mut wire = Vec::new();
        encode_frame(&Frame::Request(req), &mut wire);
        assert!(matches!(decode_payload(&wire[4..]), Err(WireError::Malformed(_))));
    }

    #[test]
    fn status_wire_codes_are_stable() {
        for v in 0..8u8 {
            assert_eq!(Status::from_u8(v).unwrap() as u8, v);
        }
        assert!(Status::from_u8(8).is_none());
        assert!(Status::QueueFull.is_queue_full_class());
        assert!(Status::AdmissionRejected.is_queue_full_class());
        assert!(!Status::DeadlineExceeded.is_queue_full_class());
        assert_eq!(Status::from_serve_error(&ServeError::QueueFull), Status::QueueFull);
        assert_eq!(
            Status::from_serve_error(&ServeError::DeadlineExceeded { waited_us: 5 }),
            Status::DeadlineExceeded
        );
        assert_eq!(
            Status::from_serve_error(&ServeError::WorkerPanicked),
            Status::WorkerPanicked
        );
        assert_eq!(Status::from_serve_error(&ServeError::ShutDown), Status::ShutDown);
    }
}
