//! Length-prefixed binary wire protocol for the network serving tier.
//!
//! Every frame on the wire is a `u32` little-endian length prefix (the
//! payload size in bytes, excluding the prefix itself) followed by the
//! payload. A payload starts with a fixed header — the 4-byte magic
//! `b"LTN1"`, a `u8` protocol version (currently [`VERSION`]) and a
//! `u8` frame kind — and continues with the kind-specific body:
//!
//! ```text
//! frame    := len:u32le payload[len]
//! payload  := magic[4]="LTN1" version:u8 kind:u8 body
//! request  := key:u64le model_len:u16le model[model_len] rows:u16le
//!             features:u32le data: rows*features * f32le   (kind 0x01)
//!             (v1 layout: identical but WITHOUT the leading key field)
//! reply    := key:u64le rows:u16le row*rows                (kind 0x02)
//!             (v1 layout: identical but WITHOUT the leading key field)
//! row      := status:u8 class:u16le version:u64le nlogits:u16le
//!             logits: nlogits * f32le          (nlogits = 0 on error rows)
//! error    := status:u8 msg_len:u16le msg[msg_len]         (kind 0x03)
//! hello    := client_id:u64le token_len:u16le token[token_len]
//!                                                     (kind 0x04, v2+)
//! goaway   := grace_ms:u32le reason_len:u16le reason[reason_len]
//!                                                     (kind 0x05, v2+)
//! ```
//!
//! Versioning rules: a magic mismatch or a version outside
//! `1..=`[`VERSION`] is a protocol error — the server answers with a
//! typed [`Status::Malformed`] error frame and closes the connection
//! (fails closed). Unknown frame kinds and any limit violation
//! ([`MAX_FRAME_BYTES`], [`MAX_ROWS_PER_FRAME`], [`MAX_MODEL_NAME`],
//! [`MAX_FEATURES`], [`MAX_TOKEN_LEN`]) are treated the same way.
//! Within a version, additions must be purely appended frame kinds;
//! anything that changes the layout of an existing kind bumps the
//! version byte and the decoder keeps accepting every older layout
//! (v2 decodes v1 frames; v1 request/reply bodies simply carry an
//! implicit idempotency key of 0). [`Hello`]/[`GoAway`] exist only
//! from v2 on — a v1 payload with those kinds is an unknown kind.
//!
//! Error frames carry failures that void a whole request frame (unknown
//! model, admission rejection, auth/rate-limit refusals, malformed
//! input, shutdown); per-row pipeline verdicts (queue-full, deadline,
//! panic) ride inside a normal reply frame as per-row status bytes, so
//! one frame can mix served and shed rows.
//!
//! **Idempotency keys.** A v2 client stamps every request with a
//! `(client_id, key)` pair (`client_id` from its [`Hello`], `key` from
//! the request) and the server echoes `key` in the reply. A reply lost
//! to a dropped connection can therefore be re-requested under the same
//! key after reconnecting: the server answers duplicates from a bounded
//! replay cache instead of re-submitting rows to the pipeline, so a
//! retried frame is acknowledged exactly once end to end. Key 0 means
//! "unkeyed" and is never cached.

use crate::coordinator::ServeError;

/// Frame magic: the first four payload bytes of every frame.
pub const MAGIC: [u8; 4] = *b"LTN1";
/// Current protocol version (the fifth payload byte). v2 added
/// [`Hello`]/[`GoAway`] frames and the request/reply idempotency key;
/// v1 payloads still decode (see the module docs).
pub const VERSION: u8 = 2;
/// Oldest protocol version the decoder accepts.
pub const MIN_VERSION: u8 = 1;

/// Hard cap on a single frame payload (16 MiB). A length prefix above
/// this is rejected before any allocation happens.
pub const MAX_FRAME_BYTES: usize = 1 << 24;
/// Hard cap on rows per request frame.
pub const MAX_ROWS_PER_FRAME: usize = 4096;
/// Hard cap on the model-name field.
pub const MAX_MODEL_NAME: usize = 256;
/// Hard cap on the per-row feature count.
pub const MAX_FEATURES: usize = 1 << 20;
/// Hard cap on the [`Hello`] auth token and [`GoAway`] reason fields.
pub const MAX_TOKEN_LEN: usize = 256;

const KIND_REQUEST: u8 = 0x01;
const KIND_REPLY: u8 = 0x02;
const KIND_ERROR: u8 = 0x03;
const KIND_HELLO: u8 = 0x04;
const KIND_GOAWAY: u8 = 0x05;

/// Wire status codes: `0` is success, everything else is a typed
/// failure mapping [`ServeError`] (and the net tier's own rejection
/// modes) onto one byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    /// Row served; logits follow.
    Ok = 0,
    /// Pipeline ingress queue full (per-model backpressure).
    QueueFull = 1,
    /// Deadline exceeded before or during batching.
    DeadlineExceeded = 2,
    /// The worker executing the batch panicked; row shed, not lost.
    WorkerPanicked = 3,
    /// Pipeline (or the whole server) is draining.
    ShutDown = 4,
    /// No model under the requested name.
    UnknownModel = 5,
    /// The shared cross-model admission budget rejected the frame.
    AdmissionRejected = 6,
    /// The frame violated the protocol; the connection is closed.
    Malformed = 7,
    /// Missing or wrong auth token; the connection is closed.
    AuthFailed = 8,
    /// The per-connection frame/row rate limit refused the frame.
    RateLimited = 9,
    /// The server's connection cap refused this connection.
    TooManyConnections = 10,
}

impl Status {
    /// Number of distinct wire status codes (codes are `0..COUNT`).
    pub const COUNT: usize = 11;

    /// Decode a wire status byte.
    pub fn from_u8(v: u8) -> Option<Status> {
        Some(match v {
            0 => Status::Ok,
            1 => Status::QueueFull,
            2 => Status::DeadlineExceeded,
            3 => Status::WorkerPanicked,
            4 => Status::ShutDown,
            5 => Status::UnknownModel,
            6 => Status::AdmissionRejected,
            7 => Status::Malformed,
            8 => Status::AuthFailed,
            9 => Status::RateLimited,
            10 => Status::TooManyConnections,
            _ => return None,
        })
    }

    /// True for the backpressure family: the request was refused to
    /// protect capacity (retry later), as opposed to being wrong.
    /// Covers per-model queue rejection, the shared admission budget
    /// and per-connection rate limits.
    pub fn is_queue_full_class(self) -> bool {
        matches!(self, Status::QueueFull | Status::AdmissionRejected | Status::RateLimited)
    }

    /// True when a frame-level refusal with this status is worth
    /// retrying (possibly after a reconnect): the server was
    /// protecting capacity or going away, not telling the client it
    /// is wrong. Terminal statuses ([`Status::Malformed`],
    /// [`Status::UnknownModel`], [`Status::AuthFailed`]) mean a retry
    /// of the same bytes can never succeed.
    pub fn is_retryable(self) -> bool {
        self.is_queue_full_class()
            || matches!(self, Status::ShutDown | Status::TooManyConnections)
    }

    /// Map a pipeline [`ServeError`] onto its wire status.
    pub fn from_serve_error(e: &ServeError) -> Status {
        match e {
            ServeError::QueueFull => Status::QueueFull,
            ServeError::DeadlineExceeded { .. } => Status::DeadlineExceeded,
            ServeError::WorkerPanicked => Status::WorkerPanicked,
            ServeError::ShutDown => Status::ShutDown,
        }
    }
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Status::Ok => "ok",
            Status::QueueFull => "queue-full",
            Status::DeadlineExceeded => "deadline-exceeded",
            Status::WorkerPanicked => "worker-panicked",
            Status::ShutDown => "shutting-down",
            Status::UnknownModel => "unknown-model",
            Status::AdmissionRejected => "admission-rejected",
            Status::Malformed => "malformed",
            Status::AuthFailed => "auth-failed",
            Status::RateLimited => "rate-limited",
            Status::TooManyConnections => "too-many-connections",
        };
        f.write_str(s)
    }
}

/// A client request: `rows` feature vectors for one model, flattened
/// row-major into `data` (`data.len() == rows * features`).
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Idempotency key, echoed verbatim in the reply (0 = unkeyed;
    /// decoding a v1 payload always yields 0).
    pub key: u64,
    /// Registry name of the target model.
    pub model: String,
    /// Per-row feature count.
    pub features: u32,
    /// Row-major feature data, `rows * features` values.
    pub data: Vec<f32>,
}

impl InferRequest {
    /// Number of rows carried by this request.
    pub fn rows(&self) -> usize {
        if self.features == 0 { 0 } else { self.data.len() / self.features as usize }
    }
}

/// One row's verdict inside a reply frame.
#[derive(Debug, Clone, PartialEq)]
pub struct RowReply {
    /// Row outcome; logits are empty unless `Ok`.
    pub status: Status,
    /// Argmax class (0 on error rows).
    pub class: u16,
    /// Backend version that served the row (0 on error rows).
    pub version: u64,
    /// Raw logits (empty on error rows).
    pub logits: Vec<f32>,
}

impl RowReply {
    /// A shed/error row carrying only its status.
    pub fn error(status: Status) -> RowReply {
        RowReply { status, class: 0, version: 0, logits: Vec::new() }
    }
}

/// A reply frame: per-row verdicts, in request row order.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReply {
    /// The request's idempotency key, echoed (0 = unkeyed / v1 peer).
    pub key: u64,
    /// One entry per request row, in order.
    pub rows: Vec<RowReply>,
}

/// A frame-level typed error: the whole request frame was refused.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReply {
    /// Why the frame was refused.
    pub status: Status,
    /// Human-readable detail.
    pub message: String,
}

/// Connection preamble (client → server, v2+): carries the shared
/// auth token (empty = none) and the client's session id used to
/// namespace idempotency keys across connections. Must be the first
/// frame on a connection when the server requires auth.
#[derive(Debug, Clone, PartialEq)]
pub struct Hello {
    /// Client session id namespacing this connection's idempotency
    /// keys (0 = anonymous, disables reply replay).
    pub client_id: u64,
    /// Shared secret (empty when the server runs without auth).
    pub token: String,
}

/// Drain notice (server → client, v2+): the server stops accepting
/// new requests, will answer everything already in flight within
/// `grace_ms`, and then close. Clients should reconnect elsewhere (or
/// later) instead of treating the close as a failure.
#[derive(Debug, Clone, PartialEq)]
pub struct GoAway {
    /// How long the server will keep flushing in-flight replies.
    pub grace_ms: u32,
    /// Human-readable drain reason.
    pub reason: String,
}

/// Any decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server inference request.
    Request(InferRequest),
    /// Server → client per-row verdicts.
    Reply(InferReply),
    /// Server → client frame-level typed error.
    Error(ErrorReply),
    /// Client → server connection preamble (auth + session id).
    Hello(Hello),
    /// Server → client graceful-drain notice.
    GoAway(GoAway),
}

/// Protocol decode failure. `Truncated` only occurs when decoding a
/// supposedly complete payload (the deframer never hands out partial
/// frames), so it always means a corrupt length prefix or body.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// First four payload bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version byte outside `MIN_VERSION..=VERSION`.
    UnsupportedVersion(u8),
    /// Unknown frame kind byte (for the payload's version).
    UnknownKind(u8),
    /// Payload ended before the structure it declared.
    Truncated {
        /// Bytes the structure needed.
        need: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// Frame or field exceeded a protocol limit.
    Oversized {
        /// What was oversized.
        what: &'static str,
        /// Declared size.
        len: usize,
        /// Protocol cap.
        cap: usize,
    },
    /// Structurally invalid field contents.
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::UnsupportedVersion(v) => {
                write!(f, "unsupported protocol version {v} (speak v{MIN_VERSION}..v{VERSION})")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k:#04x}"),
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Oversized { what, len, cap } => {
                write!(f, "oversized {what}: {len} > cap {cap}")
            }
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

// ---- encoding -------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn begin_payload(out: &mut Vec<u8>, version: u8, kind: u8) -> usize {
    let at = out.len();
    put_u32(out, 0); // length prefix, patched by finish_payload
    out.extend_from_slice(&MAGIC);
    out.push(version);
    out.push(kind);
    at
}

fn finish_payload(out: &mut Vec<u8>, at: usize) {
    let len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&len.to_le_bytes());
}

/// Append `frame` to `out` as a complete length-prefixed wire frame at
/// the current protocol version ([`VERSION`]).
pub fn encode_frame(frame: &Frame, out: &mut Vec<u8>) {
    encode_frame_at(frame, VERSION, out);
}

/// Append `frame` to `out` encoded at a specific protocol `version`
/// (used by the server to answer a v1 peer in the layout it speaks).
/// v1 request/reply layouts drop the idempotency key. [`Frame::Hello`]
/// and [`Frame::GoAway`] only exist from v2 on; callers must not send
/// them to v1 peers (debug-asserted; release builds encode them at v2).
pub fn encode_frame_at(frame: &Frame, version: u8, out: &mut Vec<u8>) {
    debug_assert!((MIN_VERSION..=VERSION).contains(&version));
    let keyed = version >= 2;
    match frame {
        Frame::Request(req) => {
            let at = begin_payload(out, version, KIND_REQUEST);
            if keyed {
                put_u64(out, req.key);
            }
            put_u16(out, req.model.len() as u16);
            out.extend_from_slice(req.model.as_bytes());
            put_u16(out, req.rows() as u16);
            put_u32(out, req.features);
            for v in &req.data {
                put_u32(out, v.to_bits());
            }
            finish_payload(out, at);
        }
        Frame::Reply(rep) => {
            let at = begin_payload(out, version, KIND_REPLY);
            if keyed {
                put_u64(out, rep.key);
            }
            put_u16(out, rep.rows.len() as u16);
            for row in &rep.rows {
                out.push(row.status as u8);
                put_u16(out, row.class);
                put_u64(out, row.version);
                put_u16(out, row.logits.len() as u16);
                for v in &row.logits {
                    put_u32(out, v.to_bits());
                }
            }
            finish_payload(out, at);
        }
        Frame::Error(err) => {
            let at = begin_payload(out, version, KIND_ERROR);
            out.push(err.status as u8);
            let msg = err.message.as_bytes();
            let take = msg.len().min(u16::MAX as usize);
            put_u16(out, take as u16);
            out.extend_from_slice(&msg[..take]);
            finish_payload(out, at);
        }
        Frame::Hello(h) => {
            debug_assert!(keyed, "Hello frames require protocol v2+");
            let at = begin_payload(out, version.max(2), KIND_HELLO);
            put_u64(out, h.client_id);
            put_u16(out, h.token.len() as u16);
            out.extend_from_slice(h.token.as_bytes());
            finish_payload(out, at);
        }
        Frame::GoAway(g) => {
            debug_assert!(keyed, "GoAway frames require protocol v2+");
            let at = begin_payload(out, version.max(2), KIND_GOAWAY);
            put_u32(out, g.grace_ms);
            let reason = g.reason.as_bytes();
            let take = reason.len().min(MAX_TOKEN_LEN);
            put_u16(out, take as u16);
            out.extend_from_slice(&reason[..take]);
            finish_payload(out, at);
        }
    }
}

// ---- decoding -------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let b = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in b.chunks_exact(4) {
            out.push(f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        }
        Ok(out)
    }

    fn short_str(&mut self, what: &'static str, cap: usize) -> Result<String, WireError> {
        let len = self.u16()? as usize;
        if len > cap {
            return Err(WireError::Oversized { what, len, cap });
        }
        std::str::from_utf8(self.take(len)?)
            .map_err(|_| WireError::Malformed(format!("{what} is not utf-8")))
            .map(str::to_string)
    }
}

/// Decode one complete frame payload (everything after the length
/// prefix). Enforces magic, version, kind and all protocol limits.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    decode_payload_versioned(payload).map(|(_, f)| f)
}

/// Like [`decode_payload`], but also returns the payload's protocol
/// version byte so a server can mirror the peer's version when
/// replying (a v1 client must receive v1 replies).
pub fn decode_payload_versioned(payload: &[u8]) -> Result<(u8, Frame), WireError> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let magic = c.take(4)?;
    if magic != MAGIC {
        return Err(WireError::BadMagic([magic[0], magic[1], magic[2], magic[3]]));
    }
    let version = c.u8()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(WireError::UnsupportedVersion(version));
    }
    let keyed = version >= 2;
    let frame = match c.u8()? {
        KIND_REQUEST => {
            let key = if keyed { c.u64()? } else { 0 };
            let model = c.short_str("model name", MAX_MODEL_NAME)?;
            let rows = c.u16()? as usize;
            if rows > MAX_ROWS_PER_FRAME {
                return Err(WireError::Oversized {
                    what: "row count",
                    len: rows,
                    cap: MAX_ROWS_PER_FRAME,
                });
            }
            if rows == 0 {
                return Err(WireError::Malformed("request carries zero rows".into()));
            }
            let features = c.u32()?;
            if features as usize > MAX_FEATURES {
                return Err(WireError::Oversized {
                    what: "feature count",
                    len: features as usize,
                    cap: MAX_FEATURES,
                });
            }
            if features == 0 {
                return Err(WireError::Malformed("request declares zero features".into()));
            }
            let data = c.f32s(rows * features as usize)?;
            expect_end(&c)?;
            Frame::Request(InferRequest { key, model, features, data })
        }
        KIND_REPLY => {
            let key = if keyed { c.u64()? } else { 0 };
            let rows = c.u16()? as usize;
            if rows > MAX_ROWS_PER_FRAME {
                return Err(WireError::Oversized {
                    what: "row count",
                    len: rows,
                    cap: MAX_ROWS_PER_FRAME,
                });
            }
            let mut out = Vec::with_capacity(rows);
            for _ in 0..rows {
                let status = decode_status(c.u8()?)?;
                let class = c.u16()?;
                let version = c.u64()?;
                let nlogits = c.u16()? as usize;
                let logits = c.f32s(nlogits)?;
                out.push(RowReply { status, class, version, logits });
            }
            expect_end(&c)?;
            Frame::Reply(InferReply { key, rows: out })
        }
        KIND_ERROR => {
            let status = decode_status(c.u8()?)?;
            let msg_len = c.u16()? as usize;
            let message = String::from_utf8_lossy(c.take(msg_len)?).into_owned();
            expect_end(&c)?;
            Frame::Error(ErrorReply { status, message })
        }
        KIND_HELLO if keyed => {
            let client_id = c.u64()?;
            let token = c.short_str("auth token", MAX_TOKEN_LEN)?;
            expect_end(&c)?;
            Frame::Hello(Hello { client_id, token })
        }
        KIND_GOAWAY if keyed => {
            let grace_ms = c.u32()?;
            let reason = c.short_str("goaway reason", MAX_TOKEN_LEN)?;
            expect_end(&c)?;
            Frame::GoAway(GoAway { grace_ms, reason })
        }
        k => return Err(WireError::UnknownKind(k)),
    };
    Ok((version, frame))
}

fn decode_status(v: u8) -> Result<Status, WireError> {
    Status::from_u8(v).ok_or_else(|| WireError::Malformed(format!("unknown status byte {v}")))
}

fn expect_end(c: &Cursor<'_>) -> Result<(), WireError> {
    if c.pos != c.buf.len() {
        return Err(WireError::Malformed(format!(
            "{} trailing bytes after frame body",
            c.buf.len() - c.pos
        )));
    }
    Ok(())
}

// ---- incremental deframing ------------------------------------------------

/// Incremental deframer over a byte stream: feed arbitrary chunks with
/// [`Deframer::extend`], pull complete payloads with
/// [`Deframer::next_payload`]. An oversized length prefix is reported
/// before any payload allocation. Consumed bytes are tracked with a
/// read offset and reclaimed in bulk, so a burst of `n` buffered
/// frames costs O(bytes) total instead of the O(n·bytes) a
/// drain-per-frame scheme pays, and a length prefix split across
/// arbitrarily small reads (down to 1 byte) never sheds or duplicates
/// a boundary byte.
#[derive(Debug)]
pub struct Deframer {
    buf: Vec<u8>,
    /// Start of the unconsumed region in `buf`.
    pos: usize,
    max_frame: usize,
}

impl Default for Deframer {
    fn default() -> Self {
        Deframer::new(MAX_FRAME_BYTES)
    }
}

impl Deframer {
    /// A deframer enforcing `max_frame` as the payload-size cap.
    pub fn new(max_frame: usize) -> Deframer {
        Deframer { buf: Vec::new(), pos: 0, max_frame }
    }

    /// Feed raw bytes read off the stream.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.compact();
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (incomplete frame tail).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Reclaim consumed prefix space when it dominates the buffer, so
    /// the buffer never grows without bound across frames while each
    /// individual frame is still copied out at most once.
    fn compact(&mut self) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > 4096 && self.pos * 2 >= self.buf.len() {
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(self.buf.len() - self.pos);
            self.pos = 0;
        }
    }

    /// Pop the next complete payload, if one is buffered. `Ok(None)`
    /// means "need more bytes"; `Err` means the stream is poisoned and
    /// the connection must be failed closed.
    pub fn next_payload(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        if self.buffered() < 4 {
            return Ok(None);
        }
        let p = self.pos;
        let len = u32::from_le_bytes([
            self.buf[p],
            self.buf[p + 1],
            self.buf[p + 2],
            self.buf[p + 3],
        ]) as usize;
        if len > self.max_frame {
            return Err(WireError::Oversized {
                what: "frame payload",
                len,
                cap: self.max_frame,
            });
        }
        if self.buffered() < 4 + len {
            return Ok(None);
        }
        let payload = self.buf[p + 4..p + 4 + len].to_vec();
        self.pos += 4 + len;
        self.compact();
        Ok(Some(payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut wire = Vec::new();
        encode_frame(frame, &mut wire);
        let mut d = Deframer::default();
        d.extend(&wire);
        let payload = d.next_payload().expect("clean stream").expect("complete frame");
        assert_eq!(d.buffered(), 0, "no leftover bytes after one frame");
        decode_payload(&payload).expect("decodes")
    }

    fn arb_request(rng: &mut Rng) -> Frame {
        let rows = 1 + rng.below(5);
        let features = 1 + rng.below(16) as u32;
        let name_len = 1 + rng.below(12);
        let model: String =
            (0..name_len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
        let data: Vec<f32> =
            (0..rows * features as usize).map(|_| rng.f32() * 4.0 - 2.0).collect();
        Frame::Request(InferRequest { key: rng.next_u64(), model, features, data })
    }

    fn arb_reply(rng: &mut Rng) -> Frame {
        let rows = (0..rng.below(6))
            .map(|_| {
                let status = Status::from_u8(rng.below(Status::COUNT) as u8).unwrap();
                if status == Status::Ok {
                    let n = rng.below(12);
                    RowReply {
                        status,
                        class: rng.below(1000) as u16,
                        version: rng.next_u64() % 1_000_000,
                        logits: (0..n).map(|_| rng.f32() * 10.0 - 5.0).collect(),
                    }
                } else {
                    RowReply::error(status)
                }
            })
            .collect();
        Frame::Reply(InferReply { key: rng.next_u64(), rows })
    }

    #[test]
    fn request_roundtrip_property() {
        let mut rng = Rng::new(0x1a51);
        for case in 0..300 {
            let frame = arb_request(&mut rng);
            assert_eq!(roundtrip(&frame), frame, "case {case}");
        }
    }

    #[test]
    fn reply_and_error_roundtrip_property() {
        let mut rng = Rng::new(0x2b52);
        for case in 0..300 {
            let frame = arb_reply(&mut rng);
            assert_eq!(roundtrip(&frame), frame, "case {case}");
            let err = Frame::Error(ErrorReply {
                status: Status::from_u8(1 + rng.below(Status::COUNT - 1) as u8).unwrap(),
                message: format!("case {case} detail"),
            });
            assert_eq!(roundtrip(&err), err);
        }
    }

    #[test]
    fn hello_and_goaway_roundtrip_property() {
        let mut rng = Rng::new(0x3c53);
        for case in 0..300 {
            let token: String =
                (0..rng.below(40)).map(|_| (b'A' + rng.below(26) as u8) as char).collect();
            let hello = Frame::Hello(Hello { client_id: rng.next_u64(), token });
            assert_eq!(roundtrip(&hello), hello, "case {case}");
            let reason: String =
                (0..rng.below(40)).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            let goaway =
                Frame::GoAway(GoAway { grace_ms: rng.below(60_000) as u32, reason });
            assert_eq!(roundtrip(&goaway), goaway, "case {case}");
        }
    }

    #[test]
    fn hello_and_goaway_truncation_and_oversize_rejected() {
        let frame = Frame::Hello(Hello { client_id: 7, token: "secret".into() });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        let payload = &wire[4..];
        for cut in 6..payload.len() {
            assert!(decode_payload(&payload[..cut]).is_err(), "cut {cut} must not decode");
        }
        let mut padded = payload.to_vec();
        padded.push(0);
        assert!(matches!(decode_payload(&padded), Err(WireError::Malformed(_))));

        let frame = Frame::GoAway(GoAway { grace_ms: 250, reason: "restart".into() });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        let payload = &wire[4..];
        for cut in 6..payload.len() {
            assert!(decode_payload(&payload[..cut]).is_err(), "cut {cut} must not decode");
        }

        // token over MAX_TOKEN_LEN: hand-rolled, since encode caps it
        let mut payload = Vec::new();
        payload.extend_from_slice(&MAGIC);
        payload.push(VERSION);
        payload.push(KIND_HELLO);
        payload.extend_from_slice(&7u64.to_le_bytes());
        payload.extend_from_slice(&((MAX_TOKEN_LEN as u16) + 1).to_le_bytes());
        payload.extend_from_slice(&[b'x'; MAX_TOKEN_LEN + 1]);
        assert!(matches!(decode_payload(&payload), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn v1_payloads_still_decode_and_v1_replies_are_keyless() {
        // a v1 peer's request (no key field) decodes with key == 0
        let req = InferRequest {
            key: 0xdead_beef,
            model: "digits".into(),
            features: 2,
            data: vec![0.25, 0.5, 0.75, 1.0],
        };
        let mut wire = Vec::new();
        encode_frame_at(&Frame::Request(req.clone()), 1, &mut wire);
        let (version, frame) = decode_payload_versioned(&wire[4..]).unwrap();
        assert_eq!(version, 1);
        match frame {
            Frame::Request(got) => {
                assert_eq!(got.key, 0, "v1 layout has no key field");
                assert_eq!((got.model.as_str(), got.features), ("digits", 2));
                assert_eq!(got.data, req.data);
            }
            other => panic!("expected a request, got {other:?}"),
        }
        // a v1-encoded reply round-trips minus the key, and is smaller
        // than its v2 encoding by exactly the 8 key bytes
        let rep = InferReply {
            key: 42,
            rows: vec![RowReply {
                status: Status::Ok,
                class: 3,
                version: 9,
                logits: vec![1.5, -0.5],
            }],
        };
        let (mut v1, mut v2) = (Vec::new(), Vec::new());
        encode_frame_at(&Frame::Reply(rep.clone()), 1, &mut v1);
        encode_frame_at(&Frame::Reply(rep.clone()), 2, &mut v2);
        assert_eq!(v2.len(), v1.len() + 8);
        match decode_payload(&v1[4..]).unwrap() {
            Frame::Reply(got) => {
                assert_eq!(got.key, 0);
                assert_eq!(got.rows, rep.rows);
            }
            other => panic!("expected a reply, got {other:?}"),
        }
        // Hello/GoAway kinds do not exist in v1: a v1 payload carrying
        // the kind byte is an unknown kind, not a truncated v2 frame
        let mut payload = Vec::new();
        payload.extend_from_slice(&MAGIC);
        payload.push(1);
        payload.push(KIND_HELLO);
        assert!(matches!(decode_payload(&payload), Err(WireError::UnknownKind(k)) if k == KIND_HELLO));
    }

    #[test]
    fn deframer_handles_byte_at_a_time_delivery() {
        let frame = Frame::Request(InferRequest {
            key: 1,
            model: "m".into(),
            features: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        encode_frame(&frame, &mut wire);
        let mut d = Deframer::default();
        let mut seen = 0;
        for b in &wire {
            d.extend(std::slice::from_ref(b));
            while let Some(p) = d.next_payload().unwrap() {
                assert_eq!(decode_payload(&p).unwrap(), frame);
                seen += 1;
            }
        }
        assert_eq!(seen, 2);
    }

    #[test]
    fn deframer_incremental_feed_property() {
        // A long multi-frame stream delivered in adversarial chunk
        // sizes (biased toward 1–3 bytes, so length prefixes and frame
        // boundaries are split constantly) must yield exactly the
        // original frame sequence — nothing lost, duplicated or
        // reordered — regardless of how reads tear the stream.
        let mut rng = Rng::new(0x4d54);
        for case in 0..40 {
            let frames: Vec<Frame> = (0..1 + rng.below(12))
                .map(|i| match i % 3 {
                    0 => arb_request(&mut rng),
                    1 => arb_reply(&mut rng),
                    _ => Frame::GoAway(GoAway {
                        grace_ms: rng.below(10_000) as u32,
                        reason: "drain".into(),
                    }),
                })
                .collect();
            let mut wire = Vec::new();
            for f in &frames {
                encode_frame(f, &mut wire);
            }
            let mut d = Deframer::default();
            let mut got = Vec::new();
            let mut off = 0usize;
            while off < wire.len() {
                // mostly tiny reads, occasionally a big gulp
                let chunk = if rng.below(4) == 0 { 1 + rng.below(64) } else { 1 + rng.below(3) };
                let end = (off + chunk).min(wire.len());
                d.extend(&wire[off..end]);
                off = end;
                while let Some(p) = d.next_payload().unwrap() {
                    got.push(decode_payload(&p).unwrap());
                }
            }
            assert_eq!(d.buffered(), 0, "case {case}: trailing bytes left buffered");
            assert_eq!(got, frames, "case {case}");
        }
    }

    #[test]
    fn bad_magic_version_and_kind_rejected() {
        let frame = Frame::Request(InferRequest {
            key: 0,
            model: "m".into(),
            features: 1,
            data: vec![0.5],
        });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        let payload = wire[4..].to_vec();

        let mut bad = payload.clone();
        bad[0] = b'X';
        assert!(matches!(decode_payload(&bad), Err(WireError::BadMagic(_))));

        let mut bad = payload.clone();
        bad[4] = 9;
        assert!(matches!(decode_payload(&bad), Err(WireError::UnsupportedVersion(9))));
        bad[4] = 0;
        assert!(matches!(decode_payload(&bad), Err(WireError::UnsupportedVersion(0))));

        let mut bad = payload.clone();
        bad[5] = 0x7f;
        assert!(matches!(decode_payload(&bad), Err(WireError::UnknownKind(0x7f))));
    }

    #[test]
    fn truncated_and_trailing_bytes_rejected() {
        let frame = Frame::Request(InferRequest {
            key: 77,
            model: "digits".into(),
            features: 4,
            data: vec![0.0; 8],
        });
        let mut wire = Vec::new();
        encode_frame(&frame, &mut wire);
        let payload = &wire[4..];
        for cut in 6..payload.len() {
            let got = decode_payload(&payload[..cut]);
            assert!(got.is_err(), "truncation at {cut} must not decode");
        }
        let mut padded = payload.to_vec();
        padded.push(0);
        assert!(matches!(decode_payload(&padded), Err(WireError::Malformed(_))));
    }

    #[test]
    fn oversized_length_prefix_rejected_without_buffering() {
        let mut d = Deframer::default();
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        d.extend(&huge);
        assert!(matches!(d.next_payload(), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn limit_violations_rejected() {
        // row count over cap
        let mut payload = Vec::new();
        payload.extend_from_slice(&MAGIC);
        payload.push(VERSION);
        payload.push(KIND_REQUEST);
        payload.extend_from_slice(&0u64.to_le_bytes());
        payload.extend_from_slice(&1u16.to_le_bytes());
        payload.push(b'm');
        payload.extend_from_slice(&(MAX_ROWS_PER_FRAME as u16 + 1).to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes());
        assert!(matches!(decode_payload(&payload), Err(WireError::Oversized { .. })));

        // zero rows is structurally meaningless
        let req = InferRequest { key: 0, model: "m".into(), features: 3, data: Vec::new() };
        let mut wire = Vec::new();
        encode_frame(&Frame::Request(req), &mut wire);
        assert!(matches!(decode_payload(&wire[4..]), Err(WireError::Malformed(_))));
    }

    #[test]
    fn status_wire_codes_are_stable() {
        for v in 0..Status::COUNT as u8 {
            assert_eq!(Status::from_u8(v).unwrap() as u8, v);
        }
        assert!(Status::from_u8(Status::COUNT as u8).is_none());
        assert!(Status::QueueFull.is_queue_full_class());
        assert!(Status::AdmissionRejected.is_queue_full_class());
        assert!(Status::RateLimited.is_queue_full_class());
        assert!(!Status::DeadlineExceeded.is_queue_full_class());
        // retryable-vs-terminal classification for the reconnecting
        // client: backpressure and drain retry, wrongness never does
        for s in [
            Status::QueueFull,
            Status::AdmissionRejected,
            Status::RateLimited,
            Status::ShutDown,
            Status::TooManyConnections,
        ] {
            assert!(s.is_retryable(), "{s} must be retryable");
        }
        for s in [Status::Malformed, Status::UnknownModel, Status::AuthFailed] {
            assert!(!s.is_retryable(), "{s} must be terminal");
        }
        assert_eq!(Status::from_serve_error(&ServeError::QueueFull), Status::QueueFull);
        assert_eq!(
            Status::from_serve_error(&ServeError::DeadlineExceeded { waited_us: 5 }),
            Status::DeadlineExceeded
        );
        assert_eq!(
            Status::from_serve_error(&ServeError::WorkerPanicked),
            Status::WorkerPanicked
        );
        assert_eq!(Status::from_serve_error(&ServeError::ShutDown), Status::ShutDown);
    }
}
